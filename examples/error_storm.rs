//! Error storm: drive DGEMM/DGEMV/DTRSV/DTRSM through the coordinator
//! under escalating injection rates (the paper's claim: hundreds of
//! errors per minute — here up to thousands per second — with negligible
//! overhead and zero wrong answers).
//!
//! ```bash
//! cargo run --release --example error_storm
//! ```

use anyhow::Result;
use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::plan::{Planner, SelectionPolicy};
use ftblas::coordinator::request::{BlasRequest, BlasResponse, BlasResult};
use ftblas::coordinator::router::execute_plan;
use ftblas::ft::injector::{Injector, InjectorConfig};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::{allclose, Matrix};
use ftblas::util::rng::Rng;

/// Plan onto a pinned native variant and run the plan.
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

fn main() -> Result<()> {
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(13);
    let n = 384;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let l = Matrix::random_lower_triangular(n, &mut rng);

    let reqs = vec![
        BlasRequest::Dgemv { alpha: 1.0, a: a.clone(), x: rng.normal_vec(n),
                             beta: 0.0, y: rng.normal_vec(n) },
        BlasRequest::Dtrsv { a: l.clone(), b: rng.normal_vec(n) },
        BlasRequest::Dgemm { alpha: 1.0, a: a.clone(), b: b.clone(),
                             beta: 0.0, c: Matrix::zeros(n, n) },
        BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
    ];

    println!("{:<8} {:>10} {:>12} {:>12} {:>10} {:>10}", "routine",
             "errors", "clean-time", "storm-time", "ovhd%", "correct");
    for req in &reqs {
        let oracle = run_native(&req.clone(), Impl::Naive, &profile,
                                FtPolicy::None, None);
        // clean protected run
        let reps = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            run_native(req, Impl::Tuned, &profile, FtPolicy::Hybrid, None);
        }
        let clean = t0.elapsed().as_secs_f64() / reps as f64;

        // storm: every call carries a fault (paper: 1..10k errors/sec)
        let cfg = InjectorConfig { count: reps, seed: 99,
                                   ..Default::default() };
        let mut inj = Injector::plan(&cfg, reps, n.min(64), n);
        let mut detected = 0u64;
        let mut all_ok = true;
        let t0 = std::time::Instant::now();
        for step in 0..reps {
            let fault = inj.take(step);
            let resp = run_native(req, Impl::Tuned, &profile,
                                  FtPolicy::Hybrid, fault);
            detected += resp.ft.errors_detected;
            all_ok &= matches(&resp.result, &oracle.result);
        }
        let storm = t0.elapsed().as_secs_f64() / reps as f64;
        println!("{:<8} {:>10} {:>11.2}ms {:>11.2}ms {:>9.2}% {:>10}",
                 req.routine(), detected, clean * 1e3, storm * 1e3,
                 (storm - clean) / clean * 100.0,
                 if all_ok { "yes" } else { "NO" });
        assert!(all_ok, "{}: a corrupted result escaped!", req.routine());
        assert!(detected >= reps as u64 - 1,
                "{}: faults went undetected", req.routine());
    }
    println!("\nevery injected error was detected, corrected, and verified \
              against the oracle");
    Ok(())
}

fn matches(a: &BlasResult, b: &BlasResult) -> bool {
    match (a, b) {
        (BlasResult::Vector(x), BlasResult::Vector(y)) => {
            allclose(x, y, 1e-7, 1e-7)
        }
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, 1e-7, 1e-7)
        }
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() < 1e-7 * (1.0 + y.abs())
        }
        _ => false,
    }
}
