//! END-TO-END DRIVER (DESIGN.md §6): a mixed BLAS workload trace served
//! by the threaded coordinator — Poisson arrivals over all three BLAS
//! levels, fault injection at a configurable rate, every response
//! verified against the oracle, and throughput/latency/FT metrics
//! reported. This is the run recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_workload           # native backend
//! cargo run --release --example e2e_workload -- --pjrt # artifact backend
//! ```

use std::collections::HashMap;

use anyhow::Result;
use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::executor::PjrtExecutor;
use ftblas::coordinator::pjrt_backend::PjrtBackend;
use ftblas::coordinator::plan::{Planner, SelectionPolicy};
use ftblas::coordinator::request::{Backend, BlasRequest, BlasResponse,
                                   BlasResult};
use ftblas::coordinator::router::{execute_plan, Router};
use ftblas::coordinator::server::Server;
use ftblas::coordinator::trace::{self, TraceConfig};
use ftblas::ft::injector::InjectorConfig;
use ftblas::ft::injector::Fault;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::allclose;

/// Plan onto a pinned native variant and run the plan.
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

fn main() -> Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let profile = Profile::skylake_sim();
    let requests = 400;
    let cfg = TraceConfig {
        requests,
        vec_len: 65536,
        mat_dim: 256,
        // a second DGEMM shape: both resolve to the same kernel, so the
        // server's planned-kernel batching merges them into one group
        mat_dim_alt: Some(128),
        rate: 500.0,
        ..Default::default()
    };
    println!("generating a {requests}-request mixed trace (Poisson arrivals, \
              L1 n={}, L2/L3 n={})", cfg.vec_len, cfg.mat_dim);
    let entries = trace::generate(&cfg);

    // precompute oracles for verification
    println!("precomputing oracles...");
    let oracles: Vec<BlasResult> = entries
        .iter()
        .map(|e| {
            run_native(&e.request, Impl::Naive, &profile, FtPolicy::None,
                       None)
            .result
        })
        .collect();

    for policy in [FtPolicy::None, FtPolicy::Hybrid] {
        let make_router = || -> Result<Router> {
            if use_pjrt {
                let dir = profile.artifact_path();
                let exec = PjrtExecutor::spawn(dir.clone())?;
                let pjrt = PjrtBackend::new(exec.handle.clone(), &dir)?;
                pjrt.warmup_all()?;
                std::mem::forget(exec); // keep the executor thread alive
                Ok(Router::with_pjrt(profile.clone(), pjrt, Backend::Pjrt))
            } else {
                Ok(Router::native_only(profile.clone(), Backend::NativeTuned))
            }
        };
        let injection = policy.protects().then(|| InjectorConfig {
            count: requests / 4, // ~hundreds of errors/minute at this rate
            seed: 0xE2E,
            ..Default::default()
        });
        let server = Server::start(make_router()?, policy, profile.workers,
                                   injection, requests);
        let handle = server.handle();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = entries
            .iter()
            .map(|e| handle.submit(e.request.clone()))
            .collect();
        let mut verified = 0;
        let mut mismatched = 0;
        for (rx, want) in rxs.into_iter().zip(&oracles) {
            let resp = rx.recv()??;
            if results_match(&resp.result, want) {
                verified += 1;
            } else {
                mismatched += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = server.shutdown();
        println!("\n--- policy={} backend={} ---", policy.name(),
                 if use_pjrt { "pjrt" } else { "native-tuned" });
        println!("completed {} requests in {:.2}s  ->  {:.1} req/s",
                 m.completed, wall, m.completed as f64 / wall);
        println!("errors: injected={} detected={} corrected={}",
                 m.errors_injected, m.errors_detected, m.errors_corrected);
        println!("verification vs oracle: {verified} ok, {mismatched} wrong");
        let mut routines: Vec<_> = m.e2e_by_routine.iter().collect();
        routines.sort_by(|a, b| a.0.cmp(b.0));
        let mut tput: HashMap<&str, f64> = HashMap::new();
        for (routine, s) in routines {
            println!("  {:<8} n={:<4} p50={:>8.2}ms p99={:>8.2}ms mean-exec={:>8.2}ms",
                     routine, s.n, s.p50 * 1e3, s.p99 * 1e3,
                     m.exec_by_routine[routine].mean * 1e3);
            tput.insert(routine.as_str(), s.mean);
        }
        println!("\nper-kernel serving ledger:");
        ftblas::bench::harness::print_ledger(&m);
        assert_eq!(mismatched, 0, "corrupted results escaped the server!");
        if policy.protects() {
            assert_eq!(m.errors_detected, m.errors_injected,
                       "every injected fault must be detected");
        }
        if !use_pjrt {
            // every native request was planned at admission; after the
            // first occurrence of each (routine, dim, policy) key the
            // cache serves hits
            assert_eq!(m.plan_cache_hits + m.plan_cache_misses,
                       requests as u64,
                       "every request must resolve through the plan cache");
            assert!(m.plan_cache_hits > m.plan_cache_misses,
                    "a mixed trace re-uses shapes: hits should dominate");
        }
        assert!(m.max_in_flight_threads <= m.thread_budget,
                "ledger oversubscribed: {} > {}", m.max_in_flight_threads,
                m.thread_budget);
    }
    println!("\nE2E PASS: all responses bit-verified against the oracle under \
              both policies");
    Ok(())
}

fn results_match(a: &BlasResult, b: &BlasResult) -> bool {
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= 1e-7 * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => {
            allclose(x, y, 1e-7, 1e-7)
        }
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, 1e-7, 1e-7)
        }
        _ => false,
    }
}
