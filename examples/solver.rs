//! Downstream-consumer demo: three solvers built entirely on FT-BLAS —
//! a blocked Cholesky (dpotrf + triangular solves), a pivoted LU
//! (dgetrf, driven by IDAMAX/DGER/DTRSM/DGEMM), and a Conjugate
//! Gradient iteration — run both clean and under fault injection.
//!
//! The CG section demonstrates the paper's motivation for iterative
//! methods: one undetected soft error silently poisons every subsequent
//! iterate, while the DMR-protected solver converges identically to the
//! clean run.
//!
//! ```bash
//! cargo run --release --example solver
//! ```

use anyhow::Result;
use ftblas::apps::{cg, cholesky, lu};
use ftblas::blas::{naive, Impl};
use ftblas::config::Profile;
use ftblas::coordinator::plan::{Planner, SelectionPolicy};
use ftblas::coordinator::request::{BlasRequest, BlasResponse};
use ftblas::coordinator::router::execute_plan;
use ftblas::ft::injector::Fault;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

/// Plan onto a pinned native variant and run the plan.
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

fn main() -> Result<()> {
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(31);
    let n = 512;
    println!("building a random SPD system A x = b, n = {n}");
    let a = Matrix::random_spd(n, &mut rng);
    let b = rng.normal_vec(n);

    // solve through the blocked Cholesky built on FT-BLAS L2/L3
    let t0 = std::time::Instant::now();
    let x = cholesky::solve_spd(&a, &b, 64, &profile.gemm)?;
    let secs = t0.elapsed().as_secs_f64();

    // residual check
    let mut r = vec![0.0; n];
    naive::dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut r);
    let num: f64 = r.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
    let den: f64 = b.iter().map(|v| v * v).sum();
    let resid = (num / den).sqrt();
    println!("cholesky solve: {:.1}ms, relative residual {resid:.2e}",
             secs * 1e3);
    assert!(resid < 1e-8, "solver lost accuracy");

    // the same factorization's heavy kernel (DTRSM) under fault injection:
    // downstream apps inherit FT-BLAS's protection transparently
    let l = cholesky::dpotrf_lower(&a, 64, &profile.gemm)?;
    let bm = Matrix::random(n, 64, &mut rng);
    let req = BlasRequest::Dtrsm { a: l.clone(), b: bm.clone() };
    let clean = run_native(&req, Impl::Tuned, &profile,
                           FtPolicy::None, None);
    let fault = Fault { step: 3, i: 5, j: 17, delta: 1e8 };
    let ft = run_native(&req, Impl::Tuned, &profile,
                        FtPolicy::Hybrid, Some(fault));
    let diff = ft.result.as_matrix().unwrap()
        .max_abs_diff(clean.result.as_matrix().unwrap());
    println!("dtrsm panel solve under a 1e8 injected fault: detected={} \
              corrected={} | max diff vs clean = {diff:.2e}",
             ft.ft.errors_detected, ft.ft.errors_corrected);
    assert!(ft.ft.errors_detected >= 1);
    assert!(diff < 1e-6, "fault propagated into the solution!");
    println!("downstream solver is protected end-to-end");

    // ---- pivoted LU on a general (diagonally dominant) system
    let g = Matrix::random_diag_dominant(n, &mut rng);
    let t0 = std::time::Instant::now();
    let xg = lu::solve(&g, &b, 64, &profile.gemm)?;
    let secs = t0.elapsed().as_secs_f64();
    let mut r = vec![0.0; n];
    naive::dgemv(n, n, 1.0, &g.data, &xg, 0.0, &mut r);
    let num: f64 = r.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
    let resid = (num / den).sqrt();
    println!("lu solve (partial pivoting): {:.1}ms, relative residual \
              {resid:.2e}", secs * 1e3);
    assert!(resid < 1e-9, "lu solver lost accuracy");

    // ---- conjugate gradient: clean vs poisoned vs protected
    let clean = cg::solve(&a, &b, 1e-10, 4 * n)?;
    println!("cg clean:      converged in {} iters (residual {:.1e})",
             clean.iterations, clean.residual);
    let fault = (1usize, 7usize, 1e8f64);
    let poisoned = cg::solve_unprotected_faulty(&a, &b, 1e-10,
                                                clean.iterations, fault)?;
    println!("cg + 1 soft error, unprotected: converged={} residual {:.1e} \
              (same iteration budget)", poisoned.converged, poisoned.residual);
    let prot = cg::solve_protected(&a, &b, 1e-10, 4 * n, Some(fault))?;
    println!("cg + 1 soft error, DMR-protected: converged in {} iters, \
              detected={} corrected={}",
             prot.iterations, prot.ft.errors_detected,
             prot.ft.errors_corrected);
    assert!(prot.converged && prot.iterations == clean.iterations);
    assert!(prot.ft.errors_detected >= 1);
    println!("iterative solver protected transparently — same trajectory \
              as the clean run");
    Ok(())
}
