
use ftblas::blas::level3::{self, GemmParams};
use ftblas::blas::blocked;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;
fn main() {
    let (m, n) = (768, 768);
    let mut rng = Rng::new(9);
    let l = Matrix::random_lower_triangular(m, &mut rng);
    let b0 = Matrix::random(m, n, &mut rng);
    let params = GemmParams::default();
    for panel in [16usize, 32, 48, 64, 96, 128] {
        let mut best = f64::INFINITY;
        for _ in 0..6 {
            let mut b = b0.data.clone();
            let t0 = std::time::Instant::now();
            level3::dtrsm_llnn(m, n, &l.data, &mut b, panel, &params);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        println!("tuned panel={panel}: {:.1}ms", best * 1e3);
    }
    let mut best = f64::INFINITY;
    for _ in 0..6 {
        let mut b = b0.data.clone();
        let t0 = std::time::Instant::now();
        blocked::dtrsm_llnn(m, n, &l.data, &mut b);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    println!("blocked(32, scalar diag): {:.1}ms", best * 1e3);
}
