//! Quickstart: call FT-BLAS through the coordinator, with and without
//! fault tolerance, on both backends.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use ftblas::config::Profile;
use ftblas::coordinator::executor::PjrtExecutor;
use ftblas::coordinator::pjrt_backend::PjrtBackend;
use ftblas::coordinator::request::{Backend, BlasRequest};
use ftblas::coordinator::router::Router;
use ftblas::ft::injector::Fault;
use ftblas::ft::policy::FtPolicy;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

fn main() -> Result<()> {
    let profile = Profile::skylake_sim();
    let mut rng = Rng::new(7);

    // 1. native tuned kernels, no FT
    let router = Router::native_only(profile.clone(), Backend::NativeTuned);
    let n = 256;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: a.clone(),
        b: b.clone(),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };
    let plan = router.plan(&req, FtPolicy::None)
        .expect("the tuned ladder serves dgemm");
    println!("plan: {}", plan.describe());
    let resp = router.execute_planned(&plan, &req, None)?;
    println!("[native/ori]    dgemm {n}x{n}: {:.2}ms",
             resp.exec_seconds * 1e3);

    // 2. same call under the hybrid FT policy with an injected fault —
    //    the soft error is detected, located and corrected online
    let fault = Fault { step: 1, i: 100, j: 200, delta: 1e6 };
    let plan = router.plan(&req, FtPolicy::Hybrid)
        .expect("a protected dgemm plans on every profile");
    let ft = router.execute_planned(&plan, &req, Some(fault))?;
    println!("[native/hybrid] dgemm {n}x{n}: {:.2}ms, detected={} corrected={}",
             ft.exec_seconds * 1e3, ft.ft.errors_detected,
             ft.ft.errors_corrected);
    let clean = resp.result.as_matrix().unwrap();
    let fixed = ft.result.as_matrix().unwrap();
    println!("max |FT - clean| = {:.2e}  (the 1e6 corruption is gone)",
             fixed.max_abs_diff(clean));

    // 3. the PJRT backend: the same request served by the AOT-compiled
    //    Pallas fused-ABFT kernel (skipped if `make artifacts` hasn't run)
    let dir = profile.artifact_path();
    if dir.join("manifest.tsv").exists() {
        let exec = PjrtExecutor::spawn(dir.clone())?;
        let pjrt = PjrtBackend::new(exec.handle.clone(), &dir)?;
        let router = Router::with_pjrt(profile, pjrt, Backend::Pjrt);
        let plan = router.plan(&req, FtPolicy::Hybrid)
            .expect("the loaded artifact set serves dgemm");
        let resp = router.execute_planned(&plan, &req, Some(fault))?;
        println!("[pjrt/hybrid]   dgemm {n}x{n}: {:.2}ms, detected={} (fused \
                  Pallas ABFT kernel)",
                 resp.exec_seconds * 1e3, resp.ft.errors_detected);
        let got = resp.result.as_matrix().unwrap();
        println!("max |pjrt - native| = {:.2e}", got.max_abs_diff(clean));
    } else {
        println!("[pjrt] artifacts/ missing — run `make artifacts` first");
    }
    Ok(())
}
