//! Tuned Level-3 kernels (paper §3.3): packed, cache-blocked DGEMM with an
//! unrolled micro kernel, and DTRSM with the reciprocal-diagonal packing
//! trick and a tuned diagonal macro kernel. The DGEMM packing panels are
//! leased from the thread-local [`crate::util::arena`], so steady-state
//! calls are allocation-free.

use crate::util::arena;

/// Cache-blocking parameters (the paper's M_C/N_C/K_C). Tuned per profile
/// in config.rs; these are the Skylake-sim defaults.
#[derive(Clone, Copy, Debug)]
pub struct GemmParams {
    /// Row-panel block (L2-cache resident A panel).
    pub mc: usize,
    /// Column block (L3-resident B panel).
    pub nc: usize,
    /// Depth block (packed panel depth).
    pub kc: usize,
    /// Micro-kernel rows (register tile).
    pub mr: usize,
    /// Micro-kernel columns (register tile).
    pub nr: usize,
}

impl Default for GemmParams {
    fn default() -> Self {
        // MR x NR = 4 x 8 micro tile: 4 accumulator rows of one
        // SIMD-width each (the paper picks its own MR/NR on top of the
        // OpenBLAS frame).
        GemmParams { mc: 128, nc: 256, kc: 128, mr: 4, nr: 8 }
    }
}

/// Pack an (mc x kc) block of A (row-major, lda = k) into micro-panels of
/// MR rows so the micro kernel streams it contiguously.
fn pack_a(a: &[f64], lda: usize, i0: usize, p0: usize, mc: usize, kc: usize,
          mr: usize, out: &mut [f64]) {
    let mut w = 0;
    let mut i = 0;
    while i < mc {
        let rows = mr.min(mc - i);
        for p in 0..kc {
            for r in 0..rows {
                out[w] = a[(i0 + i + r) * lda + p0 + p];
                w += 1;
            }
            for _ in rows..mr {
                out[w] = 0.0;
                w += 1;
            }
        }
        i += mr;
    }
}

/// Pack a (kc x nc) block of B into micro-panels of NR columns.
fn pack_b(b: &[f64], ldb: usize, p0: usize, j0: usize, kc: usize, nc: usize,
          nr: usize, out: &mut [f64]) {
    let mut w = 0;
    let mut j = 0;
    while j < nc {
        let cols = nr.min(nc - j);
        for p in 0..kc {
            for cdx in 0..cols {
                out[w] = b[(p0 + p) * ldb + j0 + j + cdx];
                w += 1;
            }
            for _ in cols..nr {
                out[w] = 0.0;
                w += 1;
            }
        }
        j += nr;
    }
}

/// MR x NR micro kernel: C_sub += Apanel * Bpanel over kc, accumulators in
/// registers (the paper's AVX-512 FMA micro kernel).
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f64], bp: &[f64], mr: usize, nr: usize,
                acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), mr * nr);
    if mr == 4 && nr == 8 {
        // const-shape fast path: with MR/NR fixed the 4x8 accumulator
        // tile is fully register-allocated (4 zmm under AVX-512) and the
        // inner body is 4 broadcast-FMA rows per k step — the paper's
        // hand-picked micro-kernel parameters (§3.3.2)
        let tile: &mut [f64; 32] = (&mut acc[..32]).try_into().unwrap();
        micro_kernel_4x8(kc, ap, bp, tile);
        return;
    }
    for v in acc.iter_mut() {
        *v = 0.0;
    }
    for p in 0..kc {
        let arow = &ap[p * mr..(p + 1) * mr];
        let brow = &bp[p * nr..(p + 1) * nr];
        for r in 0..mr {
            let av = arow[r];
            let dst = &mut acc[r * nr..(r + 1) * nr];
            for (d, bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
}

/// The 4x8 micro kernel with a compile-time-shaped accumulator tile.
#[inline(always)]
fn micro_kernel_4x8(kc: usize, ap: &[f64], bp: &[f64], acc: &mut [f64; 32]) {
    let mut tile = [0.0f64; 32];
    for p in 0..kc {
        let arow: &[f64; 4] = ap[p * 4..p * 4 + 4].try_into().unwrap();
        let brow: &[f64; 8] = bp[p * 8..p * 8 + 8].try_into().unwrap();
        for r in 0..4 {
            let av = arow[r];
            for l in 0..8 {
                tile[r * 8 + l] += av * brow[l];
            }
        }
    }
    *acc = tile;
}

/// C := alpha A B + beta C — packed + blocked (paper §3.3.2).
pub fn dgemm(m: usize, n: usize, k: usize, alpha: f64, a: &[f64], b: &[f64],
             beta: f64, c: &mut [f64], params: &GemmParams) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    if k == 0 || alpha == 0.0 {
        return;
    }
    let &GemmParams { mc, nc, kc, mr, nr } = params;
    // packing panels + accumulator come from the thread-local arena:
    // steady-state calls (the batched small-GEMM shape) allocate nothing
    arena::with(
        [arena::packed_a_len(mc, kc, mr), arena::packed_b_len(nc, kc, nr),
         mr * nr],
        |[apack, bpack, acc]| {
            let mut j0 = 0;
            while j0 < n {
                let ncb = nc.min(n - j0);
                let mut p0 = 0;
                while p0 < k {
                    let kcb = kc.min(k - p0);
                    pack_b(b, n, p0, j0, kcb, ncb, nr, bpack);
                    let mut i0 = 0;
                    while i0 < m {
                        let mcb = mc.min(m - i0);
                        pack_a(a, k, i0, p0, mcb, kcb, mr, apack);
                        // macro kernel: iterate micro tiles
                        let mut jj = 0;
                        while jj < ncb {
                            let nrb = nr.min(ncb - jj);
                            let bp =
                                &bpack[(jj / nr) * (nr * kcb)..][..nr * kcb];
                            let mut ii = 0;
                            while ii < mcb {
                                let mrb = mr.min(mcb - ii);
                                let ap = &apack[(ii / mr) * (mr * kcb)..]
                                    [..mr * kcb];
                                micro_kernel(kcb, ap, bp, mr, nr, acc);
                                for r in 0..mrb {
                                    let crow = &mut c
                                        [(i0 + ii + r) * n + j0 + jj..][..nrb];
                                    let arow = &acc[r * nr..r * nr + nrb];
                                    for (cv, av) in crow.iter_mut().zip(arow) {
                                        *cv += alpha * av;
                                    }
                                }
                                ii += mr;
                            }
                            jj += nr;
                        }
                        i0 += mc;
                    }
                    p0 += kc;
                }
                j0 += nc;
            }
        },
    );
}

/// C := alpha sym(A) B + beta C — the DSYMM packing modification: the
/// packed A panel reads the lower triangle for both halves (paper §6.2.3).
pub fn dsymm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &[f64],
                   beta: f64, c: &mut [f64], params: &GemmParams) {
    // symmetrize into a scratch matrix (the packing-routine analog),
    // then run the tuned GEMM frame on it.
    let mut full = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            let v = a[i * m + j];
            full[i * m + j] = v;
            full[j * m + i] = v;
        }
    }
    dgemm(m, n, m, alpha, &full, b, beta, c, params);
}

/// B := alpha tril(A) B — triangular packing + the GEMM frame.
pub fn dtrmm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &mut [f64],
                   params: &GemmParams) {
    let mut low = vec![0.0; m * m];
    for i in 0..m {
        low[i * m..i * m + i + 1].copy_from_slice(&a[i * m..i * m + i + 1]);
    }
    let b0 = b.to_vec();
    dgemm(m, n, m, alpha, &low, &b0, 0.0, b, params);
}

/// C := alpha A A^T + beta C (lower triangle); uses the GEMM frame per
/// block-row to stay O(n^2 k / 2).
pub fn dsyrk_lower(n: usize, k: usize, alpha: f64, a: &[f64], beta: f64,
                   c: &mut [f64], params: &GemmParams) {
    // Row-block panels: C(i:ib, 0:ib) uses gemm against A(0:ib,:)^T.
    let blk = params.mc;
    let at = {
        let mut t = vec![0.0; k * n];
        for i in 0..n {
            for p in 0..k {
                t[p * n + i] = a[i * k + p];
            }
        }
        t
    };
    let mut i0 = 0;
    while i0 < n {
        let mb = blk.min(n - i0);
        let jb = i0 + mb; // only columns 0..jb are in the lower triangle
        // C(i0:i0+mb, 0:jb) = alpha * A(i0:.., :) @ A(0:jb, :)^T + beta C
        let mut cblk = vec![0.0; mb * jb];
        for r in 0..mb {
            cblk[r * jb..(r + 1) * jb]
                .copy_from_slice(&c[(i0 + r) * n..(i0 + r) * n + jb]);
        }
        let ablk = &a[i0 * k..(i0 + mb) * k];
        // build A(0:jb,:)^T view from at: rows p, cols 0..jb
        let mut bt = vec![0.0; k * jb];
        for p in 0..k {
            bt[p * jb..(p + 1) * jb].copy_from_slice(&at[p * n..p * n + jb]);
        }
        dgemm(mb, jb, k, alpha, ablk, &bt, beta, &mut cblk, params);
        for r in 0..mb {
            let gi = i0 + r;
            // only write the lower part of this block row
            let lim = (gi + 1).min(jb);
            c[gi * n..gi * n + lim].copy_from_slice(&cblk[r * jb..r * jb + lim]);
        }
        i0 += mb;
    }
}

/// Solve tril(A) X = B in place — paneled (paper §3.3.3, Fig. 2): the
/// off-diagonal update B_block -= Ã B̃ goes through the tuned GEMM macro
/// kernel; the diagonal block is solved by a tuned TRSM kernel that uses
/// *reciprocals of the diagonal packed ahead of time* (avoids divisions in
/// the hot loop — the paper's packing trick).
pub fn dtrsm_llnn(m: usize, n: usize, a: &[f64], b: &mut [f64], panel: usize,
                  params: &GemmParams) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    // pack reciprocal diagonal once (paper: stored during packing)
    let rdiag: Vec<f64> = (0..m).map(|i| 1.0 / a[i * m + i]).collect();
    let mut i = 0;
    while i < m {
        let pb = panel.min(m - i);
        if i > 0 {
            // B(i:i+pb, :) -= A(i:i+pb, 0:i) * X(0:i, :)  — GEMM update
            let mut apanel = vec![0.0; pb * i];
            for r in 0..pb {
                apanel[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * m..(i + r) * m + i]);
            }
            let xdone = b[..i * n].to_vec();
            let (_, btail) = b.split_at_mut(i * n);
            let bblk = &mut btail[..pb * n];
            dgemm(pb, n, i, -1.0, &apanel, &xdone, 1.0, bblk, params);
        }
        // diagonal pb x pb solve with reciprocal multiplies
        for r in 0..pb {
            let gi = i + r;
            for p in 0..r {
                let aip = a[gi * m + i + p];
                if aip != 0.0 {
                    let src = i + p;
                    let (done, cur) = b.split_at_mut(gi * n);
                    let brow = &mut cur[..n];
                    let srow = &done[src * n..(src + 1) * n];
                    for (bv, sv) in brow.iter_mut().zip(srow) {
                        *bv -= aip * sv;
                    }
                }
            }
            let rd = rdiag[gi];
            for bv in &mut b[gi * n..(gi + 1) * n] {
                *bv *= rd;
            }
        }
        i += pb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    fn small_params(g: &mut crate::util::check::Gen) -> GemmParams {
        GemmParams {
            mc: [16, 32, 64][g.rng.below(3)],
            nc: [16, 32, 64][g.rng.below(3)],
            kc: [16, 32][g.rng.below(2)],
            mr: 4,
            nr: 8,
        }
    }

    #[test]
    fn dgemm_matches_naive() {
        check("dgemm", 30, |g| {
            let m = g.dim(1, 70);
            let n = g.dim(1, 70);
            let k = g.dim(1, 70);
            let p = small_params(g);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let (alpha, beta) = (g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0));
            let mut c1 = c0.data.clone();
            let mut c2 = c0.data;
            dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut c1, &p);
            naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut c2);
            ensure(allclose(&c1, &c2, 1e-10, 1e-10), "tuned dgemm != naive")
        });
    }

    #[test]
    fn dgemm_alpha_zero_scales_only() {
        let mut c = vec![2.0; 4];
        dgemm(2, 2, 2, 0.0, &[1.0; 4], &[1.0; 4], 0.5, &mut c,
              &GemmParams::default());
        assert_eq!(c, vec![1.0; 4]);
    }

    #[test]
    fn dsymm_matches_naive() {
        check("dsymm", 15, |g| {
            let m = g.dim(1, 50);
            let n = g.dim(1, 50);
            let p = small_params(g);
            let a = Matrix::random_symmetric(m, &mut g.rng);
            let b = Matrix::random(m, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let mut c1 = c0.data.clone();
            let mut c2 = c0.data;
            dsymm_lower(m, n, 1.1, &a.data, &b.data, 0.6, &mut c1, &p);
            naive::dsymm_lower(m, n, 1.1, &a.data, &b.data, 0.6, &mut c2);
            ensure(allclose(&c1, &c2, 1e-10, 1e-10), "dsymm mismatch")
        });
    }

    #[test]
    fn dtrmm_matches_naive() {
        check("dtrmm", 15, |g| {
            let m = g.dim(1, 50);
            let n = g.dim(1, 50);
            let p = small_params(g);
            let a = Matrix::random_lower_triangular(m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut b1 = b0.data.clone();
            let mut b2 = b0.data;
            dtrmm_lower(m, n, 1.4, &a.data, &mut b1, &p);
            naive::dtrmm_lower(m, n, 1.4, &a.data, &mut b2);
            ensure(allclose(&b1, &b2, 1e-10, 1e-10), "dtrmm mismatch")
        });
    }

    #[test]
    fn dsyrk_matches_naive() {
        check("dsyrk", 15, |g| {
            let n = g.dim(1, 60);
            let k = g.dim(1, 40);
            let p = small_params(g);
            let a = Matrix::random(n, k, &mut g.rng);
            let c0 = Matrix::random(n, n, &mut g.rng);
            let mut c1 = c0.data.clone();
            let mut c2 = c0.data;
            dsyrk_lower(n, k, 1.3, &a.data, 0.7, &mut c1, &p);
            naive::dsyrk_lower(n, k, 1.3, &a.data, 0.7, &mut c2);
            ensure(allclose(&c1, &c2, 1e-10, 1e-10), "dsyrk mismatch")
        });
    }

    #[test]
    fn dtrsm_matches_naive_any_panel() {
        check("dtrsm", 20, |g| {
            let m = g.dim(1, 80);
            let n = g.dim(1, 60);
            let panel = [1, 4, 16, 32][g.rng.below(4)];
            let p = small_params(g);
            let a = Matrix::random_lower_triangular(m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut x1 = b0.data.clone();
            let mut x2 = b0.data;
            dtrsm_llnn(m, n, &a.data, &mut x1, panel, &p);
            naive::dtrsm_llnn(m, n, &a.data, &mut x2);
            ensure(
                allclose(&x1, &x2, 1e-9, 1e-9),
                format!("dtrsm mismatch panel={panel}"),
            )
        });
    }
}
