//! The "OpenBLAS/BLIS stand-in" (DESIGN.md substitution #2): competent
//! cache-blocked kernels that deliberately carry the exact
//! under-optimizations the paper's Table 1 and §3 call out, so the
//! benches reproduce the paper's *relative* gaps:
//!
//! - `dscal`: vectorized chunks but **no software prefetch** (the paper's
//!   3.85 % DSCAL gap).
//! - `dnrm2`: narrow 2-lane chunks standing in for the legacy **SSE2**
//!   path OpenBLAS ships (the paper's 17.89 % DNRM2 gap).
//! - `dtrsv`: panel size **B = 64** (OpenBLAS's `common.h` default; the
//!   paper tunes B = 4 for its 11.17 % gap).
//! - `dtrsm`: GEMM frame for the panel update but a **scalar diagonal
//!   solver** ("an under-optimized prototype", the paper's 22.19 % gap).
//! - `dgemm`: the same packed/blocked frame as the tuned kernel (the
//!   paper reports < ±0.5 % vs OpenBLAS DGEMM).

use crate::blas::level3::{self, GemmParams};

const SSE_LANES: usize = 2; // legacy 128-bit SSE2 = 2 doubles

/// DSCAL without prefetch (otherwise the tuned chunked loop).
///
/// This is rung two of the four-rung serial ladder the registry
/// reports through `serial_variants` — naive → **blocked** → tuned →
/// simd — and its position is load-bearing: the bench figures and the
/// committed perf trajectory read the ladder positionally (blocked at
/// index 1, the paper's 3.85 % DSCAL gap measured against index 2).
/// The ordering itself is pinned by the registry's
/// `serial_ladder_order_is_deterministic` regression test.
pub fn dscal(alpha: f64, x: &mut [f64]) {
    const STEP: usize = 8 * 4;
    let n = x.len();
    let main = n - n % STEP;
    let mut i = 0;
    while i < main {
        for l in 0..STEP {
            x[i + l] *= alpha;
        }
        i += STEP;
    }
    for v in &mut x[main..] {
        *v *= alpha;
    }
}

/// DAXPY, vectorized, no prefetch.
pub fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// DDOT with a single accumulator chain (no ILP unrolling).
pub fn ddot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = 0.0;
    for (a, b) in x.iter().zip(y) {
        acc += a * b;
    }
    acc
}

/// DNRM2 via narrow SSE2-width chunks (Table 1: OpenBLAS DNRM2 is
/// "AVX or earlier").
pub fn dnrm2(x: &[f64]) -> f64 {
    let n = x.len();
    let main = n - n % SSE_LANES;
    let mut acc = [0.0f64; SSE_LANES];
    let mut i = 0;
    while i < main {
        for (l, a) in acc.iter_mut().enumerate() {
            let v = x[i + l];
            *a += v * v;
        }
        i += SSE_LANES;
    }
    let mut ssq: f64 = acc.iter().sum();
    for v in &x[main..] {
        ssq += v * v;
    }
    if ssq.is_finite() && ssq > f64::MIN_POSITIVE {
        ssq.sqrt()
    } else {
        crate::blas::naive::dnrm2(x)
    }
}

/// DGEMV with cache blocking of A (the strategy the paper argues *against*
/// for DGEMV — extra pointer bookkeeping, same loads).
pub fn dgemv(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64],
             beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    const JBLK: usize = 512;
    let mut tmp = vec![0.0; m];
    let mut j0 = 0;
    while j0 < n {
        let jb = JBLK.min(n - j0);
        for i in 0..m {
            let row = &a[i * n + j0..i * n + j0 + jb];
            let xs = &x[j0..j0 + jb];
            let mut acc = 0.0;
            for (av, xv) in row.iter().zip(xs) {
                acc += av * xv;
            }
            tmp[i] += acc;
        }
        j0 += JBLK;
    }
    for i in 0..m {
        y[i] = alpha * tmp[i] + beta * y[i];
    }
}

/// DTRSV with the OpenBLAS default panel B = 64.
pub fn dtrsv_lower(n: usize, a: &[f64], x: &mut [f64]) {
    crate::blas::level2::dtrsv_lower(n, a, x, 64);
}

/// DGEMM: same frame as tuned (paper: < ±0.5 % difference).
pub fn dgemm(m: usize, n: usize, k: usize, alpha: f64, a: &[f64], b: &[f64],
             beta: f64, c: &mut [f64]) {
    level3::dgemm(m, n, k, alpha, a, b, beta, c, &GemmParams::default());
}

/// DSYMM via the same frame.
pub fn dsymm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &[f64],
                   beta: f64, c: &mut [f64]) {
    level3::dsymm_lower(m, n, alpha, a, b, beta, c, &GemmParams::default());
}

/// DTRMM via the same frame.
pub fn dtrmm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &mut [f64]) {
    level3::dtrmm_lower(m, n, alpha, a, b, &GemmParams::default());
}

/// DTRSM: GEMM panel update + **scalar** diagonal solver (the
/// "under-optimized prototype" the paper beats by 22.19 %).
pub fn dtrsm_llnn(m: usize, n: usize, a: &[f64], b: &mut [f64]) {
    const PANEL: usize = 32;
    let params = GemmParams::default();
    let mut i = 0;
    while i < m {
        let pb = PANEL.min(m - i);
        if i > 0 {
            let mut apanel = vec![0.0; pb * i];
            for r in 0..pb {
                apanel[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * m..(i + r) * m + i]);
            }
            let xdone = b[..i * n].to_vec();
            let (_, btail) = b.split_at_mut(i * n);
            level3::dgemm(pb, n, i, -1.0, &apanel, &xdone, 1.0,
                          &mut btail[..pb * n], &params);
        }
        // scalar diagonal solve: per-element divisions, no vectorization,
        // column-major walk (pessimal stride) — the unoptimized prototype
        for j in 0..n {
            for r in 0..pb {
                let gi = i + r;
                let mut acc = b[gi * n + j];
                for p in 0..r {
                    acc -= a[gi * m + i + p] * b[(i + p) * n + j];
                }
                b[gi * n + j] = acc / a[gi * m + gi];
            }
        }
        i += pb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure, ensure_close};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn all_match_naive() {
        check("blocked-matches-naive", 25, |g| {
            let n = g.dim(1, 90);
            let alpha = g.rng.range(-2.0, 2.0);
            // dscal
            let x0 = g.rng.normal_vec(n);
            let mut a1 = x0.clone();
            let mut a2 = x0.clone();
            dscal(alpha, &mut a1);
            naive::dscal(alpha, &mut a2);
            ensure(a1 == a2, "dscal")?;
            // ddot/dnrm2
            let y0 = g.rng.normal_vec(n);
            ensure_close(ddot(&x0, &y0), naive::ddot(&x0, &y0), 1e-12, "ddot")?;
            ensure_close(dnrm2(&x0), naive::dnrm2(&x0), 1e-12, "dnrm2")
        });
    }

    #[test]
    fn dgemv_matches_naive() {
        check("blocked-dgemv", 20, |g| {
            let m = g.dim(1, 80);
            let n = g.dim(1, 700);
            let a = Matrix::random(m, n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(m);
            let mut y1 = y0.clone();
            let mut y2 = y0;
            dgemv(m, n, 1.2, &a.data, &x, -0.3, &mut y1);
            naive::dgemv(m, n, 1.2, &a.data, &x, -0.3, &mut y2);
            ensure(allclose(&y1, &y2, 1e-10, 1e-10), "blocked dgemv mismatch")
        });
    }

    #[test]
    fn dtrsm_matches_naive() {
        check("blocked-dtrsm", 15, |g| {
            let m = g.dim(1, 70);
            let n = g.dim(1, 50);
            let a = Matrix::random_lower_triangular(m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut x1 = b0.data.clone();
            let mut x2 = b0.data;
            dtrsm_llnn(m, n, &a.data, &mut x1);
            naive::dtrsm_llnn(m, n, &a.data, &mut x2);
            ensure(allclose(&x1, &x2, 1e-9, 1e-9), "blocked dtrsm mismatch")
        });
    }

    #[test]
    fn dtrsv_matches_naive() {
        check("blocked-dtrsv", 15, |g| {
            let n = g.dim(1, 150);
            let a = Matrix::random_lower_triangular(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let mut x1 = b.clone();
            let mut x2 = b;
            dtrsv_lower(n, &a.data, &mut x1);
            naive::dtrsv_lower(n, &a.data, &mut x2);
            ensure(allclose(&x1, &x2, 1e-9, 1e-9), "blocked dtrsv mismatch")
        });
    }
}
