//! Multi-threaded Level-3 kernels — the paper's stated future work
//! ("extending FT-BLAS to more architectures with parallel support"),
//! built so the FT machinery composes with parallelism for free.
//!
//! Partitioning choices keep every thread's FT state private:
//!
//! - **DGEMM**: C is split into row bands; each thread runs the serial
//!   (or fused-ABFT) frame on `C[band] += α·A[band]·B`. Bands share only
//!   read-only A/B, so the fused checksum vectors, verification intervals
//!   and corrections are all band-local — a strike in one band is
//!   detected and corrected by the thread that computed it, concurrently
//!   with the others.
//! - **DTRSM**: the solve is sequential in M but *independent per column
//!   of B*, so threads take column stripes (gathered to contiguous
//!   stripes, solved, scattered back — the copies are O(m·n) against the
//!   O(m²·n/2) solve).
//!
//! `threads = 1` falls through to the serial kernels (no spawn, no copy).
//!
//! Threading frames go through [`crate::runtime::pool::run_tasks`]: a
//! serving cluster installs its persistent work-stealing pool on the
//! executing thread and the band closures become pool tasks gated on a
//! completion latch; without an installed pool (unit tests, `--no-pool`
//! A/B mode) the identical closures run under a scoped fork/join. The
//! band decomposition, strike re-homing, and report merges are the same
//! either way, so pooled results are bitwise identical to scoped ones.

use crate::blas::level3::{self, GemmParams};
use crate::blas::simd;
use crate::ft::abft_fused::{self, Strike};
use crate::ft::FtReport;
use crate::runtime::pool::{self, ScopedTask};

/// Split `m` rows into at most `threads` contiguous bands, MR-aligned so
/// no band starts mid micro-tile. Shared with the batched driver
/// ([`crate::blas::batched`]), which decomposes every item of a batch by
/// the same rule before pooling the bands into one work queue.
pub(crate) fn row_bands(m: usize, threads: usize, mr: usize)
                        -> Vec<(usize, usize)> {
    let t = threads.max(1).min(m.div_ceil(mr).max(1));
    let per = m.div_ceil(t).div_ceil(mr) * mr;
    let mut bands = Vec::new();
    let mut i = 0;
    while i < m {
        let hi = (i + per).min(m);
        bands.push((i, hi));
        i = hi;
    }
    bands
}

/// C := α·A·B + β·C across `threads` row bands.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_mt(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                b: &[f64], beta: f64, c: &mut [f64], params: &GemmParams,
                threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if threads <= 1 || m < 2 * params.mr {
        level3::dgemm(m, n, k, alpha, a, b, beta, c, params);
        return;
    }
    let bands = row_bands(m, threads, params.mr);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = c;
    for &(lo, hi) in &bands {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let a_band = &a[lo * k..hi * k];
        tasks.push(Box::new(move || {
            level3::dgemm(hi - lo, n, k, alpha, a_band, b, beta, band,
                          params);
        }));
    }
    pool::run_tasks("dgemm/mt", tasks);
}

/// Fused-ABFT DGEMM across row bands: each band carries its own checksum
/// state and verification intervals, so protection is per-thread with no
/// shared mutable state. Strikes are routed to the band owning their row.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_fused_mt(m: usize, n: usize, k: usize, alpha: f64,
                           a: &[f64], b: &[f64], beta: f64, c: &mut [f64],
                           params: &GemmParams, threads: usize,
                           inject: &[Strike]) -> FtReport {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if threads <= 1 || m < 2 * params.mr {
        return abft_fused::dgemm_abft_fused(m, n, k, alpha, a, b, beta, c,
                                            params, inject);
    }
    let bands = row_bands(m, threads, params.mr);
    let mut reports: Vec<FtReport> = vec![FtReport::none(); bands.len()];
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = c;
    for (&(lo, hi), slot) in bands.iter().zip(reports.iter_mut()) {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let a_band = &a[lo * k..hi * k];
        // re-home strikes into band-local row coordinates
        let band_inject: Vec<Strike> = inject
            .iter()
            .filter(|&&(_, i, _, _)| i >= lo && i < hi)
            .map(|&(st, i, j, d)| (st, i - lo, j, d))
            .collect();
        tasks.push(Box::new(move || {
            *slot = abft_fused::dgemm_abft_fused(hi - lo, n, k, alpha,
                                                 a_band, b, beta, band,
                                                 params, &band_inject);
        }));
    }
    pool::run_tasks("dgemm/abft-fused-mt", tasks);
    let mut total = FtReport::none();
    for r in reports {
        total.merge(r);
    }
    total
}

/// C := α·A·B + β·C across `threads` row bands, each band running the
/// runtime-probed SIMD serial frame (AVX2+FMA where the one-time CPU
/// probe allows, tuned-scalar otherwise). Bands are MR-aligned to the
/// SIMD micro-tile height so no thread starts mid 8×4 tile.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_simd_mt(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                     b: &[f64], beta: f64, c: &mut [f64],
                     params: &GemmParams, threads: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let mr = simd::MR;
    if threads <= 1 || m < 2 * mr {
        simd::dgemm(m, n, k, alpha, a, b, beta, c, params);
        return;
    }
    let bands = row_bands(m, threads, mr);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = c;
    for &(lo, hi) in &bands {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let a_band = &a[lo * k..hi * k];
        tasks.push(Box::new(move || {
            simd::dgemm(hi - lo, n, k, alpha, a_band, b, beta, band,
                        params);
        }));
    }
    pool::run_tasks("dgemm/simd-mt", tasks);
}

/// Checksum-fused SIMD DGEMM across row bands: the same band-local FT
/// state as [`dgemm_abft_fused_mt`], but each band runs the
/// runtime-probed SIMD fused frame, so the dual accumulators stay
/// in-register per thread. Strikes are re-homed to the band owning
/// their row.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_fused_simd_mt(m: usize, n: usize, k: usize, alpha: f64,
                                a: &[f64], b: &[f64], beta: f64,
                                c: &mut [f64], params: &GemmParams,
                                threads: usize, inject: &[Strike])
                                -> FtReport {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let mr = simd::MR;
    if threads <= 1 || m < 2 * mr {
        return simd::dgemm_abft_fused(m, n, k, alpha, a, b, beta, c,
                                      params, inject);
    }
    let bands = row_bands(m, threads, mr);
    let mut reports: Vec<FtReport> = vec![FtReport::none(); bands.len()];
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = c;
    for (&(lo, hi), slot) in bands.iter().zip(reports.iter_mut()) {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let a_band = &a[lo * k..hi * k];
        // re-home strikes into band-local row coordinates
        let band_inject: Vec<Strike> = inject
            .iter()
            .filter(|&&(_, i, _, _)| i >= lo && i < hi)
            .map(|&(st, i, j, d)| (st, i - lo, j, d))
            .collect();
        tasks.push(Box::new(move || {
            *slot = simd::dgemm_abft_fused(hi - lo, n, k, alpha, a_band, b,
                                           beta, band, params, &band_inject);
        }));
    }
    pool::run_tasks("dgemm/abft-fused-simd-mt", tasks);
    let mut total = FtReport::none();
    for r in reports {
        total.merge(r);
    }
    total
}

/// C := α·sym(A)·B + β·C across `threads` row bands (A symmetric, lower
/// triangle stored). The symmetrization buffer is built once and shared
/// read-only — the packing-routine analog — then each band runs the
/// serial GEMM frame on its own rows of C, so bands share no mutable
/// state.
#[allow(clippy::too_many_arguments)]
pub fn dsymm_lower_mt(m: usize, n: usize, alpha: f64, a: &[f64], b: &[f64],
                      beta: f64, c: &mut [f64], params: &GemmParams,
                      threads: usize) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), m * n);
    if threads <= 1 || m < 2 * params.mr {
        level3::dsymm_lower(m, n, alpha, a, b, beta, c, params);
        return;
    }
    let mut full = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            let v = a[i * m + j];
            full[i * m + j] = v;
            full[j * m + i] = v;
        }
    }
    let bands = row_bands(m, threads, params.mr);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = c;
    for &(lo, hi) in &bands {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let a_band = &full[lo * m..hi * m];
        tasks.push(Box::new(move || {
            level3::dgemm(hi - lo, n, m, alpha, a_band, b, beta, band,
                          params);
        }));
    }
    pool::run_tasks("dsymm/mt", tasks);
}

/// B := α·tril(A)·B across `threads` row bands. Output row `i` only
/// reads input rows `0..=i`, so each band multiplies its rows of the
/// (zero-filled above the diagonal) triangle against a snapshot of B —
/// the k-extent per band stops at the band's last row, keeping the work
/// O(m²·n/2) overall like the serial frame.
pub fn dtrmm_lower_mt(m: usize, n: usize, alpha: f64, a: &[f64],
                      b: &mut [f64], params: &GemmParams, threads: usize) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    if threads <= 1 || m < 2 * params.mr {
        level3::dtrmm_lower(m, n, alpha, a, b, params);
        return;
    }
    let b0 = b.to_vec();
    let bands = row_bands(m, threads, params.mr);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(bands.len());
    let mut rest = b;
    for &(lo, hi) in &bands {
        let (band, tail) = rest.split_at_mut((hi - lo) * n);
        rest = tail;
        let b0 = &b0;
        tasks.push(Box::new(move || {
            // pack this band's rows of the triangle, zero-filled
            // above the diagonal, truncated to k = hi columns
            let mut apanel = vec![0.0; (hi - lo) * hi];
            for (r, row) in apanel.chunks_exact_mut(hi).enumerate() {
                let gi = lo + r;
                row[..=gi].copy_from_slice(&a[gi * m..gi * m + gi + 1]);
            }
            level3::dgemm(hi - lo, n, hi, alpha, &apanel, &b0[..hi * n],
                          0.0, band, params);
        }));
    }
    pool::run_tasks("dtrmm/mt", tasks);
}

/// Solve tril(A)·X = B in place across `threads` column stripes (each
/// stripe is an independent triangular solve).
pub fn dtrsm_llnn_mt(m: usize, n: usize, a: &[f64], b: &mut [f64],
                     panel: usize, params: &GemmParams, threads: usize) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    let t = threads.max(1).min(n);
    if t <= 1 {
        level3::dtrsm_llnn(m, n, a, b, panel, params);
        return;
    }
    let per = n.div_ceil(t);
    // gather stripes (column-major hops), solve in parallel, scatter back
    let mut stripes: Vec<(usize, usize, Vec<f64>)> = Vec::new();
    let mut j = 0;
    while j < n {
        let w = per.min(n - j);
        let mut s = vec![0.0; m * w];
        for r in 0..m {
            s[r * w..(r + 1) * w].copy_from_slice(&b[r * n + j..r * n + j + w]);
        }
        stripes.push((j, w, s));
        j += per;
    }
    let mut tasks: Vec<ScopedTask<'_>> = Vec::with_capacity(stripes.len());
    for (_, w, stripe) in stripes.iter_mut() {
        let w = *w;
        tasks.push(Box::new(move || {
            level3::dtrsm_llnn(m, w, a, stripe, panel, params);
        }));
    }
    pool::run_tasks("dtrsm/mt", tasks);
    for (j, w, stripe) in &stripes {
        for r in 0..m {
            b[r * n + j..r * n + j + w].copy_from_slice(
                &stripe[r * w..(r + 1) * w]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn row_bands_cover_and_align() {
        check("mt-bands", 50, |g| {
            let m = 1 + g.rng.below(500);
            let threads = 1 + g.rng.below(8);
            let mr = [2, 4, 8][g.rng.below(3)];
            let bands = row_bands(m, threads, mr);
            ensure(bands.len() <= threads, "too many bands")?;
            ensure(bands[0].0 == 0 && bands.last().unwrap().1 == m,
                   "bands do not cover")?;
            for w in bands.windows(2) {
                ensure(w[0].1 == w[1].0, "gap between bands")?;
            }
            for &(lo, _) in &bands {
                ensure(lo % mr == 0, "band not MR-aligned")?;
            }
            Ok(())
        });
    }

    #[test]
    fn dgemm_mt_matches_serial() {
        check("mt-gemm", 12, |g| {
            let m = g.dim(1, 100);
            let n = g.dim(1, 80);
            let k = g.dim(1, 60);
            let threads = 1 + g.rng.below(5);
            let params = GemmParams::default();
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, 0.7, &a.data, &b.data, -0.4, &mut want);
            let mut c = c0.data.clone();
            dgemm_mt(m, n, k, 0.7, &a.data, &b.data, -0.4, &mut c, &params,
                     threads);
            ensure(allclose(&c, &want, 1e-9, 1e-9),
                   format!("mt gemm wrong ({threads} threads)"))
        });
    }

    #[test]
    fn dgemm_abft_mt_clean_and_injected() {
        check("mt-gemm-ft", 10, |g| {
            let m = g.dim(8, 96);
            let n = g.dim(8, 64);
            let k = g.dim(8, 64);
            let threads = 2 + g.rng.below(3);
            let params = GemmParams { kc: 16, ..Default::default() };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut want = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_mt(m, n, k, 1.0, &a.data, &b.data, 0.0,
                                          &mut c, &params, threads, &[]);
            ensure(rep == FtReport::none(), "clean mt flagged")?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "clean mt wrong")?;
            // one strike per band-disjoint row region
            let steps = k.div_ceil(params.kc);
            let strikes: Vec<Strike> = vec![
                (g.rng.below(steps), g.rng.below(m), g.rng.below(n), 4e4),
            ];
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_mt(m, n, k, 1.0, &a.data, &b.data, 0.0,
                                          &mut c, &params, threads, &strikes);
            ensure(rep.errors_corrected == 1,
                   format!("mt inject not corrected: {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8), "mt inject wrong")
        });
    }

    #[test]
    fn dgemm_abft_mt_concurrent_strikes_all_bands() {
        // one strike per band, all corrected concurrently
        let mut rng = crate::util::rng::Rng::new(0xBEEF);
        let (m, n, k) = (128, 64, 64);
        let threads = 4;
        let params = GemmParams { kc: 32, ..Default::default() };
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let mut want = vec![0.0; m * n];
        naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
        let bands = row_bands(m, threads, params.mr);
        let strikes: Vec<Strike> = bands
            .iter()
            .map(|&(lo, hi)| (0, lo + (hi - lo) / 2, 7, 1e5))
            .collect();
        let mut c = vec![0.0; m * n];
        let rep = dgemm_abft_fused_mt(m, n, k, 1.0, &a.data, &b.data, 0.0,
                                      &mut c, &params, threads, &strikes);
        assert_eq!(rep.errors_corrected, strikes.len() as u64);
        assert!(allclose(&c, &want, 1e-8, 1e-8));
    }

    /// Requests too small for banding (m < 2·MR) fall through to the
    /// serial fused-ABFT kernel — and MUST surface that kernel's
    /// FtReport, not a default, or error counters would silently drop
    /// on small requests routed through the MT entry.
    #[test]
    fn serial_fallthrough_preserves_ft_report() {
        let mut rng = crate::util::rng::Rng::new(0x5F);
        let params = GemmParams { kc: 16, ..Default::default() };
        let (m, n, k) = (params.mr * 2 - 1, 24, 32); // below the band floor
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let mut want = vec![0.0; m * n];
        naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
        for threads in [1usize, 4] {
            let strikes: Vec<Strike> = vec![(0, m / 2, n / 3, 9e4)];
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_mt(m, n, k, 1.0, &a.data, &b.data,
                                          0.0, &mut c, &params, threads,
                                          &strikes);
            assert_eq!(rep.errors_detected, 1,
                       "t={threads}: serial fall-through dropped detection");
            assert_eq!(rep.errors_corrected, 1,
                       "t={threads}: serial fall-through dropped correction");
            assert!(allclose(&c, &want, 1e-8, 1e-8),
                    "t={threads}: fall-through result wrong");
        }
    }

    #[test]
    fn dgemm_simd_mt_matches_serial() {
        check("mt-gemm-simd", 12, |g| {
            let m = g.dim(1, 100);
            let n = g.dim(1, 80);
            let k = g.dim(1, 60);
            let threads = 1 + g.rng.below(5);
            let params = GemmParams::default();
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, 0.7, &a.data, &b.data, -0.4, &mut want);
            let mut c = c0.data.clone();
            dgemm_simd_mt(m, n, k, 0.7, &a.data, &b.data, -0.4, &mut c,
                          &params, threads);
            ensure(allclose(&c, &want, 1e-9, 1e-9),
                   format!("mt simd gemm wrong ({threads} threads)"))
        });
    }

    #[test]
    fn dgemm_abft_simd_mt_clean_and_injected() {
        check("mt-gemm-simd-ft", 10, |g| {
            let m = g.dim(16, 96);
            let n = g.dim(8, 64);
            let k = g.dim(8, 64);
            let threads = 2 + g.rng.below(3);
            let params = GemmParams { kc: 16, ..Default::default() };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let mut want = vec![0.0; m * n];
            naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_simd_mt(m, n, k, 1.0, &a.data,
                                               &b.data, 0.0, &mut c, &params,
                                               threads, &[]);
            ensure(rep == FtReport::none(), "clean simd mt flagged")?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "clean simd mt wrong")?;
            let steps = k.div_ceil(params.kc);
            let strikes: Vec<Strike> = vec![
                (g.rng.below(steps), g.rng.below(m), g.rng.below(n), 4e4),
            ];
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_simd_mt(m, n, k, 1.0, &a.data,
                                               &b.data, 0.0, &mut c, &params,
                                               threads, &strikes);
            ensure(rep.errors_corrected == 1,
                   format!("simd mt inject not corrected: {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8), "simd mt inject wrong")
        });
    }

    /// The SIMD MT entry's small-m fall-through must surface the serial
    /// fused kernel's FtReport, exactly like the scalar MT entry.
    #[test]
    fn simd_fallthrough_preserves_ft_report() {
        let mut rng = crate::util::rng::Rng::new(0x51);
        let params = GemmParams { kc: 16, ..Default::default() };
        let (m, n, k) = (simd::MR * 2 - 1, 24, 32); // below the band floor
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let mut want = vec![0.0; m * n];
        naive::dgemm(m, n, k, 1.0, &a.data, &b.data, 0.0, &mut want);
        for threads in [1usize, 4] {
            let strikes: Vec<Strike> = vec![(0, m / 2, n / 3, 9e4)];
            let mut c = vec![0.0; m * n];
            let rep = dgemm_abft_fused_simd_mt(m, n, k, 1.0, &a.data,
                                               &b.data, 0.0, &mut c, &params,
                                               threads, &strikes);
            assert_eq!(rep.errors_detected, 1,
                       "t={threads}: simd fall-through dropped detection");
            assert_eq!(rep.errors_corrected, 1,
                       "t={threads}: simd fall-through dropped correction");
            assert!(allclose(&c, &want, 1e-8, 1e-8),
                    "t={threads}: simd fall-through result wrong");
        }
    }

    #[test]
    fn dsymm_mt_matches_serial() {
        check("mt-symm", 12, |g| {
            let m = g.dim(1, 100);
            let n = g.dim(1, 80);
            let threads = 1 + g.rng.below(5);
            let params = GemmParams::default();
            let a = Matrix::random_symmetric(m, &mut g.rng);
            let b = Matrix::random(m, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let mut want = c0.data.clone();
            naive::dsymm_lower(m, n, 1.3, &a.data, &b.data, -0.6, &mut want);
            let mut c = c0.data.clone();
            dsymm_lower_mt(m, n, 1.3, &a.data, &b.data, -0.6, &mut c, &params,
                           threads);
            ensure(allclose(&c, &want, 1e-9, 1e-9),
                   format!("mt symm wrong ({threads} threads)"))
        });
    }

    #[test]
    fn dtrmm_mt_matches_serial() {
        check("mt-trmm", 12, |g| {
            let m = g.dim(1, 100);
            let n = g.dim(1, 80);
            let threads = 1 + g.rng.below(5);
            let params = GemmParams::default();
            let l = Matrix::random_lower_triangular(m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut want = b0.data.clone();
            naive::dtrmm_lower(m, n, 0.8, &l.data, &mut want);
            let mut b = b0.data.clone();
            dtrmm_lower_mt(m, n, 0.8, &l.data, &mut b, &params, threads);
            ensure(allclose(&b, &want, 1e-9, 1e-9),
                   format!("mt trmm wrong ({threads} threads)"))
        });
    }

    #[test]
    fn dtrsm_mt_matches_serial() {
        check("mt-trsm", 10, |g| {
            let m = g.dim(4, 120);
            let n = g.dim(1, 90);
            let threads = 1 + g.rng.below(5);
            let params = GemmParams::default();
            let l = Matrix::random_lower_triangular(m, &mut g.rng);
            let b0 = Matrix::random(m, n, &mut g.rng);
            let mut want = b0.data.clone();
            naive::dtrsm_llnn(m, n, &l.data, &mut want);
            let mut b = b0.data.clone();
            dtrsm_llnn_mt(m, n, &l.data, &mut b, 32, &params, threads);
            ensure(allclose(&b, &want, 1e-7, 1e-7),
                   format!("mt trsm wrong ({threads} threads)"))
        });
    }
}
