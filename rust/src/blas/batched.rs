//! Batch-fused small-GEMM execution: run a whole same-kernel batch of
//! DGEMMs in **one** call under **one** threading frame.
//!
//! The per-call MT drivers in [`crate::blas::parallel`] fork and join a
//! thread scope per request. That amortizes fine for one large GEMM,
//! but a serving batch of N *small* GEMMs pays N fork/join frames — and
//! most items are below the banding floor anyway, so the threads sit
//! idle while each item runs serially. The batched drivers here invert
//! that: every item of the batch is decomposed into MR-aligned row
//! bands by the same rule the MT kernels use (a small item is a single
//! band), the (item × band) tasks are pooled into **one** work queue,
//! and one threading frame — the cluster's persistent
//! [`crate::runtime::pool`] when installed, a scoped fork/join
//! otherwise — drains it. Worker threads pick up
//! whatever task is next, so a batch of many small items keeps every
//! thread busy without per-item fork/join, and each worker's packing
//! and checksum scratch comes from its own thread-local
//! [`crate::util::arena`] slab — steady-state batches allocate nothing
//! on the kernel hot path.
//!
//! Per-band execution reuses the serial kernels unchanged, so a batched
//! run is arithmetically identical to calling the underlying kernel per
//! item (bitwise for the scalar/SIMD paths — the property tests pin
//! this). On the fused-ABFT path every band carries band-local checksum
//! state and re-homed strikes exactly like
//! [`crate::blas::parallel::dgemm_abft_fused_mt`], and band reports are
//! merged **per item**, so each item of the batch gets its own
//! [`FtReport`] and injection-campaign accounting stays exact.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::blas::level3::{self, GemmParams};
use crate::blas::parallel::row_bands;
use crate::blas::simd;
use crate::ft::abft_fused::Strike;
use crate::ft::FtReport;
use crate::runtime::pool::{self, ScopedTask};

/// One DGEMM of a batch: `c := alpha * a * b + beta * c`, with the
/// strikes (if any) an injection campaign armed against this item.
pub struct GemmItem<'a> {
    /// Rows of `a` and `c`.
    pub m: usize,
    /// Columns of `b` and `c`.
    pub n: usize,
    /// Inner dimension.
    pub k: usize,
    /// Scale on the product.
    pub alpha: f64,
    /// Scale on the existing `c`.
    pub beta: f64,
    /// `m x k` row-major input.
    pub a: &'a [f64],
    /// `k x n` row-major input.
    pub b: &'a [f64],
    /// `m x n` row-major output, updated in place.
    pub c: &'a mut [f64],
    /// Strikes to inject into *this item* (fused-ABFT driver only; the
    /// unprotected drivers ignore it). Row/column coordinates are
    /// item-global; the driver re-homes them to the owning band.
    pub inject: Vec<Strike>,
}

/// Which serial kernel a batch's bands run on.
#[derive(Clone, Copy)]
enum Backend {
    /// Tuned scalar GEBP frame ([`level3::dgemm`]).
    Scalar,
    /// Runtime-probed SIMD frame ([`simd::dgemm`]).
    Simd,
    /// Checksum-fused SIMD frame ([`simd::dgemm_abft_fused`]).
    FusedSimd,
}

/// One unit of work: a contiguous row band of one batch item.
struct Task<'t> {
    /// Index of the owning item (band reports merge under it).
    item: usize,
    /// Rows in this band.
    rows: usize,
    n: usize,
    k: usize,
    alpha: f64,
    beta: f64,
    a: &'t [f64],
    b: &'t [f64],
    c: &'t mut [f64],
    /// Strikes owned by this band, in band-local row coordinates.
    inject: Vec<Strike>,
}

/// Decompose every item into row bands, pool the bands into one queue,
/// and drain it under a single threading frame — pool tasks when a
/// compute pool is installed, a scoped fork/join otherwise (inline when
/// the grant or the task count is 1). Returns one merged report per
/// item.
fn run_batch(items: &mut [GemmItem<'_>], params: &GemmParams,
             threads: usize, backend: Backend) -> Vec<FtReport> {
    let mr = match backend {
        Backend::Scalar => params.mr,
        Backend::Simd | Backend::FusedSimd => simd::MR,
    };
    let mut tasks: VecDeque<Task<'_>> = VecDeque::new();
    for (idx, it) in items.iter_mut().enumerate() {
        assert_eq!(it.a.len(), it.m * it.k, "item {idx}: bad A shape");
        assert_eq!(it.b.len(), it.k * it.n, "item {idx}: bad B shape");
        assert_eq!(it.c.len(), it.m * it.n, "item {idx}: bad C shape");
        if it.m == 0 || it.n == 0 {
            continue; // nothing to compute or scale
        }
        // same banding rule (and small-m floor) as the per-call MT
        // drivers, so banded batched execution matches them band-for-band
        let bands = if threads <= 1 || it.m < 2 * mr {
            vec![(0, it.m)]
        } else {
            row_bands(it.m, threads, mr)
        };
        let mut rest: &mut [f64] = it.c;
        for &(lo, hi) in &bands {
            let (band, tail) = rest.split_at_mut((hi - lo) * it.n);
            rest = tail;
            // re-home strikes into band-local row coordinates
            let inject: Vec<Strike> = it
                .inject
                .iter()
                .filter(|&&(_, i, _, _)| i >= lo && i < hi)
                .map(|&(st, i, j, d)| (st, i - lo, j, d))
                .collect();
            tasks.push_back(Task {
                item: idx,
                rows: hi - lo,
                n: it.n,
                k: it.k,
                alpha: it.alpha,
                beta: it.beta,
                a: &it.a[lo * it.k..hi * it.k],
                b: it.b,
                c: band,
                inject,
            });
        }
    }
    let reports: Vec<Mutex<FtReport>> =
        (0..items.len()).map(|_| Mutex::new(FtReport::none())).collect();
    let run = |t: Task<'_>| -> FtReport {
        match backend {
            Backend::Scalar => {
                level3::dgemm(t.rows, t.n, t.k, t.alpha, t.a, t.b, t.beta,
                              t.c, params);
                FtReport::none()
            }
            Backend::Simd => {
                simd::dgemm(t.rows, t.n, t.k, t.alpha, t.a, t.b, t.beta,
                            t.c, params);
                FtReport::none()
            }
            Backend::FusedSimd => {
                simd::dgemm_abft_fused(t.rows, t.n, t.k, t.alpha, t.a, t.b,
                                       t.beta, t.c, params, &t.inject)
            }
        }
    };
    let workers = threads.max(1).min(tasks.len().max(1));
    if workers <= 1 {
        // serial drain: no threading frame at all
        for t in tasks {
            let item = t.item;
            let rep = run(t);
            reports[item].lock().unwrap().merge(rep);
        }
    } else {
        // ONE threading frame for the whole batch: workers pull from the
        // shared queue until it runs dry
        let queue = Mutex::new(tasks);
        let drainers: Vec<ScopedTask<'_>> = (0..workers)
            .map(|_| {
                let queue = &queue;
                let reports = &reports;
                let run = &run;
                Box::new(move || loop {
                    // take the lock only for the pop, never across a task
                    let next = queue.lock().unwrap().pop_front();
                    let Some(t) = next else { break };
                    let item = t.item;
                    let rep = run(t);
                    reports[item].lock().unwrap().merge(rep);
                }) as ScopedTask<'_>
            })
            .collect();
        pool::run_tasks("dgemm/batched", drainers);
    }
    reports.into_iter().map(|m| m.into_inner().unwrap()).collect()
}

/// Run a batch of DGEMMs on the tuned scalar frame. Bitwise identical
/// to calling [`level3::dgemm`] once per item, at any thread grant.
pub fn dgemm_batched(items: &mut [GemmItem<'_>], params: &GemmParams,
                     threads: usize) {
    run_batch(items, params, threads, Backend::Scalar);
}

/// Run a batch of DGEMMs on the runtime-probed SIMD frame. Bitwise
/// identical to calling [`simd::dgemm`] once per item, at any grant.
pub fn dgemm_batched_simd(items: &mut [GemmItem<'_>], params: &GemmParams,
                          threads: usize) {
    run_batch(items, params, threads, Backend::Simd);
}

/// Run a batch of DGEMMs on the checksum-fused SIMD frame, injecting
/// each item's strikes into the band that owns the struck row. Returns
/// one [`FtReport`] per item (index-aligned with `items`), so the
/// server can account detections and corrections per request.
pub fn dgemm_batched_abft_fused_simd(items: &mut [GemmItem<'_>],
                                     params: &GemmParams, threads: usize)
                                     -> Vec<FtReport> {
    run_batch(items, params, threads, Backend::FusedSimd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::matrix::{allclose, Matrix};
    use crate::util::rng::Rng;

    /// A reproducible mixed-shape batch: returns (items' inputs, fresh
    /// outputs) for `count` items whose dims straddle the banding floor.
    fn mixed_batch(rng: &mut Rng, count: usize)
                   -> Vec<(usize, usize, usize, f64, f64, Vec<f64>,
                           Vec<f64>, Vec<f64>)> {
        (0..count)
            .map(|i| {
                let m = 3 + rng.below(40);
                let n = 2 + rng.below(24);
                let k = 1 + rng.below(32);
                let alpha = [1.0, 0.7, -1.2][i % 3];
                let beta = [0.0, 1.0, -0.4][(i + 1) % 3];
                let a = Matrix::random(m, k, rng).data;
                let b = Matrix::random(k, n, rng).data;
                let c = Matrix::random(m, n, rng).data;
                (m, n, k, alpha, beta, a, b, c)
            })
            .collect()
    }

    #[test]
    fn batched_scalar_is_bitwise_sequential() {
        let mut rng = Rng::new(0xBA7C);
        let params = GemmParams::default();
        let specs = mixed_batch(&mut rng, 7);
        for threads in [1usize, 4] {
            let mut want: Vec<Vec<f64>> = Vec::new();
            for (m, n, k, alpha, beta, a, b, c0) in &specs {
                let mut c = c0.clone();
                level3::dgemm(*m, *n, *k, *alpha, a, b, *beta, &mut c,
                              &params);
                want.push(c);
            }
            let mut outs: Vec<Vec<f64>> =
                specs.iter().map(|s| s.7.clone()).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(outs.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: s.3, beta: s.4,
                    a: &s.5[..], b: &s.6[..], c: &mut c[..],
                    inject: Vec::new(),
                })
                .collect();
            dgemm_batched(&mut items, &params, threads);
            drop(items);
            for (got, want) in outs.iter().zip(&want) {
                assert_eq!(got, want,
                           "t={threads}: batched scalar diverged bitwise");
            }
        }
    }

    #[test]
    fn batched_simd_is_bitwise_sequential() {
        let mut rng = Rng::new(0x51BD);
        let params = GemmParams::default();
        let specs = mixed_batch(&mut rng, 6);
        for threads in [1usize, 3] {
            let mut want: Vec<Vec<f64>> = Vec::new();
            for (m, n, k, alpha, beta, a, b, c0) in &specs {
                let mut c = c0.clone();
                simd::dgemm(*m, *n, *k, *alpha, a, b, *beta, &mut c,
                            &params);
                want.push(c);
            }
            let mut outs: Vec<Vec<f64>> =
                specs.iter().map(|s| s.7.clone()).collect();
            let mut items: Vec<GemmItem<'_>> = specs
                .iter()
                .zip(outs.iter_mut())
                .map(|(s, c)| GemmItem {
                    m: s.0, n: s.1, k: s.2, alpha: s.3, beta: s.4,
                    a: &s.5[..], b: &s.6[..], c: &mut c[..],
                    inject: Vec::new(),
                })
                .collect();
            dgemm_batched_simd(&mut items, &params, threads);
            drop(items);
            for (got, want) in outs.iter().zip(&want) {
                assert_eq!(got, want,
                           "t={threads}: batched simd diverged bitwise");
            }
        }
    }

    #[test]
    fn fused_batch_reports_per_item_and_corrects() {
        let mut rng = Rng::new(0xF7);
        let params = GemmParams { kc: 16, ..Default::default() };
        let dims = [(24usize, 16usize, 32usize), (9, 12, 16), (40, 8, 32)];
        let mats: Vec<(Vec<f64>, Vec<f64>)> = dims
            .iter()
            .map(|&(m, n, k)| (Matrix::random(m, k, &mut rng).data,
                               Matrix::random(k, n, &mut rng).data))
            .collect();
        let want: Vec<Vec<f64>> = dims
            .iter()
            .zip(&mats)
            .map(|(&(m, n, k), (a, b))| {
                let mut c = vec![0.0; m * n];
                naive::dgemm(m, n, k, 1.0, a, b, 0.0, &mut c);
                c
            })
            .collect();
        for threads in [1usize, 4] {
            let mut outs: Vec<Vec<f64>> =
                dims.iter().map(|&(m, n, _)| vec![0.0; m * n]).collect();
            let mut items: Vec<GemmItem<'_>> = dims
                .iter()
                .zip(&mats)
                .zip(outs.iter_mut())
                .enumerate()
                .map(|(i, ((&(m, n, k), (a, b)), c))| GemmItem {
                    m, n, k, alpha: 1.0, beta: 0.0,
                    a: &a[..], b: &b[..], c: &mut c[..],
                    // strike items 0 and 2; item 1 stays clean
                    inject: if i != 1 {
                        vec![(0, m / 2, n / 3, 5e4)]
                    } else {
                        Vec::new()
                    },
                })
                .collect();
            let reps =
                dgemm_batched_abft_fused_simd(&mut items, &params, threads);
            drop(items);
            assert_eq!(reps.len(), 3);
            for (i, rep) in reps.iter().enumerate() {
                let hit = i != 1;
                assert_eq!(rep.errors_detected, hit as u64,
                           "t={threads} item {i}: wrong detection count");
                assert_eq!(rep.errors_corrected, hit as u64,
                           "t={threads} item {i}: wrong correction count");
            }
            for (i, (got, want)) in outs.iter().zip(&want).enumerate() {
                assert!(allclose(got, want, 1e-8, 1e-8),
                        "t={threads} item {i}: corrected result wrong");
            }
        }
    }

    /// Arena reuse across a batch of *differing* dims must be invisible:
    /// re-running the same batch — and interleaving a large item before
    /// a small one — always reproduces the standalone per-item result
    /// bitwise. This is the arena-determinism acceptance property.
    #[test]
    fn arena_reuse_across_differing_dims_is_deterministic() {
        let mut rng = Rng::new(0xA2E);
        let params = GemmParams::default();
        let (big_m, small_m, n, k) = (96usize, 5usize, 18usize, 24usize);
        let ba = Matrix::random(big_m, k, &mut rng).data;
        let sa = Matrix::random(small_m, k, &mut rng).data;
        let b = Matrix::random(k, n, &mut rng).data;
        // standalone small-item result, computed before any big lease
        let mut standalone = vec![0.0; small_m * n];
        simd::dgemm(small_m, n, k, 1.0, &sa, &b, 0.0, &mut standalone,
                    &params);
        let run_once = |ba: &[f64], sa: &[f64], b: &[f64]| {
            let mut big_c = vec![0.0; big_m * n];
            let mut small_c = vec![0.0; small_m * n];
            let mut items = vec![
                GemmItem { m: big_m, n, k, alpha: 1.0, beta: 0.0,
                           a: ba, b, c: &mut big_c[..],
                           inject: Vec::new() },
                GemmItem { m: small_m, n, k, alpha: 1.0, beta: 0.0,
                           a: sa, b, c: &mut small_c[..],
                           inject: Vec::new() },
            ];
            dgemm_batched_simd(&mut items, &params, 1);
            drop(items);
            (big_c, small_c)
        };
        let first = run_once(&ba, &sa, &b);
        let second = run_once(&ba, &sa, &b);
        assert_eq!(first, second, "batch re-run diverged (arena leak)");
        assert_eq!(first.1, standalone,
                   "small item after a big lease diverged from standalone");
    }

    /// After one warm-up batch, running more batches of the same (or
    /// smaller) shapes must not grow the arena slab: the steady-state
    /// hot path is allocation-free. Runs on a dedicated thread so other
    /// tests' leases can't skew the thread-local counters.
    #[test]
    fn steady_state_batches_do_not_grow_the_arena() {
        std::thread::spawn(|| {
            let mut rng = Rng::new(0x57D);
            let params = GemmParams::default();
            let (m, n, k) = (12usize, 10usize, 14usize);
            let a = Matrix::random(m, k, &mut rng).data;
            let b = Matrix::random(k, n, &mut rng).data;
            let warm = |a: &[f64], b: &[f64]| {
                let mut c = vec![0.0; m * n];
                let mut items = vec![GemmItem {
                    m, n, k, alpha: 1.0, beta: 0.0, a, b, c: &mut c[..],
                    inject: Vec::new(),
                }];
                dgemm_batched_simd(&mut items, &params, 1);
            };
            warm(&a, &b);
            let (_, grows, _) = crate::util::arena::thread_stats();
            for _ in 0..10 {
                warm(&a, &b);
            }
            let (_, grows_after, _) = crate::util::arena::thread_stats();
            assert_eq!(grows, grows_after,
                       "steady-state batches reallocated packing scratch");
        })
        .join()
        .unwrap();
    }

    #[test]
    fn degenerate_items_are_skipped_cleanly() {
        let params = GemmParams::default();
        let a: Vec<f64> = Vec::new();
        let b: Vec<f64> = Vec::new();
        let mut c: Vec<f64> = Vec::new();
        let mut items = vec![GemmItem {
            m: 0, n: 0, k: 4, alpha: 1.0, beta: 0.0,
            a: &a[..], b: &b[..], c: &mut c[..], inject: Vec::new(),
        }];
        let reps = dgemm_batched_abft_fused_simd(&mut items, &params, 4);
        assert_eq!(reps, vec![FtReport::none()]);
        let empty: &mut [GemmItem<'_>] = &mut [];
        assert!(dgemm_batched_abft_fused_simd(empty, &params, 2).is_empty());
    }
}
