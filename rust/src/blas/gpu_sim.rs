//! Simulated GPU executor: warp-tiled fused-ABFT DGEMM tiers.
//!
//! "Anatomy of High-Performance GEMM with Online Fault Tolerance on
//! GPUs" (arXiv 2305.01024) fuses ABFT checksum maintenance into the
//! GPU GEMM hierarchy: each thread-block tile of C carries its own
//! encoded row/column checksums, updated per rank-k ("warp MMA") step
//! from the A/B fragments the tile already loads, so detection,
//! location, and correction all happen tile-locally with no global
//! reduction. This module emulates that execution shape on the host —
//! a grid of `tile × tile` C blocks, each advancing through rank-`tile`
//! steps with per-step 2D checksum verification — so the coordinator
//! can register GPU-style executor descriptors (a heterogeneous
//! backend tier) and drive them through the same planner, fault
//! campaigns, and soak gates as the native kernels.
//!
//! The error model matches the rest of the repo (paper §2.1): a strike
//! perturbs one computed element during one rank step, before the
//! step's reference checksums are read. Because every (block tile ×
//! rank step) pair is an independent verification interval, the
//! simulated GPU frame tolerates one strike per tile per step —
//! strictly finer-grained than the serial fused kernel's one strike
//! per rank step.

use crate::ft::abft::round_off_threshold;
use crate::ft::abft_fused::Strike;
use crate::ft::FtReport;

/// Compute C ← α·A·B + β·C through the simulated warp-tiled fused-ABFT
/// frame. `tile` is the thread-block tile edge (the WMMA fragment
/// multiple); `strikes` follow the repo-wide `(rank step, global row,
/// global col, delta)` injection model with rank steps of width `tile`.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_gpusim_abft(m: usize, n: usize, k: usize, alpha: f64,
                         a: &[f64], b: &[f64], beta: f64, c: &mut [f64],
                         tile: usize, strikes: &[Strike]) -> FtReport {
    let tile = tile.max(1);
    let mut report = FtReport::none();
    if m == 0 || n == 0 {
        return report;
    }
    // β-scaling pass (the GPU kernel's epilogue runs it first here so
    // every rank step accumulates into the final C block directly)
    if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    let nsteps = k.div_ceil(tile).max(1);
    // grid loop: one iteration per thread-block tile of C
    let mut i0 = 0;
    while i0 < m {
        let mb = tile.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nb = tile.min(n - j0);
            report.merge(block_tile(m, n, k, alpha, a, b, c, tile, strikes,
                                    i0, mb, j0, nb, nsteps));
            j0 += tile;
        }
        i0 += tile;
    }
    report
}

/// One thread-block tile: advance through the rank-k steps, verifying
/// the step's fragment against its encoded 2D checksums before
/// accumulating it into C.
#[allow(clippy::too_many_arguments)]
fn block_tile(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
              b: &[f64], c: &mut [f64], tile: usize, strikes: &[Strike],
              i0: usize, mb: usize, j0: usize, nb: usize, nsteps: usize)
              -> FtReport {
    let mut report = FtReport::none();
    let mut frag = vec![0.0; mb * nb];
    let mut eta = vec![0.0; tile]; // eᵀ·A fragment (column sums of A)
    let mut brow = vec![0.0; tile]; // B fragment row sums (B·e)
    for (step, p0) in (0..k).step_by(tile).enumerate() {
        let kb = tile.min(k - p0);
        // load the A fragment's column sums and row-checksum seeds —
        // on the GPU these ride the shared-memory staging loads
        let mut max_a = 0.0f64;
        for (p, ep) in eta.iter_mut().enumerate().take(kb) {
            let mut s = 0.0;
            for r in 0..mb {
                let v = a[(i0 + r) * k + p0 + p];
                max_a = max_a.max(v.abs());
                s += v;
            }
            *ep = s;
        }
        let mut max_b = 0.0f64;
        for (p, bp) in brow.iter_mut().enumerate().take(kb) {
            let mut s = 0.0;
            for cx in 0..nb {
                let v = b[(p0 + p) * n + j0 + cx];
                max_b = max_b.max(v.abs());
                s += v;
            }
            *bp = s;
        }
        // encoded checksums for this step's fragment, derived from A/B
        // (a strike on the compute cannot touch these)
        let mut ecc = vec![0.0; nb]; // α·(eᵀA)·B
        for p in 0..kb {
            let ep = alpha * eta[p];
            for (cx, e) in ecc.iter_mut().enumerate() {
                *e += ep * b[(p0 + p) * n + j0 + cx];
            }
        }
        let mut erc = vec![0.0; mb]; // α·A·(B·e)
        for (r, e) in erc.iter_mut().enumerate() {
            let mut s = 0.0;
            for p in 0..kb {
                s += a[(i0 + r) * k + p0 + p] * brow[p];
            }
            *e = alpha * s;
        }
        // the warp MMA loop: compute the step fragment
        for (r, row) in frag.chunks_mut(nb).enumerate().take(mb) {
            for (cx, o) in row.iter_mut().enumerate() {
                let mut s = 0.0;
                for p in 0..kb {
                    s += a[(i0 + r) * k + p0 + p] * b[(p0 + p) * n + j0 + cx];
                }
                *o = alpha * s;
            }
        }
        // strikes for this (tile, step) interval land on the computed
        // fragment — before the reference checksums read it
        for &(fs, fi, fj, delta) in strikes {
            if fs == step % nsteps
                && (i0..i0 + mb).contains(&fi)
                && (j0..j0 + nb).contains(&fj)
            {
                frag[(fi - i0) * nb + (fj - j0)] += delta;
            }
        }
        // verify: reference sums of the computed fragment vs encoded
        let tol = round_off_threshold(
            alpha.abs().max(1.0) * max_a * max_b, kb, nb.max(mb));
        // one correction round per struck column: the single-error-per-
        // interval model holds per (column × tile × step), so distinct
        // struck columns in one fragment are each located and repaired
        for cx in 0..nb {
            let mut s = 0.0;
            for r in 0..mb {
                s += frag[r * nb + cx];
            }
            let delta = s - ecc[cx];
            if delta.abs() <= tol {
                continue;
            }
            report.errors_detected += 1;
            // locate the row whose row-checksum miss decodes to this
            // column's magnitude (pairs rows to columns correctly even
            // with several struck columns in one fragment)
            let mut bad_row = 0;
            let mut best = f64::INFINITY;
            for (r, e) in erc.iter().enumerate() {
                let mut rs = 0.0;
                for v in &frag[r * nb..(r + 1) * nb] {
                    rs += v;
                }
                let score = (rs - e - delta).abs();
                if score < best {
                    best = score;
                    bad_row = r;
                }
            }
            frag[bad_row * nb + cx] -= delta;
            report.errors_corrected += 1;
        }
        // epilogue: accumulate the verified fragment into C
        for r in 0..mb {
            let row = &frag[r * nb..(r + 1) * nb];
            let out = &mut c[(i0 + r) * n + j0..(i0 + r) * n + j0 + nb];
            for (o, v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
    }
    report
}

/// Unprotected tier of the simulated GPU executor: the same grid /
/// block-tile / rank-step execution shape with the checksum stream
/// compiled out (the "Ori" kernel of arXiv 2305.01024's comparison).
pub fn dgemm_gpusim(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                    b: &[f64], beta: f64, c: &mut [f64], tile: usize) {
    let tile = tile.max(1);
    if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    let mut i0 = 0;
    while i0 < m {
        let mb = tile.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nb = tile.min(n - j0);
            let mut p0 = 0;
            while p0 < k {
                let kb = tile.min(k - p0);
                for r in 0..mb {
                    for cx in 0..nb {
                        let mut s = 0.0;
                        for p in 0..kb {
                            s += a[(i0 + r) * k + p0 + p]
                                * b[(p0 + p) * n + j0 + cx];
                        }
                        c[(i0 + r) * n + j0 + cx] += alpha * s;
                    }
                }
                p0 += tile;
            }
            j0 += tile;
        }
        i0 += tile;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::matrix::{allclose, Matrix};
    use crate::util::rng::Rng;

    fn case(m: usize, n: usize, k: usize, alpha: f64, beta: f64, seed: u64)
            -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Matrix::random(m, k, &mut rng).data;
        let b = Matrix::random(k, n, &mut rng).data;
        let c = Matrix::random(m, n, &mut rng).data;
        let mut want = c.clone();
        naive::dgemm(m, n, k, alpha, &a, &b, beta, &mut want);
        (a, b, c, want)
    }

    #[test]
    fn clean_runs_match_naive_for_both_tiers() {
        for (m, n, k) in [(5, 7, 9), (16, 16, 16), (33, 20, 41)] {
            for tile in [4, 16, 32] {
                let (a, b, c0, want) = case(m, n, k, 1.25, 0.5, 7);
                let mut c = c0.clone();
                let ft = dgemm_gpusim_abft(m, n, k, 1.25, &a, &b, 0.5,
                                           &mut c, tile, &[]);
                assert_eq!(ft, FtReport::none(), "tile {tile}: dirty report");
                assert!(allclose(&c, &want, 1e-9, 1e-9), "tile {tile}");
                let mut c = c0.clone();
                dgemm_gpusim(m, n, k, 1.25, &a, &b, 0.5, &mut c, tile);
                assert!(allclose(&c, &want, 1e-9, 1e-9), "ori tile {tile}");
            }
        }
    }

    #[test]
    fn strikes_are_detected_located_and_corrected() {
        let (m, n, k) = (24, 18, 40);
        let (a, b, c0, want) = case(m, n, k, 1.0, 1.0, 11);
        for tile in [8, 16] {
            let strikes: &[Strike] = &[(1, 3, 5, 3e4), (0, 20, 17, -2e4)];
            let mut c = c0.clone();
            let ft = dgemm_gpusim_abft(m, n, k, 1.0, &a, &b, 1.0, &mut c,
                                       tile, strikes);
            assert_eq!(ft.errors_detected, 2, "tile {tile}");
            assert_eq!(ft.errors_corrected, 2, "tile {tile}");
            assert!(allclose(&c, &want, 1e-8, 1e-8),
                    "tile {tile}: correction left residue");
        }
    }
}
