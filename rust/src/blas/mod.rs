//! The pure-Rust BLAS substrate (paper §3).
//!
//! Three implementations of every routine, standing in for the paper's
//! comparison libraries (DESIGN.md substitution #2):
//!
//! | variant   | stands in for       | character                           |
//! |-----------|---------------------|-------------------------------------|
//! | [`naive`] | LAPACK reference    | textbook triple loops               |
//! | [`blocked`]| OpenBLAS / BLIS    | cache-blocked, but with the exact under-optimizations the paper calls out (TRSV B=64, scalar TRSM diagonal solver, no prefetch in SCAL) |
//! | [`level1`]/[`level2`]/[`level3`] | FT-BLAS "Ori" | the tuned kernels: chunked+unrolled L1, register-reuse GEMV (R_i=4), B=4 TRSV, packed GEMM with an unrolled micro kernel, reciprocal-diagonal TRSM |
//! | [`simd`]  | FT-BLAS (AVX)       | explicit `std::arch` AVX2+FMA microkernels (8×4 GEBP dgemm, wide-lane L1) behind a runtime CPU probe; tuned-scalar fallback off-AVX2 |
//!
//! [`stepwise`] holds the Fig. 7 DSCAL optimization ladder (six steps,
//! FT and non-FT at each step). [`batched`] executes a whole
//! same-kernel batch of small DGEMMs under one threading frame — the
//! serving fast path for the small-GEMM workload.
//!
//! All matrices are dense row-major `&[f64]` with explicit dimensions;
//! triangular routines read the lower triangle (the paper restricts its
//! presentation to the same case).

pub mod batched;
pub mod blocked;
pub mod gpu_sim;
pub mod level1;
pub mod level2;
pub mod level3;
pub mod naive;
pub mod parallel;
pub mod simd;
pub mod stepwise;

/// Which implementation variant to dispatch to (coordinator backends and
/// bench baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Impl {
    /// Textbook loops — LAPACK-reference stand-in.
    Naive,
    /// Cache-blocked with the paper's called-out under-optimizations —
    /// OpenBLAS/BLIS stand-in.
    Blocked,
    /// The tuned FT-BLAS kernels.
    Tuned,
    /// The explicit AVX2+FMA microkernels of [`simd`], runtime-probed
    /// with a tuned-scalar fallback — the top rung of the variant
    /// ladder.
    Simd,
}

impl Impl {
    /// Every variant, in bench/report (= ladder) order:
    /// naive → blocked → tuned → simd.
    pub const ALL: [Impl; 4] =
        [Impl::Naive, Impl::Blocked, Impl::Tuned, Impl::Simd];

    /// CLI/report name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            Impl::Naive => "naive",
            Impl::Blocked => "blocked",
            Impl::Tuned => "tuned",
            Impl::Simd => "simd",
        }
    }

    /// Parse a variant name — the symmetric counterpart of
    /// `Backend::by_name` and `FtPolicy::by_name`, used by the CLI and
    /// bench harness argument paths.
    pub fn by_name(s: &str) -> Option<Impl> {
        match s {
            "naive" => Some(Impl::Naive),
            "blocked" => Some(Impl::Blocked),
            "tuned" => Some(Impl::Tuned),
            "simd" => Some(Impl::Simd),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn impl_names_roundtrip() {
        for v in Impl::ALL {
            assert_eq!(Impl::by_name(v.name()), Some(v));
        }
        assert!(Impl::by_name("pjrt").is_none());
    }
}
