//! Tuned Level-1 kernels (paper §3.1): the FT-BLAS "Ori" implementations.
//!
//! The AVX-512 adaptation in safe Rust: fixed-size chunks of `LANES`
//! doubles stand in for a 512-bit register (the compiler auto-vectorizes
//! the chunk bodies), 4-way unrolling matches the paper's unroll factor,
//! and `prefetch` issues `prefetcht0`-equivalent hints a fixed distance
//! ahead (the paper's 1024-bit distance, §4.4.4).

/// SIMD register width the paper targets: 8 doubles per AVX-512 register.
pub const LANES: usize = 8;
/// Unroll factor (paper: 4).
pub const UNROLL: usize = 4;
/// Prefetch distance in elements (paper: 128 doubles ahead).
pub const PREFETCH_DIST: usize = 128;

#[inline(always)]
pub(crate) fn prefetch(ptr: *const f64) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(ptr as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = ptr;
    }
}

const STEP: usize = LANES * UNROLL;

/// x := alpha * x — unrolled, vector-width chunks, prefetched.
///
/// `chunks_exact_mut` gives LLVM bound-check-free bodies it vectorizes to
/// the full SIMD width (the paper's vmulpd loop); the prefetch hint is
/// issued once per STEP, a fixed distance ahead (out-of-range prefetch
/// addresses are harmless — `wrapping_add` keeps the pointer math defined).
pub fn dscal(alpha: f64, x: &mut [f64]) {
    let mut chunks = x.chunks_exact_mut(STEP);
    for chunk in &mut chunks {
        // prefetch half the loads (paper: avoid fighting the HW prefetcher)
        prefetch(chunk.as_ptr().wrapping_add(PREFETCH_DIST));
        for v in chunk.iter_mut() {
            *v *= alpha;
        }
    }
    for v in chunks.into_remainder() {
        *v *= alpha;
    }
}

/// y := alpha * x + y
pub fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let mut ychunks = y.chunks_exact_mut(STEP);
    let mut xchunks = x.chunks_exact(STEP);
    for (yc, xc) in (&mut ychunks).zip(&mut xchunks) {
        prefetch(xc.as_ptr().wrapping_add(PREFETCH_DIST));
        prefetch(yc.as_ptr().wrapping_add(PREFETCH_DIST));
        for (yi, xi) in yc.iter_mut().zip(xc) {
            *yi += alpha * xi;
        }
    }
    for (yi, xi) in ychunks.into_remainder().iter_mut()
        .zip(xchunks.remainder())
    {
        *yi += alpha * xi;
    }
}

/// dot(x, y) with 4 independent accumulator chains (ILP, paper's VFMA
/// latency hiding).
pub fn ddot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [[0.0f64; LANES]; UNROLL];
    let mut xchunks = x.chunks_exact(STEP);
    let mut ychunks = y.chunks_exact(STEP);
    for (xc, yc) in (&mut xchunks).zip(&mut ychunks) {
        prefetch(xc.as_ptr().wrapping_add(PREFETCH_DIST));
        prefetch(yc.as_ptr().wrapping_add(PREFETCH_DIST));
        for (u, accu) in acc.iter_mut().enumerate() {
            let xs = &xc[u * LANES..(u + 1) * LANES];
            let ys = &yc[u * LANES..(u + 1) * LANES];
            for (a, (xi, yi)) in accu.iter_mut().zip(xs.iter().zip(ys)) {
                *a += xi * yi;
            }
        }
    }
    let mut total: f64 = acc.iter().flatten().sum();
    for (xi, yi) in xchunks.remainder().iter().zip(ychunks.remainder()) {
        total += xi * yi;
    }
    total
}

/// ||x||_2, AVX-512-width sum of squares + scaling guard.
///
/// (The paper's upgrade of OpenBLAS's SSE2 DNRM2 to AVX-512 — Table 1's
/// under-optimization it fixes.)
pub fn dnrm2(x: &[f64]) -> f64 {
    let mut acc = [[0.0f64; LANES]; UNROLL];
    let mut chunks = x.chunks_exact(STEP);
    for xc in &mut chunks {
        prefetch(xc.as_ptr().wrapping_add(PREFETCH_DIST));
        for (u, accu) in acc.iter_mut().enumerate() {
            let xs = &xc[u * LANES..(u + 1) * LANES];
            for (a, v) in accu.iter_mut().zip(xs) {
                *a += v * v;
            }
        }
    }
    let mut ssq: f64 = acc.iter().flatten().sum();
    for v in chunks.remainder() {
        ssq += v * v;
    }
    if ssq.is_finite() && ssq > f64::MIN_POSITIVE {
        ssq.sqrt()
    } else {
        // fall back to the scaled path on overflow/underflow/zero
        crate::blas::naive::dnrm2(x)
    }
}

/// sum |x_i|
pub fn dasum(x: &[f64]) -> f64 {
    let mut acc = [[0.0f64; LANES]; UNROLL];
    let mut chunks = x.chunks_exact(STEP);
    for xc in &mut chunks {
        for (u, accu) in acc.iter_mut().enumerate() {
            let xs = &xc[u * LANES..(u + 1) * LANES];
            for (a, v) in accu.iter_mut().zip(xs) {
                *a += v.abs();
            }
        }
    }
    let mut total: f64 = acc.iter().flatten().sum();
    for v in chunks.remainder() {
        total += v.abs();
    }
    total
}

/// y := x (chunked copy; the libc memcpy path is what OpenBLAS uses too).
pub fn dcopy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// swap x, y
pub fn dswap(x: &mut [f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        std::mem::swap(a, b);
    }
}

/// Givens rotation, unrolled chunks.
pub fn drot(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        for l in 0..LANES {
            let (xa, yb) = (x[i + l], y[i + l]);
            x[i + l] = c * xa + s * yb;
            y[i + l] = c * yb - s * xa;
        }
        i += LANES;
    }
    for l in main..n {
        let (xa, yb) = (x[l], y[l]);
        x[l] = c * xa + s * yb;
        y[l] = c * yb - s * xa;
    }
}

/// Modified Givens rotation (Table 1 routine), unrolled chunks with the
/// flag dispatched once outside the loop.
pub fn drotm(x: &mut [f64], y: &mut [f64], param: &[f64; 5]) {
    assert_eq!(x.len(), y.len());
    let flag = param[0];
    let (h11, h21, h12, h22) = match flag {
        f if f == -2.0 => return,
        f if f == -1.0 => (param[1], param[2], param[3], param[4]),
        f if f == 0.0 => (1.0, param[2], param[3], 1.0),
        _ => (param[1], -1.0, 1.0, param[4]),
    };
    let n = x.len();
    let main = n - n % LANES;
    let mut i = 0;
    while i < main {
        prefetch(unsafe { x.as_ptr().add((i + PREFETCH_DIST).min(n - 1)) });
        for l in 0..LANES {
            let (xa, yb) = (x[i + l], y[i + l]);
            x[i + l] = h11 * xa + h12 * yb;
            y[i + l] = h21 * xa + h22 * yb;
        }
        i += LANES;
    }
    for l in main..n {
        let (xa, yb) = (x[l], y[l]);
        x[l] = h11 * xa + h12 * yb;
        y[l] = h21 * xa + h22 * yb;
    }
}

/// IDAMAX with chunked scanning: per-lane running maxima and positions,
/// reduced once at the end (the vectorized-compare pattern; reference
/// BLAS scans scalar).
pub fn idamax(x: &[f64]) -> usize {
    let n = x.len();
    if n == 0 {
        return 0;
    }
    let main = n - n % LANES;
    let mut bv = [0.0f64; LANES];
    let mut bi = [0usize; LANES];
    let mut i = 0;
    while i < main {
        for l in 0..LANES {
            let v = x[i + l].abs();
            // strict > keeps the first occurrence per lane
            if v > bv[l] {
                bv[l] = v;
                bi[l] = i + l;
            }
        }
        i += LANES;
    }
    let mut best = 0usize;
    let mut bval = 0.0f64;
    for l in 0..LANES {
        // lane order is index order for ties within a chunk; across
        // chunks the earlier index wins on strict inequality only
        if bv[l] > bval || (bv[l] == bval && bv[l] > 0.0 && bi[l] < best) {
            bval = bv[l];
            best = bi[l];
        }
    }
    for l in main..n {
        if x[l].abs() > bval {
            bval = x[l].abs();
            best = l;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure, ensure_close};
    use crate::util::matrix::allclose;

    #[test]
    fn dscal_matches_naive_all_remainders() {
        // exercise every remainder class around the unroll step
        check("dscal-remainders", 60, |g| {
            let n = g.dim(1, 200);
            let alpha = g.rng.range(-3.0, 3.0);
            let x0 = g.rng.normal_vec(n);
            let mut a = x0.clone();
            let mut b = x0;
            dscal(alpha, &mut a);
            naive::dscal(alpha, &mut b);
            ensure(a == b, "tuned dscal != naive")
        });
    }

    #[test]
    fn daxpy_matches_naive() {
        check("daxpy", 40, |g| {
            let n = g.dim(1, 300);
            let alpha = g.rng.range(-2.0, 2.0);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let mut a = y0.clone();
            let mut b = y0;
            daxpy(alpha, &x, &mut a);
            naive::daxpy(alpha, &x, &mut b);
            ensure(a == b, "tuned daxpy != naive")
        });
    }

    #[test]
    fn ddot_matches_naive() {
        check("ddot", 40, |g| {
            let n = g.dim(1, 500);
            let x = g.rng.normal_vec(n);
            let y = g.rng.normal_vec(n);
            ensure_close(ddot(&x, &y), naive::ddot(&x, &y), 1e-12, "ddot")
        });
    }

    #[test]
    fn dnrm2_matches_naive() {
        check("dnrm2", 40, |g| {
            let n = g.dim(1, 500);
            let x = g.rng.normal_vec(n);
            ensure_close(dnrm2(&x), naive::dnrm2(&x), 1e-12, "dnrm2")
        });
    }

    #[test]
    fn dnrm2_overflow_falls_back() {
        let x = vec![1e300, 1e300];
        let expect = 1e300 * 2.0f64.sqrt();
        assert!((dnrm2(&x) - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn dasum_matches_naive() {
        check("dasum", 30, |g| {
            let n = g.dim(1, 500);
            let x = g.rng.normal_vec(n);
            ensure_close(dasum(&x), naive::dasum(&x), 1e-12, "dasum")
        });
    }

    #[test]
    fn drotm_matches_naive_all_flags() {
        check("drotm", 40, |g| {
            let n = g.dim(1, 300);
            let flag = [-2.0, -1.0, 0.0, 1.0][g.rng.below(4)];
            let param = [flag, g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0),
                         g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0)];
            let x0 = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let (mut x1, mut y1) = (x0.clone(), y0.clone());
            let (mut x2, mut y2) = (x0, y0);
            drotm(&mut x1, &mut y1, &param);
            naive::drotm(&mut x2, &mut y2, &param);
            ensure(x1 == x2 && y1 == y2,
                   format!("tuned drotm != naive (flag {flag})"))
        });
    }

    #[test]
    fn idamax_matches_naive() {
        check("idamax", 50, |g| {
            let n = g.dim(1, 400);
            let mut x = g.rng.normal_vec(n);
            // force ties sometimes to exercise first-occurrence semantics
            if n > 4 && g.rng.below(2) == 0 {
                let v = x[n / 2];
                x[n / 4] = -v;
            }
            ensure(idamax(&x) == naive::idamax(&x), "idamax index mismatch")
        });
    }

    #[test]
    fn idamax_empty_and_zeros() {
        assert_eq!(idamax(&[]), 0);
        assert_eq!(idamax(&[0.0; 17]), 0);
        assert_eq!(naive::idamax(&[0.0; 17]), 0);
    }

    #[test]
    fn drot_matches_naive() {
        check("drot", 30, |g| {
            let n = g.dim(1, 300);
            let (c, s) = (0.28, 0.96);
            let x0 = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let (mut x1, mut y1) = (x0.clone(), y0.clone());
            let (mut x2, mut y2) = (x0, y0);
            drot(&mut x1, &mut y1, c, s);
            naive::drot(&mut x2, &mut y2, c, s);
            ensure(
                allclose(&x1, &x2, 1e-14, 1e-14) && allclose(&y1, &y2, 1e-14, 1e-14),
                "drot mismatch",
            )
        });
    }
}
