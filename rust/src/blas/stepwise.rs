//! The Fig. 7 optimization ladder: DSCAL with and without DMR at each of
//! the paper's six assembly-optimization steps (§4.2-§4.4).
//!
//! | step | paper                         | this adaptation                     |
//! |------|-------------------------------|-------------------------------------|
//! | 0    | scalar mulsd + ucomisd + jne  | per-element dup + compare + branch  |
//! | 1    | AVX-512 vmulpd + vpcmpeqd     | 8-wide chunk dup + chunk compare    |
//! | 2    | + 4x loop unrolling           | + 4 chunks per iteration            |
//! | 3    | + opmask kandw reduction      | + mismatch flags ANDed, 1 branch/32 |
//! | 4    | + software pipelining + in-register checkpoint | + verification deferred one iteration, checkpoint kept in a register array |
//! | 5    | + prefetcht0                  | + `_mm_prefetch` hints              |
//!
//! The duplicated stream multiplies by a `black_box`-laundered copy of
//! alpha so the compiler cannot CSE the two streams into one — the Rust
//! analog of really issuing the second vmulpd.
//!
//! Injection: `Some((idx, delta))` perturbs the *primary* stream's element
//! `idx` by `delta` exactly once — the transient-ALU-flip model. Every FT
//! step returns the number of detected errors; recovery recomputes the
//! corrupted lane (the paper's third computation) and re-verifies.

use std::hint::black_box;

use crate::blas::level1::prefetch;

/// Simulated vector width (AVX-512 lanes of f64).
pub const LANES: usize = 8;
/// Unroll factor of the vectorized ladder steps.
pub const UNROLL: usize = 4;

/// One ladder step: paired FT / non-FT implementations.
#[derive(Clone, Copy)]
pub struct Step {
    /// Step label, as printed by the Fig. 7 bench.
    pub name: &'static str,
    /// paper's measured FT overhead at this step, for EXPERIMENTS.md
    pub paper_overhead_pct: f64,
    /// The unprotected DSCAL at this step.
    pub ori: fn(f64, &mut [f64]),
    /// The DMR-protected DSCAL (optional injected fault; returns
    /// corrected-error count).
    pub ft: fn(f64, &mut [f64], Option<(usize, f64)>) -> usize,
}

/// The six-step Fig. 7 ladder, slowest to fastest.
pub const STEPS: [Step; 6] = [
    Step { name: "scalar", paper_overhead_pct: 50.8, ori: v0_scalar, ft: v0_scalar_ft },
    Step { name: "vectorized", paper_overhead_pct: 5.2, ori: v1_vec, ft: v1_vec_ft },
    Step { name: "vec-unroll", paper_overhead_pct: 4.9, ori: v2_unroll, ft: v2_unroll_ft },
    Step { name: "cmp-reduction", paper_overhead_pct: 2.7, ori: v2_unroll, ft: v3_cmpred_ft },
    Step { name: "sw-pipelined", paper_overhead_pct: 0.67, ori: v4_pipe, ft: v4_pipe_ft },
    Step { name: "prefetch", paper_overhead_pct: 0.36, ori: v5_prefetch, ft: v5_prefetch_ft },
];

#[cold]
#[inline(never)]
fn unrecoverable() -> ! {
    panic!("FT-BLAS: duplicated streams disagree after recomputation — unrecoverable");
}

/// Recover one lane: recompute (third stream) and verify consensus with
/// the duplicate (paper §4.4.2).
#[inline(never)]
#[cold]
fn recover_lane(alpha: f64, xv: f64, dup: f64) -> f64 {
    let third = black_box(alpha) * black_box(xv);
    if third != dup {
        unrecoverable();
    }
    third
}

// ---------------------------------------------------------- step 0 scalar

/// A single scalar mulsd, pinned: the call boundary stops LLVM from
/// auto-vectorizing the "scalar" baseline into vmulpd (which would
/// misrepresent the paper's step 0) while still costing exactly one
/// scalar multiply issue per element — so duplicating the instruction in
/// the FT version really doubles the compute stream, which is what
/// produces the paper's ~50 % step-0 overhead.
#[inline(never)]
fn mulsd(a: f64, b: f64) -> f64 {
    a * b
}

/// Step 0: scalar `mulsd` loop.
pub fn v0_scalar(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = mulsd(alpha, *v); // mulsd
    }
}

/// Step 0 FT: every multiply issued twice and compared (paper's
/// ~50 % overhead point).
pub fn v0_scalar_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    let mut errs = 0;
    let a2 = black_box(alpha);
    for (i, v) in x.iter_mut().enumerate() {
        let xv = *v;
        let mut primary = mulsd(alpha, xv); // mulsd
        if let Some((idx, delta)) = inject {
            if idx == i {
                primary += delta;
            }
        }
        let dup = mulsd(a2, xv); // duplicated mulsd
        if primary != dup {
            // jne ERROR_HANDLER
            errs += 1;
            primary = recover_lane(alpha, xv, dup);
        }
        *v = primary;
    }
    errs
}

// ------------------------------------------------------ step 1 vectorized

/// Step 1: vectorized (`vmulpd`-shaped) loop.
pub fn v1_vec(alpha: f64, x: &mut [f64]) {
    let mut chunks = x.chunks_exact_mut(LANES);
    for c in &mut chunks {
        for v in c.iter_mut() {
            *v *= alpha; // vmulpd
        }
    }
    for v in chunks.into_remainder() {
        *v *= alpha;
    }
}

#[inline(always)]
fn chunk_ft(alpha: f64, a2: f64, x: &mut [f64], base: usize,
            inject: Option<(usize, f64)>) -> u32 {
    // One DMR chunk, immediate verification interval: compute both
    // streams, compare (kortestw analog), recover the lanes on the cold
    // path while x still holds the inputs, then store once. `a2` is a
    // black_box-laundered copy of alpha made ONCE by the caller: the
    // compiler cannot prove a2 == alpha, so the dup stream really issues
    // a second vmulpd, yet both streams vectorize — the paper's
    // duplicated multiplies on ports 0/1.
    // fixed-size array view: bound-check-free, so both multiply streams
    // compile to one vmulpd each over a single loaded register
    let xs: [f64; LANES] = x[base..base + LANES].try_into().unwrap();
    let mut primary = [0.0f64; LANES];
    let mut dup = [0.0f64; LANES];
    for l in 0..LANES {
        primary[l] = alpha * xs[l]; // vmulpd (stream 1)
        dup[l] = a2 * xs[l]; // vmulpd (stream 2)
    }
    if let Some((idx, delta)) = inject {
        if idx >= base && idx < base + LANES {
            primary[idx - base] += delta;
        }
    }
    let mut mask = 0u32;
    if chunk_mismatch(&primary, &dup) {
        mask = recover_chunk(alpha, x, base, &mut primary, &dup);
    }
    x[base..base + LANES].copy_from_slice(&primary); // single store site
    mask
}

/// Bitwise chunk comparison (the vpcmpeqd + kortestw of §4.2.2): an XOR
/// fold over the lane bit patterns — vectorizes to SIMD xor + or, one
/// scalar test per chunk (NaN-safe: bit equality, not f64 equality).
#[inline(always)]
fn chunk_mismatch(primary: &[f64; LANES], dup: &[f64; LANES]) -> bool {
    let mut diff = 0u64;
    for l in 0..LANES {
        diff |= primary[l].to_bits() ^ dup[l].to_bits();
    }
    diff != 0
}

/// Cold path: per-lane mask + third-stream recovery + consensus check.
/// `x` still holds the original inputs when this runs.
#[cold]
#[inline(never)]
fn recover_chunk(alpha: f64, x: &[f64], base: usize,
                 primary: &mut [f64; LANES], dup: &[f64; LANES]) -> u32 {
    let mask = lane_mask(primary, dup);
    for l in 0..LANES {
        if mask & (1 << l) != 0 {
            primary[l] = recover_lane(alpha, x[base + l], dup[l]);
        }
    }
    mask
}

#[cold]
#[inline(never)]
fn lane_mask(primary: &[f64; LANES], dup: &[f64; LANES]) -> u32 {
    let mut mask = 0u32;
    for l in 0..LANES {
        mask |= ((primary[l] != dup[l]) as u32) << l;
    }
    mask
}

/// Step 1 FT: per-chunk duplicated vector multiply with one opmask
/// verification branch per 8 lanes.
pub fn v1_vec_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    let n = x.len();
    let main = n - n % LANES;
    let a2 = black_box(alpha);
    let mut errs = 0;
    let mut i = 0;
    while i < main {
        // kortestw + jnc — one branch per chunk (8:1 ratio), recovery
        // inside chunk_ft's cold path
        errs += chunk_ft(alpha, a2, x, i, inject).count_ones() as usize;
        i += LANES;
    }
    errs += v0_scalar_ft(alpha, &mut x[main..],
                         inject.and_then(|(idx, d)| {
                             (idx >= main).then(|| (idx - main, d))
                         }));
    errs
}

// -------------------------------------------------- step 2 + 4x unrolling

/// Step 2: 4× unrolled vectorized loop.
pub fn v2_unroll(alpha: f64, x: &mut [f64]) {
    const STEP: usize = LANES * UNROLL;
    let mut chunks = x.chunks_exact_mut(STEP);
    for c in &mut chunks {
        for v in c.iter_mut() {
            *v *= alpha; // 4x vmulpd per iteration
        }
    }
    v1_vec(alpha, chunks.into_remainder());
}

/// Step 2 FT: unrolled duplicated multiplies, still one verification
/// branch per chunk.
pub fn v2_unroll_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    const STEP: usize = LANES * UNROLL;
    let n = x.len();
    let main = n - n % STEP;
    let a2 = black_box(alpha);
    let mut errs = 0;
    let mut i = 0;
    while i < main {
        for u in 0..UNROLL {
            // still one verification branch per chunk at this step
            errs += chunk_ft(alpha, a2, x, i + u * LANES, inject)
                .count_ones() as usize;
        }
        i += STEP;
    }
    errs += v1_vec_ft(alpha, &mut x[main..],
                      inject.and_then(|(idx, d)| {
                          (idx >= main).then(|| (idx - main, d))
                      }));
    errs
}

// --------------------------------------- step 3 + comparison reduction

/// Step 3 FT: comparison reduction — the per-chunk opmasks are OR-ed
/// so only one accounting branch fires per 32 elements.
pub fn v3_cmpred_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    const STEP: usize = LANES * UNROLL;
    let n = x.len();
    let main = n - n % STEP;
    let a2 = black_box(alpha);
    let mut errs = 0;
    let mut i = 0;
    while i < main {
        let mut reduced = 0u32; // kandw-accumulated opmask
        let mut masks = [0u32; UNROLL];
        for u in 0..UNROLL {
            masks[u] = chunk_ft(alpha, a2, x, i + u * LANES, inject);
            reduced |= masks[u]; // kandw reduction (inverted-sense OR here)
        }
        if reduced != 0 {
            // single accounting branch per 4 chunks (32 elements);
            // the lanes were already recovered inside chunk_ft
            for m in masks {
                errs += m.count_ones() as usize;
            }
        }
        i += STEP;
    }
    errs += v1_vec_ft(alpha, &mut x[main..],
                      inject.and_then(|(idx, d)| {
                          (idx >= main).then(|| (idx - main, d))
                      }));
    errs
}

// ------------------------- step 4 + software pipelining + checkpointing

/// Step 4: the non-FT side of the software-pipelined step (identical
/// instruction stream to step 2; see the comment inside).
pub fn v4_pipe(alpha: f64, x: &mut [f64]) {
    // non-FT pipelined version: same instructions as v2_unroll — LLVM
    // already performs the modulo scheduling the paper does by hand, so
    // the ori side of this step is the unrolled kernel.
    v2_unroll(alpha, x);
}

/// Pipelined FT (paper Fig. 3): iteration k's results are *stored before
/// verification*; the original inputs are checkpointed in a register
/// array (BS stage) and iteration k is verified while k+1 computes. On a
/// detected error the checkpoint replays the corrupted iteration (R).
pub fn v4_pipe_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    pipelined_ft::<false>(alpha, x, inject)
}

// ------------------------------------------------- step 5 + prefetching

/// Step 5: unrolled loop with the paper's 128-element prefetch
/// distance.
pub fn v5_prefetch(alpha: f64, x: &mut [f64]) {
    const STEP: usize = LANES * UNROLL;
    const DIST: usize = 128; // the paper's 1024-bit / 128-element distance
    let mut chunks = x.chunks_exact_mut(STEP);
    for c in &mut chunks {
        prefetch(c.as_ptr().wrapping_add(DIST));
        prefetch(c.as_ptr().wrapping_add(DIST + 16));
        for v in c.iter_mut() {
            *v *= alpha;
        }
    }
    v1_vec(alpha, chunks.into_remainder());
}

/// Step 5 FT: the pipelined DMR loop with prefetching — the ladder's
/// 0.36 % endpoint.
pub fn v5_prefetch_ft(alpha: f64, x: &mut [f64], inject: Option<(usize, f64)>) -> usize {
    pipelined_ft::<true>(alpha, x, inject)
}

/// The shared pipelined DMR loop (steps 4-5; PREFETCH selects step 5).
///
/// Per 32-element iteration: load (L), both multiply streams (M1, M2),
/// store the primary immediately (S — the store retires *before* the
/// verification branch resolves, the paper's Fig. 3 S-before-C order),
/// fold the comparison into one u64 (C), and only then branch. The
/// loaded inputs `xs` are the in-register checkpoint (B): they are still
/// live when the cold path runs, so recovery (R) replays the iteration
/// with a third computation + consensus without any clean-path
/// checkpoint traffic — the Rust analog of the paper's "unused register"
/// checkpoint. Compared to step 3 this removes the per-chunk mask
/// bookkeeping and lets every store issue without waiting on any
/// comparison in program order.
#[inline(always)]
fn pipelined_ft<const PREFETCH: bool>(alpha: f64, x: &mut [f64],
                                      inject: Option<(usize, f64)>) -> usize {
    const STEP: usize = LANES * UNROLL;
    const DIST: usize = 128;
    let n = x.len();
    let main = n - n % STEP;
    let a2 = black_box(alpha);
    let mut errs = 0;

    let (inj_idx, inj_delta) = inject.unwrap_or((usize::MAX, 0.0));
    let mut i = 0;
    while i < main {
        if PREFETCH {
            prefetch(x.as_ptr().wrapping_add(i + DIST));
            prefetch(x.as_ptr().wrapping_add(i + DIST + 16));
        }
        // the injected iteration takes the cold instantiation so the hot
        // loop body carries no per-lane injection checks at all
        if inj_idx >= i && inj_idx < i + STEP {
            errs += pipelined_iter::<true>(alpha, a2, x, i,
                                           (inj_idx, inj_delta));
        } else {
            errs += pipelined_iter::<false>(alpha, a2, x, i, (0, 0.0));
        }
        i += STEP;
    }
    errs += v1_vec_ft(alpha, &mut x[main..],
                      inject.and_then(|(idx, d)| {
                          (idx >= main).then(|| (idx - main, d))
                      }));
    errs
}

/// One 32-element pipelined iteration: L, M1+M2+C fused in one pass
/// (both multiply streams and the comparison fold consume the loaded
/// lane while it is live — no intermediate dup array), S before the
/// branch resolves, and the loaded `xs` doubling as the in-register
/// checkpoint for the cold replay path.
#[inline(always)]
fn pipelined_iter<const INJ: bool>(alpha: f64, a2: f64, x: &mut [f64],
                                   i: usize, inj: (usize, f64)) -> usize {
    const STEP: usize = LANES * UNROLL;
    let xs: [f64; STEP] = x[i..i + STEP].try_into().unwrap(); // L (+B)
    let mut out = [0.0f64; STEP];
    let mut diff = 0u64;
    for l in 0..STEP {
        let mut p = alpha * xs[l]; // M1
        let d = a2 * xs[l]; // M2
        if INJ {
            if i + l == inj.0 {
                p += inj.1;
            }
        }
        out[l] = p;
        diff |= p.to_bits() ^ d.to_bits(); // C (folded)
    }
    x[i..i + STEP].copy_from_slice(&out); // S (before the branch)
    if diff != 0 {
        // R: replay from the in-register checkpoint (cold)
        replay_iteration(alpha, x, i, &xs)
    } else {
        0
    }
}

/// Cold path (R): replay a corrupted iteration from its checkpoint with
/// a third computation + consensus check, fixing x in place. Returns the
/// number of corrupted lanes.
#[cold]
#[inline(never)]
fn replay_iteration(alpha: f64, x: &mut [f64], base: usize,
                    ckpt: &[f64; LANES * UNROLL]) -> usize {
    let mut errs = 0;
    for (l, &orig) in ckpt.iter().enumerate() {
        let r1 = black_box(alpha) * black_box(orig);
        let r2 = black_box(alpha) * black_box(orig);
        if r1 != r2 {
            unrecoverable();
        }
        if x[base + l].to_bits() != r1.to_bits() {
            errs += 1;
            x[base + l] = r1;
        }
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    fn expected(alpha: f64, x: &[f64]) -> Vec<f64> {
        x.iter().map(|v| alpha * v).collect()
    }

    #[test]
    fn all_steps_match_without_injection() {
        check("stepwise-clean", 30, |g| {
            let n = g.dim(1, 300);
            let alpha = g.rng.range(-3.0, 3.0);
            let x0 = g.rng.normal_vec(n);
            let want = expected(alpha, &x0);
            for step in STEPS {
                let mut a = x0.clone();
                (step.ori)(alpha, &mut a);
                ensure(a == want, format!("{} ori mismatch", step.name))?;
                let mut b = x0.clone();
                let errs = (step.ft)(alpha, &mut b, None);
                ensure(errs == 0, format!("{} spurious errors", step.name))?;
                ensure(b == want, format!("{} ft mismatch", step.name))?;
            }
            Ok(())
        });
    }

    #[test]
    fn all_steps_detect_and_correct_injection() {
        check("stepwise-inject", 40, |g| {
            let n = g.dim(2, 400);
            let alpha = g.rng.range(0.5, 3.0);
            let x0: Vec<f64> = (0..n).map(|_| g.rng.range(0.5, 2.0)).collect();
            let idx = g.rng.below(n);
            let delta = g.rng.range(1.0, 1e6);
            let want = expected(alpha, &x0);
            for step in STEPS {
                let mut b = x0.clone();
                let errs = (step.ft)(alpha, &mut b, Some((idx, delta)));
                ensure(errs == 1,
                       format!("{}: detected {errs} errors (idx={idx})", step.name))?;
                ensure(b == want, format!("{} did not correct", step.name))?;
            }
            Ok(())
        });
    }

    #[test]
    fn injection_at_boundaries() {
        let alpha = 2.0;
        let n = 97; // forces scalar remainder paths
        let x0: Vec<f64> = (1..=n).map(|i| i as f64).collect();
        let want = expected(alpha, &x0);
        for idx in [0, 31, 32, 63, 95, 96] {
            for step in STEPS {
                let mut b = x0.clone();
                let errs = (step.ft)(alpha, &mut b, Some((idx, 5.0)));
                assert_eq!(errs, 1, "{} idx={idx}", step.name);
                assert_eq!(b, want, "{} idx={idx}", step.name);
            }
        }
    }
}
