//! Tuned Level-2 kernels (paper §3.2): register-reuse DGEMV and the
//! blocked DTRSV that casts its panel work onto DGEMV.

use crate::blas::level1::prefetch;

/// The paper's R_i: rows unrolled so each x_j load is register-reused.
pub const RI: usize = 4;
/// j-loop vector width (8 doubles = one AVX-512 register).
pub const RJ: usize = 8;

/// y := alpha * A x + beta * y — i-loop unrolled RI=4 (x reuse), j-loop
/// vectorized RJ=8, *no cache blocking of A* (paper §3.2.1: blocking
/// breaks A's streaming access and hurts the HW prefetcher).
pub fn dgemv(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64],
             beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    let mi = m - m % RI;
    let nj = n - n % RJ;
    let mut i = 0;
    while i < mi {
        // four row accumulators (vr_0..vr_3 in the paper's Fig. 1)
        let mut acc = [0.0f64; RI];
        let rows: [&[f64]; RI] = [
            &a[i * n..(i + 1) * n],
            &a[(i + 1) * n..(i + 2) * n],
            &a[(i + 2) * n..(i + 3) * n],
            &a[(i + 3) * n..(i + 4) * n],
        ];
        let mut j = 0;
        while j < nj {
            prefetch(unsafe { rows[3].as_ptr().add((j + 64).min(n - 1)) });
            // each x[j..j+8] load is reused RI times (register reuse)
            for l in 0..RJ {
                let xv = x[j + l];
                acc[0] += rows[0][j + l] * xv;
                acc[1] += rows[1][j + l] * xv;
                acc[2] += rows[2][j + l] * xv;
                acc[3] += rows[3][j + l] * xv;
            }
            j += RJ;
        }
        while j < n {
            let xv = x[j];
            for (r, av) in acc.iter_mut().enumerate() {
                *av += rows[r][j] * xv;
            }
            j += 1;
        }
        for (r, av) in acc.iter().enumerate() {
            y[i + r] = alpha * av + beta * y[i + r];
        }
        i += RI;
    }
    // remainder rows
    while i < m {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        y[i] = alpha * acc + beta * y[i];
        i += 1;
    }
}

/// A := alpha x y^T + A, unrolled over columns.
pub fn dger(m: usize, n: usize, alpha: f64, x: &[f64], y: &[f64], a: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    for i in 0..m {
        let axi = alpha * x[i];
        let row = &mut a[i * n..(i + 1) * n];
        for (rv, yv) in row.iter_mut().zip(y) {
            *rv += axi * yv;
        }
    }
}

/// y := alpha sym(A) x + beta y (lower storage): row pass + reflected pass.
pub fn dsymv_lower(n: usize, alpha: f64, a: &[f64], x: &[f64],
                   beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), n * n);
    let mut tmp = vec![0.0; n];
    for i in 0..n {
        let row = &a[i * n..i * n + i];
        let mut acc = a[i * n + i] * x[i];
        // lower-triangle row i contributes to y[i] and (reflected) y[j]
        for (j, &aij) in row.iter().enumerate() {
            acc += aij * x[j];
            tmp[j] += aij * x[i];
        }
        tmp[i] += acc;
    }
    for i in 0..n {
        y[i] = alpha * tmp[i] + beta * y[i];
    }
}

/// x := tril(A) x, row-walk bottom-up with chunked dot products.
pub fn dtrmv_lower(n: usize, a: &[f64], x: &mut [f64]) {
    for i in (0..n).rev() {
        let row = &a[i * n..i * n + i + 1];
        let mut acc = 0.0;
        for (j, &aij) in row.iter().enumerate() {
            acc += aij * x[j];
        }
        x[i] = acc;
    }
}

/// Solve tril(A) x = b in place — paneled (paper §3.2.2, Fig. 1 right):
/// the sub-diagonal panel A(i:i+B, 0:i) is applied with the *tuned DGEMV*
/// (the bulk of the work), the B x B diagonal block with Level-1 dots.
///
/// `panel` is the paper's block size B: FT-BLAS tunes B=4 (= R_i, the
/// minimal and optimal choice); OpenBLAS ships B=64 — the blocked variant
/// uses that to reproduce the paper's 11.17 % gap.
pub fn dtrsv_lower(n: usize, a: &[f64], x: &mut [f64], panel: usize) {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    let mut i = 0;
    while i < n {
        let b = panel.min(n - i);
        // x(i:i+b) -= A(i:i+b, 0:i) * x(0:i)   — cast to DGEMV
        if i > 0 {
            let mut upd = vec![0.0; b];
            // gather the panel rows (the packing analog; contiguous rows)
            let mut panel_rows = vec![0.0; b * i];
            for r in 0..b {
                panel_rows[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * n..(i + r) * n + i]);
            }
            dgemv(b, i, 1.0, &panel_rows, &x[..i], 0.0, &mut upd);
            for r in 0..b {
                x[i + r] -= upd[r];
            }
        }
        // diagonal b x b block: forward substitution with Level-1 dots
        for r in 0..b {
            let row = &a[(i + r) * n + i..(i + r) * n + i + r];
            let mut acc = x[i + r];
            for (j, &v) in row.iter().enumerate() {
                acc -= v * x[i + j];
            }
            x[i + r] = acc / a[(i + r) * n + i + r];
        }
        i += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn dgemv_matches_naive() {
        check("dgemv", 40, |g| {
            let m = g.dim(1, 90);
            let n = g.dim(1, 90);
            let a = Matrix::random(m, n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(m);
            let (alpha, beta) = (g.rng.range(-2.0, 2.0), g.rng.range(-2.0, 2.0));
            let mut y1 = y0.clone();
            let mut y2 = y0;
            dgemv(m, n, alpha, &a.data, &x, beta, &mut y1);
            naive::dgemv(m, n, alpha, &a.data, &x, beta, &mut y2);
            ensure(allclose(&y1, &y2, 1e-11, 1e-11), "tuned dgemv != naive")
        });
    }

    #[test]
    fn dger_matches_naive() {
        check("dger", 25, |g| {
            let m = g.dim(1, 50);
            let n = g.dim(1, 50);
            let x = g.rng.normal_vec(m);
            let y = g.rng.normal_vec(n);
            let a0 = Matrix::random(m, n, &mut g.rng);
            let mut a1 = a0.data.clone();
            let mut a2 = a0.data;
            dger(m, n, 1.7, &x, &y, &mut a1);
            naive::dger(m, n, 1.7, &x, &y, &mut a2);
            ensure(allclose(&a1, &a2, 1e-12, 1e-12), "dger mismatch")
        });
    }

    #[test]
    fn dsymv_matches_naive() {
        check("dsymv", 25, |g| {
            let n = g.dim(1, 60);
            let a = Matrix::random_symmetric(n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(n);
            let mut y1 = y0.clone();
            let mut y2 = y0;
            dsymv_lower(n, 0.9, &a.data, &x, -0.4, &mut y1);
            naive::dsymv_lower(n, 0.9, &a.data, &x, -0.4, &mut y2);
            ensure(allclose(&y1, &y2, 1e-11, 1e-11), "dsymv mismatch")
        });
    }

    #[test]
    fn dtrmv_matches_naive() {
        check("dtrmv", 25, |g| {
            let n = g.dim(1, 60);
            let a = Matrix::random_lower_triangular(n, &mut g.rng);
            let x0 = g.rng.normal_vec(n);
            let mut x1 = x0.clone();
            let mut x2 = x0;
            dtrmv_lower(n, &a.data, &mut x1);
            naive::dtrmv_lower(n, &a.data, &mut x2);
            ensure(allclose(&x1, &x2, 1e-12, 1e-12), "dtrmv mismatch")
        });
    }

    #[test]
    fn dtrsv_matches_naive_any_panel() {
        check("dtrsv-panels", 40, |g| {
            let n = g.dim(1, 120);
            let panel = [1, 3, 4, 8, 64][g.rng.below(5)];
            let a = Matrix::random_lower_triangular(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let mut x1 = b.clone();
            let mut x2 = b;
            dtrsv_lower(n, &a.data, &mut x1, panel);
            naive::dtrsv_lower(n, &a.data, &mut x2);
            ensure(
                allclose(&x1, &x2, 1e-9, 1e-9),
                format!("dtrsv mismatch (panel={panel})"),
            )
        });
    }

    #[test]
    fn dtrsv_panel_equivalence() {
        // the paper's claim: block size is a pure performance knob
        check("dtrsv-panel-equiv", 20, |g| {
            let n = g.dim(8, 128);
            let a = Matrix::random_lower_triangular(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let mut x4 = b.clone();
            let mut x64 = b;
            dtrsv_lower(n, &a.data, &mut x4, 4);
            dtrsv_lower(n, &a.data, &mut x64, 64);
            ensure(allclose(&x4, &x64, 1e-9, 1e-9), "panel changed result")
        });
    }
}
