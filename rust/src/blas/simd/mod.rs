//! SIMD microkernel backend (paper §3.3, §4.4): explicit AVX2+FMA
//! `std::arch` kernels behind a one-time runtime CPU-feature probe.
//!
//! The tuned kernels in [`crate::blas::level1`]/[`crate::blas::level3`]
//! are written so LLVM *auto*-vectorizes them; this module is the layer
//! the paper actually ships — hand-scheduled wide-lane loops:
//!
//! - Level-1 (`dscal`/`daxpy`/`ddot`/`dnrm2`): 256-bit lanes, 4-way
//!   unrolled FMA chains, software prefetch a fixed distance ahead
//!   (§4.4.4's `prefetcht0` placement).
//! - Level-2 (`dgemv`): row-major matrix-vector product where every row
//!   runs the ddot kernel's four independent FMA accumulator chains —
//!   the §4.4 register-reuse scheme at AVX2 width.
//! - Level-3 (`dgemm`): a GEBP macro kernel over packed A/B panels with
//!   an 8×4 register-tiled microkernel — eight `__m256d` accumulators,
//!   one broadcast-FMA per row per rank-1 update (§3.3.2's register
//!   blocking, at AVX2 width).
//! - Fused ABFT (`dgemm_abft_fused`): the §5.2 fusion on the AVX2
//!   path. The packed panels are shared with the checksum pass (the
//!   fused packing routines of [`crate::ft::abft_fused`] accumulate
//!   `B·e` / `e^T·A` from the loads packing performs anyway), and the
//!   `dC^c` checksum stream runs as one extra FMA accumulator over the
//!   packed, cache-hot B̃ — dual accumulation in-register instead of a
//!   second memory pass.
//!
//! Every public entry point consults [`CpuFeatures::get`] — a process-
//! wide, once-only probe — and dispatches to the AVX2 path only when
//! the running CPU reports both `avx2` and `fma`. Otherwise (including
//! every non-x86_64 build, where the intrinsics are compiled out) the
//! call falls through to the existing tuned scalar kernel, so results
//! off-AVX2 are bit-identical to the tuned path and the registry can
//! expose `Impl::Simd` unconditionally.

use std::sync::OnceLock;

use crate::blas::level3::GemmParams;
use crate::ft::abft_fused::Strike;
use crate::ft::FtReport;

/// Register-tile rows of the AVX2 GEBP microkernel: eight `__m256d`
/// accumulators, one per row. The MT row-band frames in
/// [`crate::blas::parallel`] band on this so every band keeps full
/// tiles.
pub const MR: usize = 8;

/// Register-tile columns of the microkernel: one 4-lane `__m256d` per
/// row.
pub const NR: usize = 4;

/// Result of the one-time CPU feature probe gating the AVX2 kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CpuFeatures {
    /// 256-bit SIMD (`vmulpd`/`vbroadcastsd` tier).
    pub avx2: bool,
    /// Fused multiply-add (`vfmadd231pd`).
    pub fma: bool,
}

impl CpuFeatures {
    /// Probe the running CPU. On non-x86_64 targets every feature reads
    /// `false`, so the simd wrappers dispatch to the tuned scalar path.
    pub fn detect() -> CpuFeatures {
        #[cfg(target_arch = "x86_64")]
        {
            CpuFeatures {
                avx2: std::arch::is_x86_feature_detected!("avx2"),
                fma: std::arch::is_x86_feature_detected!("fma"),
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            CpuFeatures { avx2: false, fma: false }
        }
    }

    /// The cached probe result — detection runs once per process; every
    /// kernel dispatch afterwards is a branch on two bools.
    pub fn get() -> CpuFeatures {
        static PROBE: OnceLock<CpuFeatures> = OnceLock::new();
        *PROBE.get_or_init(CpuFeatures::detect)
    }

    /// Whether the AVX2+FMA microkernels can run on this CPU.
    pub fn simd_ready(self) -> bool {
        self.avx2 && self.fma
    }

    /// Stable feature string for ledgers and bench rows. Committed
    /// `BENCH_*.json` rows are compared across machines, so every
    /// report records what the probe saw when the rows were produced.
    pub fn summary() -> &'static str {
        let f = CpuFeatures::get();
        match (cfg!(target_arch = "x86_64"), f.avx2, f.fma) {
            (true, true, true) => "x86_64+avx2+fma",
            (true, true, false) => "x86_64+avx2",
            (true, false, _) => "x86_64",
            (false, ..) => "scalar",
        }
    }
}

/// x := α·x — AVX2 wide-lane loop with software prefetch; tuned scalar
/// fallback off-AVX2.
pub fn dscal(alpha: f64, x: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        unsafe { avx2::dscal(alpha, x) };
        return;
    }
    crate::blas::level1::dscal(alpha, x);
}

/// y := α·x + y — AVX2 FMA loop; tuned scalar fallback off-AVX2.
pub fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        unsafe { avx2::daxpy(alpha, x, y) };
        return;
    }
    crate::blas::level1::daxpy(alpha, x, y);
}

/// dot(x, y) — four independent AVX2 FMA chains (VFMA latency hiding),
/// folded once; tuned scalar fallback off-AVX2.
pub fn ddot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        return unsafe { avx2::ddot(x, y) };
    }
    crate::blas::level1::ddot(x, y)
}

/// ‖x‖₂ — AVX2 sum-of-squares with the same overflow/underflow guard as
/// the tuned kernel (degrade to the scaled naive path when the plain
/// sum of squares is not representable); tuned scalar fallback
/// off-AVX2.
pub fn dnrm2(x: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        let ssq = unsafe { avx2::dsumsq(x) };
        return if ssq.is_finite() && ssq > f64::MIN_POSITIVE {
            ssq.sqrt()
        } else {
            crate::blas::naive::dnrm2(x)
        };
    }
    crate::blas::level1::dnrm2(x)
}

/// y := α·A·x + β·y over row-major A (m×n) — each row reduces through
/// the ddot kernel's four independent AVX2 FMA chains; tuned scalar
/// fallback off-AVX2.
pub fn dgemv(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64], beta: f64,
             y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        unsafe { avx2::dgemv(m, n, alpha, a, x, beta, y) };
        return;
    }
    crate::blas::level2::dgemv(m, n, alpha, a, x, beta, y);
}

/// C := α·A·B + β·C — GEBP over packed panels with the 8×4 AVX2
/// microkernel. Blocking sizes (`mc`/`nc`/`kc`) come from `params`; the
/// register tile is fixed at [`MR`]×[`NR`]. Falls back to the tuned
/// scalar [`crate::blas::level3::dgemm`] off-AVX2.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(m: usize, n: usize, k: usize, alpha: f64, a: &[f64], b: &[f64],
             beta: f64, c: &mut [f64], params: &GemmParams) {
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        unsafe { avx2::dgemm(m, n, k, alpha, a, b, beta, c, params) };
        return;
    }
    crate::blas::level3::dgemm(m, n, k, alpha, a, b, beta, c, params);
}

/// C := α·A·B + β·C with fused online ABFT on the AVX2 path (paper
/// §5.2; FT-GEMM's dual-accumulation refinement): panels are packed
/// once by the fused packing routines (checksums accumulate from the
/// packed loads), the 8×4 microkernel computes the tile, and the `dC^c`
/// stream is one extra in-register FMA accumulator over the packed B̃.
/// Off-AVX2 the call falls through to the tuned scalar fused kernel
/// with identical detection/correction semantics.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_abft_fused(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                        b: &[f64], beta: f64, c: &mut [f64],
                        params: &GemmParams, inject: &[Strike]) -> FtReport {
    #[cfg(target_arch = "x86_64")]
    if CpuFeatures::get().simd_ready() {
        // SAFETY: the probe confirmed avx2+fma on this CPU.
        return unsafe {
            avx2::dgemm_abft_fused(m, n, k, alpha, a, b, beta, c, params,
                                   inject)
        };
    }
    crate::ft::abft_fused::dgemm_abft_fused(m, n, k, alpha, a, b, beta, c,
                                            params, inject)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! The `#[target_feature]` kernel bodies. Everything here is
    //! `unsafe fn`: callers must have verified `avx2` and `fma` via
    //! [`super::CpuFeatures`] before entering.

    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_castpd256_pd128, _mm256_extractf128_pd,
        _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_setzero_pd, _mm256_storeu_pd, _mm_add_pd, _mm_add_sd,
        _mm_cvtsd_f64, _mm_prefetch, _mm_unpackhi_pd, _MM_HINT_T0, __m256d,
    };

    use super::{MR, NR};
    use crate::blas::level3::GemmParams;
    use crate::ft::abft;
    use crate::ft::abft_fused::{self, Strike};
    use crate::ft::FtReport;
    use crate::util::arena;

    /// f64 lanes per `__m256d`.
    const LANES: usize = 4;
    /// Independent FMA chains in the Level-1 loops (paper: 4).
    const UNROLL: usize = 4;
    const STEP: usize = LANES * UNROLL;
    /// Prefetch distance in elements — the tuned scalar kernels' 1 KiB
    /// look-ahead (`wrapping_add` keeps out-of-range hint addresses
    /// defined; the hint itself never faults).
    const PREFETCH_DIST: usize = 128;

    #[inline(always)]
    unsafe fn prefetch(p: *const f64) {
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }

    /// x := α·x.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dscal(alpha: f64, x: &mut [f64]) {
        let n = x.len();
        let p = x.as_mut_ptr();
        let va = _mm256_set1_pd(alpha);
        let mut i = 0;
        while i + STEP <= n {
            prefetch(p.wrapping_add(i + PREFETCH_DIST) as *const f64);
            let mut u = 0;
            while u < UNROLL {
                let q = p.add(i + u * LANES);
                _mm256_storeu_pd(q, _mm256_mul_pd(va, _mm256_loadu_pd(q)));
                u += 1;
            }
            i += STEP;
        }
        while i < n {
            *p.add(i) *= alpha;
            i += 1;
        }
    }

    /// y := α·x + y (equal lengths, asserted by the safe wrapper).
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let va = _mm256_set1_pd(alpha);
        let mut i = 0;
        while i + STEP <= n {
            prefetch(xp.wrapping_add(i + PREFETCH_DIST));
            prefetch(yp.wrapping_add(i + PREFETCH_DIST) as *const f64);
            let mut u = 0;
            while u < UNROLL {
                let q = yp.add(i + u * LANES);
                let r = _mm256_fmadd_pd(
                    va, _mm256_loadu_pd(xp.add(i + u * LANES)),
                    _mm256_loadu_pd(q));
                _mm256_storeu_pd(q, r);
                u += 1;
            }
            i += STEP;
        }
        while i < n {
            *yp.add(i) += alpha * *xp.add(i);
            i += 1;
        }
    }

    /// Horizontal sum of one ymm: lo128 + hi128, then the two lanes.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let s = _mm_add_pd(lo, hi);
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    /// dot(x, y) with four independent FMA accumulator chains.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn ddot(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len().min(y.len());
        let xp = x.as_ptr();
        let yp = y.as_ptr();
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        let mut i = 0;
        while i + STEP <= n {
            prefetch(xp.wrapping_add(i + PREFETCH_DIST));
            prefetch(yp.wrapping_add(i + PREFETCH_DIST));
            a0 = _mm256_fmadd_pd(_mm256_loadu_pd(xp.add(i)),
                                 _mm256_loadu_pd(yp.add(i)), a0);
            a1 = _mm256_fmadd_pd(_mm256_loadu_pd(xp.add(i + LANES)),
                                 _mm256_loadu_pd(yp.add(i + LANES)), a1);
            a2 = _mm256_fmadd_pd(_mm256_loadu_pd(xp.add(i + 2 * LANES)),
                                 _mm256_loadu_pd(yp.add(i + 2 * LANES)), a2);
            a3 = _mm256_fmadd_pd(_mm256_loadu_pd(xp.add(i + 3 * LANES)),
                                 _mm256_loadu_pd(yp.add(i + 3 * LANES)), a3);
            i += STEP;
        }
        let mut sum = hsum(_mm256_add_pd(_mm256_add_pd(a0, a1),
                                         _mm256_add_pd(a2, a3)));
        while i < n {
            sum += *xp.add(i) * *yp.add(i);
            i += 1;
        }
        sum
    }

    /// Σ xᵢ² with four independent FMA accumulator chains (the dnrm2
    /// core; the overflow guard lives in the safe wrapper).
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dsumsq(x: &[f64]) -> f64 {
        let n = x.len();
        let xp = x.as_ptr();
        let mut a0 = _mm256_setzero_pd();
        let mut a1 = _mm256_setzero_pd();
        let mut a2 = _mm256_setzero_pd();
        let mut a3 = _mm256_setzero_pd();
        let mut i = 0;
        while i + STEP <= n {
            prefetch(xp.wrapping_add(i + PREFETCH_DIST));
            let v0 = _mm256_loadu_pd(xp.add(i));
            let v1 = _mm256_loadu_pd(xp.add(i + LANES));
            let v2 = _mm256_loadu_pd(xp.add(i + 2 * LANES));
            let v3 = _mm256_loadu_pd(xp.add(i + 3 * LANES));
            a0 = _mm256_fmadd_pd(v0, v0, a0);
            a1 = _mm256_fmadd_pd(v1, v1, a1);
            a2 = _mm256_fmadd_pd(v2, v2, a2);
            a3 = _mm256_fmadd_pd(v3, v3, a3);
            i += STEP;
        }
        let mut ssq = hsum(_mm256_add_pd(_mm256_add_pd(a0, a1),
                                         _mm256_add_pd(a2, a3)));
        while i < n {
            let v = *xp.add(i);
            ssq += v * v;
            i += 1;
        }
        ssq
    }

    /// y := α·A·x + β·y over row-major A: one row per iteration, each
    /// reduced by [`ddot`]'s four independent FMA accumulator chains
    /// (the row stream prefetches inside `ddot`; rows are contiguous,
    /// so the next row's head is usually already resident).
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dgemv(m: usize, n: usize, alpha: f64, a: &[f64],
                        x: &[f64], beta: f64, y: &mut [f64]) {
        for i in 0..m {
            let acc = ddot(&a[i * n..(i + 1) * n], x);
            y[i] = alpha * acc + beta * y[i];
        }
    }

    /// Pack an (mcb × kcb) block of A into MR-row micro panels,
    /// zero-padded to full tiles (so the microkernel never branches on
    /// edge rows).
    fn pack_a(a: &[f64], lda: usize, i0: usize, p0: usize, mcb: usize,
              kcb: usize, out: &mut [f64]) {
        let mut w = 0;
        let mut i = 0;
        while i < mcb {
            let rows = MR.min(mcb - i);
            for p in 0..kcb {
                for r in 0..rows {
                    out[w] = a[(i0 + i + r) * lda + p0 + p];
                    w += 1;
                }
                for _ in rows..MR {
                    out[w] = 0.0;
                    w += 1;
                }
            }
            i += MR;
        }
    }

    /// Pack a (kcb × ncb) block of B into NR-col micro panels,
    /// zero-padded to full tiles.
    fn pack_b(b: &[f64], ldb: usize, p0: usize, j0: usize, kcb: usize,
              ncb: usize, out: &mut [f64]) {
        let mut w = 0;
        let mut j = 0;
        while j < ncb {
            let cols = NR.min(ncb - j);
            for p in 0..kcb {
                for cdx in 0..cols {
                    out[w] = b[(p0 + p) * ldb + j0 + j + cdx];
                    w += 1;
                }
                for _ in cols..NR {
                    out[w] = 0.0;
                    w += 1;
                }
            }
            j += NR;
        }
    }

    /// The 8×4 register-tiled microkernel: eight `__m256d` accumulators
    /// (one row each); per rank-1 update, one packed-B row load and
    /// eight broadcast-FMAs. Writes the raw A·B tile (no α) to `acc`.
    ///
    /// # Safety
    /// `ap`/`bp` must point at `kc` full MR-row / NR-col packed panels;
    /// requires avx2+fma.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn kernel_8x4(kc: usize, ap: *const f64, bp: *const f64,
                         acc: &mut [f64; MR * NR]) {
        let mut c0 = _mm256_setzero_pd();
        let mut c1 = _mm256_setzero_pd();
        let mut c2 = _mm256_setzero_pd();
        let mut c3 = _mm256_setzero_pd();
        let mut c4 = _mm256_setzero_pd();
        let mut c5 = _mm256_setzero_pd();
        let mut c6 = _mm256_setzero_pd();
        let mut c7 = _mm256_setzero_pd();
        let mut p = 0;
        while p < kc {
            // stay ~8 rank-1 updates ahead of the FMA stream
            prefetch(ap.wrapping_add((p + 8) * MR));
            prefetch(bp.wrapping_add((p + 8) * NR));
            let bv = _mm256_loadu_pd(bp.add(p * NR));
            let ar = ap.add(p * MR);
            c0 = _mm256_fmadd_pd(_mm256_set1_pd(*ar), bv, c0);
            c1 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(1)), bv, c1);
            c2 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(2)), bv, c2);
            c3 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(3)), bv, c3);
            c4 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(4)), bv, c4);
            c5 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(5)), bv, c5);
            c6 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(6)), bv, c6);
            c7 = _mm256_fmadd_pd(_mm256_set1_pd(*ar.add(7)), bv, c7);
            p += 1;
        }
        let out = acc.as_mut_ptr();
        _mm256_storeu_pd(out, c0);
        _mm256_storeu_pd(out.add(NR), c1);
        _mm256_storeu_pd(out.add(2 * NR), c2);
        _mm256_storeu_pd(out.add(3 * NR), c3);
        _mm256_storeu_pd(out.add(4 * NR), c4);
        _mm256_storeu_pd(out.add(5 * NR), c5);
        _mm256_storeu_pd(out.add(6 * NR), c6);
        _mm256_storeu_pd(out.add(7 * NR), c7);
    }

    /// Serial GEBP DGEMM: C := α·A·B + β·C.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dgemm(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                        b: &[f64], beta: f64, c: &mut [f64],
                        params: &GemmParams) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        // β pass first so the macro kernel accumulates with a pure +=
        if beta == 0.0 {
            c.fill(0.0);
        } else if beta != 1.0 {
            dscal(beta, c);
        }
        if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
            return;
        }
        let &GemmParams { mc, nc, kc, .. } = params;
        // packing panels come from the thread-local arena: steady-state
        // calls (the batched small-GEMM shape) allocate nothing
        arena::with(
            [arena::packed_a_len(mc, kc, MR),
             arena::packed_b_len(nc, kc, NR)],
            // SAFETY: the caller vouched for avx2+fma
            |[apack, bpack]| unsafe {
                gebp_loop(m, n, k, alpha, a, b, c, params, apack, bpack)
            },
        );
    }

    /// The GEBP loop nest of [`dgemm`], over arena-leased packed panels.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn gebp_loop(m: usize, n: usize, k: usize, alpha: f64, a: &[f64],
                        b: &[f64], c: &mut [f64], params: &GemmParams,
                        apack: &mut [f64], bpack: &mut [f64]) {
        let &GemmParams { mc, nc, kc, .. } = params;
        let mut acc = [0.0f64; MR * NR];
        let mut j0 = 0;
        while j0 < n {
            let ncb = nc.min(n - j0);
            let mut p0 = 0;
            while p0 < k {
                let kcb = kc.min(k - p0);
                pack_b(b, n, p0, j0, kcb, ncb, &mut bpack);
                let mut i0 = 0;
                while i0 < m {
                    let mcb = mc.min(m - i0);
                    pack_a(a, k, i0, p0, mcb, kcb, &mut apack);
                    let mut jj = 0;
                    while jj < ncb {
                        let nrb = NR.min(ncb - jj);
                        let bp = bpack[(jj / NR) * (NR * kcb)..].as_ptr();
                        let mut ii = 0;
                        while ii < mcb {
                            let mrb = MR.min(mcb - ii);
                            let ap =
                                apack[(ii / MR) * (MR * kcb)..].as_ptr();
                            kernel_8x4(kcb, ap, bp, &mut acc);
                            for r in 0..mrb {
                                let crow = &mut c[(i0 + ii + r) * n + j0
                                    + jj..][..nrb];
                                let arow = &acc[r * NR..r * NR + nrb];
                                for (cv, av) in crow.iter_mut().zip(arow) {
                                    *cv += alpha * av;
                                }
                            }
                            ii += MR;
                        }
                        jj += NR;
                    }
                    i0 += mc;
                }
                p0 += kc;
            }
            j0 += nc;
        }
    }

    /// The fused `dC^c` checksum stream for one NR-tile of packed B̃:
    /// `dst[c] += Σ_p (α·eta[p]) · B̃[p][c]` — a single extra FMA
    /// accumulator register riding the cache-hot packed panel (the "one
    /// extra FMA stream" the §5.2 fusion costs).
    ///
    /// # Safety
    /// `bp` must point at `kcb` packed NR-col rows; requires avx2+fma.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dcc_tile(kcb: usize, alpha: f64, eta: &[f64], bp: *const f64,
                       dst: &mut [f64]) {
        if dst.len() == NR {
            let mut acc = _mm256_setzero_pd();
            for (p, e) in eta.iter().enumerate().take(kcb) {
                acc = _mm256_fmadd_pd(_mm256_set1_pd(alpha * e),
                                      _mm256_loadu_pd(bp.add(p * NR)), acc);
            }
            let mut out = [0.0f64; NR];
            _mm256_storeu_pd(out.as_mut_ptr(), acc);
            for (d, v) in dst.iter_mut().zip(out) {
                *d += v;
            }
        } else {
            for (p, e) in eta.iter().enumerate().take(kcb) {
                let ep = alpha * e;
                for (cdx, d) in dst.iter_mut().enumerate() {
                    *d += ep * *bp.add(p * NR + cdx);
                }
            }
        }
    }

    /// C := α·A·B + β·C with fused online ABFT — the scalar
    /// [`abft_fused::dgemm_abft_fused`] orchestration (same fused
    /// packing, same verification intervals, same injection model) with
    /// the 8×4 AVX2 microkernel and the in-register `dC^c` stream.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dgemm_abft_fused(m: usize, n: usize, k: usize, alpha: f64,
                                   a: &[f64], b: &[f64], beta: f64,
                                   c: &mut [f64], params: &GemmParams,
                                   inject: &[Strike]) -> FtReport {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(c.len(), m * n);
        if m == 0 || n == 0 {
            return FtReport::none();
        }
        let &GemmParams { mc, nc, kc, .. } = params;
        // checksum vectors, packing panels, and ABFT scratch come from
        // one zeroed arena lease — steady-state protected GEMMs are
        // allocation-free
        arena::with(
            [m, n, m, n,
             arena::packed_a_len(mc, kc, MR),
             arena::packed_b_len(nc, kc, NR),
             kc, kc, mc, mc, nc, nc],
            // SAFETY: the caller vouched for avx2+fma
            |[cr_enc, cc_enc, cr_ref, cc_ref, apack, bpack, be, eta,
              crenc_loc, crref_loc, ccenc_loc, ccref_loc]| unsafe {
                fused_loop(m, n, k, alpha, a, b, beta, c, params, inject,
                           FusedScratch { cr_enc, cc_enc, cr_ref, cc_ref,
                                          apack, bpack, be, eta, crenc_loc,
                                          crref_loc, ccenc_loc, ccref_loc })
            },
        )
    }

    /// Arena-leased scratch of one fused AVX2 GEMM (the accumulator
    /// tile stays a stack array in [`fused_loop`]).
    struct FusedScratch<'s> {
        cr_enc: &'s mut [f64],
        cc_enc: &'s mut [f64],
        cr_ref: &'s mut [f64],
        cc_ref: &'s mut [f64],
        apack: &'s mut [f64],
        bpack: &'s mut [f64],
        be: &'s mut [f64],
        eta: &'s mut [f64],
        crenc_loc: &'s mut [f64],
        crref_loc: &'s mut [f64],
        ccenc_loc: &'s mut [f64],
        ccref_loc: &'s mut [f64],
    }

    /// The fused loop nest of [`dgemm_abft_fused`], operating entirely
    /// on arena-leased scratch.
    ///
    /// # Safety
    /// Requires avx2+fma (probe-checked by the safe wrapper).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn fused_loop(m: usize, n: usize, k: usize, alpha: f64,
                         a: &[f64], b: &[f64], beta: f64, c: &mut [f64],
                         params: &GemmParams, inject: &[Strike],
                         scratch: FusedScratch<'_>) -> FtReport {
        let FusedScratch { cr_enc, cc_enc, cr_ref, cc_ref, apack, bpack,
                           be, eta, crenc_loc, crref_loc, ccenc_loc,
                           ccref_loc } = scratch;
        let &GemmParams { mc, nc, kc, .. } = params;
        let mut report = FtReport::none();

        // fused β-scaling + checksum seeding, exactly as the scalar
        // fused kernel (each C element is read once anyway)
        for i in 0..m {
            let row = &mut c[i * n..(i + 1) * n];
            let mut rsum = 0.0;
            for (j, v) in row.iter_mut().enumerate() {
                *v *= beta;
                rsum += *v;
                cc_enc[j] += *v;
            }
            cr_enc[i] = rsum;
        }
        cr_ref.copy_from_slice(cr_enc);
        cc_ref.copy_from_slice(cc_enc);

        if k == 0 || alpha == 0.0 {
            return report;
        }

        let mut acc = [0.0f64; MR * NR];
        let (mut max_a, mut max_b) = (0.0f64, 0.0f64);
        let mut corrected_tol = 0.0f64;

        // rank-k loop outermost: each K_C step is one verification
        // interval (one correction per interval, paper §2.1)
        let mut p0 = 0;
        let mut step = 0;
        while p0 < k {
            let kcb = kc.min(k - p0);
            let mut j0 = 0;
            while j0 < n {
                let ncb = nc.min(n - j0);
                be[..kcb].fill(0.0);
                abft_fused::pack_b_fused(b, n, p0, j0, kcb, ncb, NR,
                                         &mut bpack, &mut be[..kcb]);
                max_b = max_b.max(abft_fused::max_abs(
                    &bpack[..ncb.div_ceil(NR) * NR * kcb]));
                let mut i0 = 0;
                while i0 < m {
                    let mcb = mc.min(m - i0);
                    eta[..kcb].fill(0.0);
                    crenc_loc[..mcb].fill(0.0);
                    crref_loc[..mcb].fill(0.0);
                    ccenc_loc[..ncb].fill(0.0);
                    ccref_loc[..ncb].fill(0.0);
                    abft_fused::pack_a_fused(a, k, i0, p0, mcb, kcb, MR,
                                             alpha, &be[..kcb], &mut apack,
                                             &mut crenc_loc, &mut eta[..kcb]);
                    if j0 == 0 {
                        max_a = max_a.max(abft_fused::max_abs(
                            &apack[..mcb.div_ceil(MR) * MR * kcb]));
                    }
                    // dC^c of this block pair: (e^T A_block) · B̃, one
                    // FMA accumulator per NR-tile of the packed panel
                    {
                        let mut jj = 0;
                        while jj < ncb {
                            let cols = NR.min(ncb - jj);
                            let bp =
                                bpack[(jj / NR) * (NR * kcb)..].as_ptr();
                            dcc_tile(kcb, alpha, &eta, bp,
                                     &mut ccenc_loc[jj..jj + cols]);
                            jj += NR;
                        }
                    }
                    // macro kernel with fused reference-checksum update
                    let mut jj = 0;
                    while jj < ncb {
                        let nrb = NR.min(ncb - jj);
                        let bp = bpack[(jj / NR) * (NR * kcb)..].as_ptr();
                        let mut ii = 0;
                        while ii < mcb {
                            let mrb = MR.min(mcb - ii);
                            let ap =
                                apack[(ii / MR) * (MR * kcb)..].as_ptr();
                            kernel_8x4(kcb, ap, bp, &mut acc);
                            // transient-fault injection: corrupt the
                            // computed tile value before anything
                            // consumes it (same model as the scalar
                            // fused kernel)
                            for &(s, fi, fj, delta) in inject {
                                if s == step
                                    && fi >= i0 + ii && fi < i0 + ii + mrb
                                    && fj >= j0 + jj && fj < j0 + jj + nrb
                                {
                                    acc[(fi - i0 - ii) * NR
                                        + (fj - j0 - jj)] += delta / alpha;
                                }
                            }
                            // write-back reuses the register tile for
                            // the reference checksums
                            for r in 0..mrb {
                                let gi = i0 + ii + r;
                                let crow = &mut c[gi * n + j0 + jj..][..nrb];
                                let arow = &acc[r * NR..r * NR + nrb];
                                let ccref = &mut ccref_loc[jj..jj + nrb];
                                let mut drow = [0.0f64; NR];
                                let drow = &mut drow[..nrb];
                                for (dv, av) in drow.iter_mut().zip(arow) {
                                    *dv = alpha * av;
                                }
                                for (cv, dv) in
                                    crow.iter_mut().zip(drow.iter())
                                {
                                    *cv += dv;
                                }
                                for (cc, dv) in
                                    ccref.iter_mut().zip(drow.iter())
                                {
                                    *cc += dv;
                                }
                                crref_loc[ii + r] +=
                                    drow.iter().sum::<f64>();
                            }
                            ii += MR;
                        }
                        jj += NR;
                    }
                    // flush the block-local checksum accumulators
                    for (g, l) in cr_enc[i0..i0 + mcb].iter_mut()
                        .zip(&crenc_loc[..mcb])
                    {
                        *g += l;
                    }
                    for (g, l) in cr_ref[i0..i0 + mcb].iter_mut()
                        .zip(&crref_loc[..mcb])
                    {
                        *g += l;
                    }
                    for (g, l) in cc_enc[j0..j0 + ncb].iter_mut()
                        .zip(&ccenc_loc[..ncb])
                    {
                        *g += l;
                    }
                    for (g, l) in cc_ref[j0..j0 + ncb].iter_mut()
                        .zip(&ccref_loc[..ncb])
                    {
                        *g += l;
                    }
                    i0 += mc;
                }
                j0 += nc;
            }
            // end of verification interval: O(m+n) compare / locate /
            // correct
            let tol = abft::round_off_threshold(
                alpha.abs().max(1.0) * max_a * max_b, k, n.max(m))
                + corrected_tol;
            if let Some(err) = abft_fused::verify_refs(&cr_enc, &cc_enc,
                                                       &cr_ref, &cc_ref, tol)
            {
                c[err.i * n + err.j] -= err.magnitude;
                cr_ref[err.i] -= err.magnitude;
                cc_ref[err.j] -= err.magnitude;
                corrected_tol += err.magnitude.abs() * f64::EPSILON * 64.0;
                report.errors_detected += 1;
                report.errors_corrected += 1;
            }
            p0 += kc;
            step += 1;
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::naive;
    use crate::util::check::{check, ensure, ensure_close};
    use crate::util::matrix::{allclose, Matrix};

    #[test]
    fn probe_is_cached_and_summarized() {
        let a = CpuFeatures::get();
        let b = CpuFeatures::get();
        assert_eq!(a, b, "probe must be stable across calls");
        assert_eq!(a, CpuFeatures::detect());
        let s = CpuFeatures::summary();
        assert!(!s.is_empty());
        if !cfg!(target_arch = "x86_64") {
            assert_eq!(s, "scalar");
            assert!(!a.simd_ready());
        }
    }

    #[test]
    fn level1_kernels_match_naive() {
        check("simd-level1", 40, |g| {
            let n = g.dim(1, 300);
            let alpha = g.rng.range(-2.0, 2.0);
            let x = g.rng.normal_vec(n);
            let y = g.rng.normal_vec(n);
            let mut xs = x.clone();
            let mut xn = x.clone();
            dscal(alpha, &mut xs);
            naive::dscal(alpha, &mut xn);
            ensure(xs == xn, "simd dscal != naive")?;
            let mut ys = y.clone();
            let mut yn = y.clone();
            daxpy(alpha, &x, &mut ys);
            naive::daxpy(alpha, &x, &mut yn);
            ensure(allclose(&ys, &yn, 1e-13, 1e-13), "simd daxpy drifted")?;
            ensure_close(ddot(&x, &y), naive::ddot(&x, &y), 1e-12,
                         "simd ddot")?;
            ensure_close(dnrm2(&x), naive::dnrm2(&x), 1e-12, "simd dnrm2")
        });
    }

    #[test]
    fn dnrm2_overflow_falls_back() {
        let x = vec![1e300; 18];
        let expect = 1e300 * (18.0f64).sqrt();
        assert!((dnrm2(&x) - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn dgemv_matches_naive_odd_shapes() {
        check("simd-dgemv", 30, |g| {
            let m = g.dim(1, 60);
            let n = g.dim(1, 60);
            let a = Matrix::random(m, n, &mut g.rng);
            let x = g.rng.normal_vec(n);
            let y0 = g.rng.normal_vec(m);
            let (alpha, beta) =
                (g.rng.range(-2.0, 2.0), g.rng.range(-1.0, 1.0));
            let mut want = y0.clone();
            naive::dgemv(m, n, alpha, &a.data, &x, beta, &mut want);
            let mut got = y0.clone();
            dgemv(m, n, alpha, &a.data, &x, beta, &mut got);
            ensure(allclose(&got, &want, 1e-12, 1e-12), "simd dgemv wrong")
        });
    }

    #[test]
    fn dgemm_matches_naive_odd_shapes() {
        check("simd-dgemm", 20, |g| {
            let m = g.dim(1, 40);
            let n = g.dim(1, 40);
            let k = g.dim(1, 40);
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let (alpha, beta) =
                (g.rng.range(-2.0, 2.0), g.rng.range(-1.0, 1.0));
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut want);
            let mut got = c0.data.clone();
            dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut got,
                  &GemmParams::default());
            ensure(allclose(&got, &want, 1e-10, 1e-10), "simd dgemm wrong")
        });
    }

    #[test]
    fn fused_dgemm_clean_and_injected() {
        check("simd-fused", 20, |g| {
            let m = g.dim(4, 40);
            let n = g.dim(4, 40);
            let k = g.dim(4, 48);
            let params = GemmParams { kc: 8, ..Default::default() };
            let a = Matrix::random(m, k, &mut g.rng);
            let b = Matrix::random(k, n, &mut g.rng);
            let c0 = Matrix::random(m, n, &mut g.rng);
            let alpha = g.rng.range(0.5, 2.0);
            let beta = g.rng.range(-1.0, 1.0);
            let mut want = c0.data.clone();
            naive::dgemm(m, n, k, alpha, &a.data, &b.data, beta, &mut want);
            let mut c = c0.data.clone();
            let rep = dgemm_abft_fused(m, n, k, alpha, &a.data, &b.data,
                                       beta, &mut c, &params, &[]);
            ensure(rep == FtReport::none(), "clean simd-fused flagged")?;
            ensure(allclose(&c, &want, 1e-9, 1e-9), "clean value wrong")?;
            let steps = k.div_ceil(params.kc);
            let strike = (g.rng.below(steps), g.rng.below(m), g.rng.below(n),
                          g.rng.range(1e2, 1e5));
            let mut c = c0.data.clone();
            let rep = dgemm_abft_fused(m, n, k, alpha, &a.data, &b.data,
                                       beta, &mut c, &params, &[strike]);
            ensure(rep.errors_detected == 1 && rep.errors_corrected == 1,
                   format!("simd-fused report {rep:?}"))?;
            ensure(allclose(&c, &want, 1e-8, 1e-8), "strike not corrected")
        });
    }
}
