//! Naive reference implementations — the LAPACK-reference stand-in
//! (DESIGN.md substitution #2) and the oracle every other Rust variant is
//! tested against. Textbook loops, no blocking, no unrolling.

/// x := alpha * x
pub fn dscal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// y := alpha * x + y
pub fn daxpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

/// dot(x, y)
pub fn ddot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// ||x||_2 with overflow-safe scaling (reference-BLAS style).
pub fn dnrm2(x: &[f64]) -> f64 {
    let amax = x.iter().fold(0.0f64, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        return 0.0;
    }
    let ssq: f64 = x.iter().map(|v| (v / amax) * (v / amax)).sum();
    amax * ssq.sqrt()
}

/// sum |x_i|
pub fn dasum(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// y := x
pub fn dcopy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// swap x and y
pub fn dswap(x: &mut [f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        std::mem::swap(a, b);
    }
}

/// Apply a Givens rotation: (x, y) := (c x + s y, c y - s x)
pub fn drot(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    assert_eq!(x.len(), y.len());
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        let (xa, yb) = (*a, *b);
        *a = c * xa + s * yb;
        *b = c * yb - s * xa;
    }
}

/// Modified Givens rotation, BLAS DROTM. `param = [flag, h11, h21, h12,
/// h22]`; the flag selects which H entries are implied (reference BLAS
/// semantics: -2 identity, -1 full H, 0 unit diagonal, 1 unit
/// off-diagonal).
pub fn drotm(x: &mut [f64], y: &mut [f64], param: &[f64; 5]) {
    assert_eq!(x.len(), y.len());
    let flag = param[0];
    let (h11, h21, h12, h22) = match flag {
        f if f == -2.0 => return,
        f if f == -1.0 => (param[1], param[2], param[3], param[4]),
        f if f == 0.0 => (1.0, param[2], param[3], 1.0),
        _ => (param[1], -1.0, 1.0, param[4]),
    };
    for (a, b) in x.iter_mut().zip(y.iter_mut()) {
        let (xa, yb) = (*a, *b);
        *a = h11 * xa + h12 * yb;
        *b = h21 * xa + h22 * yb;
    }
}

/// Index of max |x_i| (first occurrence), BLAS IDAMAX.
pub fn idamax(x: &[f64]) -> usize {
    let mut best = 0;
    let mut bv = 0.0f64;
    for (i, v) in x.iter().enumerate() {
        if v.abs() > bv {
            bv = v.abs();
            best = i;
        }
    }
    best
}

/// y := alpha * A x + beta * y; A is (m x n) row-major.
pub fn dgemv(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64],
             beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), n);
    assert_eq!(y.len(), m);
    for i in 0..m {
        let mut acc = 0.0;
        for j in 0..n {
            acc += a[i * n + j] * x[j];
        }
        y[i] = alpha * acc + beta * y[i];
    }
}

/// y := alpha * A^T x + beta * y; A is (m x n) row-major, x len m, y len n.
pub fn dgemv_t(m: usize, n: usize, alpha: f64, a: &[f64], x: &[f64],
               beta: f64, y: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(x.len(), m);
    assert_eq!(y.len(), n);
    for (yj, yv) in y.iter_mut().enumerate() {
        let mut acc = 0.0;
        for i in 0..m {
            acc += a[i * n + yj] * x[i];
        }
        *yv = alpha * acc + beta * *yv;
    }
}

/// A := alpha * x y^T + A; A is (m x n) row-major.
pub fn dger(m: usize, n: usize, alpha: f64, x: &[f64], y: &[f64], a: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    for i in 0..m {
        let axi = alpha * x[i];
        for j in 0..n {
            a[i * n + j] += axi * y[j];
        }
    }
}

/// x := tril(A) x (lower-triangular matrix-vector product).
pub fn dtrmv_lower(n: usize, a: &[f64], x: &mut [f64]) {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    // walk rows bottom-up so x[j<i] are still the inputs
    for i in (0..n).rev() {
        let mut acc = 0.0;
        for j in 0..=i {
            acc += a[i * n + j] * x[j];
        }
        x[i] = acc;
    }
}

/// y := alpha * sym(A) x + beta * y, A referenced by its lower triangle.
pub fn dsymv_lower(n: usize, alpha: f64, a: &[f64], x: &[f64],
                   beta: f64, y: &mut [f64]) {
    for i in 0..n {
        let mut acc = 0.0;
        for j in 0..n {
            let aij = if j <= i { a[i * n + j] } else { a[j * n + i] };
            acc += aij * x[j];
        }
        y[i] = alpha * acc + beta * y[i];
    }
}

/// Solve tril(A) x = b in place (x starts as b), non-unit diagonal.
pub fn dtrsv_lower(n: usize, a: &[f64], x: &mut [f64]) {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    for i in 0..n {
        let mut acc = x[i];
        for j in 0..i {
            acc -= a[i * n + j] * x[j];
        }
        x[i] = acc / a[i * n + i];
    }
}

/// C := alpha * A B + beta * C; A (m x k), B (k x n), C (m x n), row-major.
pub fn dgemm(m: usize, n: usize, k: usize, alpha: f64, a: &[f64], b: &[f64],
             beta: f64, c: &mut [f64]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// C := alpha * sym(A) B + beta * C, A (n x n) referenced by lower triangle.
pub fn dsymm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &[f64],
                   beta: f64, c: &mut [f64]) {
    assert_eq!(a.len(), m * m);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..m {
                let aip = if p <= i { a[i * m + p] } else { a[p * m + i] };
                acc += aip * b[p * n + j];
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// B := alpha * tril(A) B; A (m x m), B (m x n).
pub fn dtrmm_lower(m: usize, n: usize, alpha: f64, a: &[f64], b: &mut [f64]) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    for i in (0..m).rev() {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..=i {
                acc += a[i * m + p] * b[p * n + j];
            }
            b[i * n + j] = alpha * acc;
        }
    }
}

/// C := alpha * A A^T + beta * C (lower triangle updated); A (n x k).
pub fn dsyrk_lower(n: usize, k: usize, alpha: f64, a: &[f64],
                   beta: f64, c: &mut [f64]) {
    assert_eq!(a.len(), n * k);
    assert_eq!(c.len(), n * n);
    for i in 0..n {
        for j in 0..=i {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * a[j * k + p];
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// Solve tril(A) X = B in place (X starts as B); A (m x m), B (m x n).
pub fn dtrsm_llnn(m: usize, n: usize, a: &[f64], b: &mut [f64]) {
    assert_eq!(a.len(), m * m);
    assert_eq!(b.len(), m * n);
    for i in 0..m {
        for p in 0..i {
            let aip = a[i * m + p];
            if aip != 0.0 {
                for j in 0..n {
                    b[i * n + j] -= aip * b[p * n + j];
                }
            }
        }
        let d = a[i * m + i];
        for j in 0..n {
            b[i * n + j] /= d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::matrix::{allclose, Matrix};
    use crate::util::rng::Rng;

    #[test]
    fn dscal_basic() {
        let mut x = vec![1.0, -2.0, 3.0];
        dscal(2.0, &mut x);
        assert_eq!(x, vec![2.0, -4.0, 6.0]);
    }

    #[test]
    fn daxpy_basic() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        daxpy(3.0, &x, &mut y);
        assert_eq!(y, vec![13.0, 26.0]);
    }

    #[test]
    fn ddot_basic() {
        assert_eq!(ddot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }

    #[test]
    fn dnrm2_345() {
        assert!((dnrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-14);
        assert_eq!(dnrm2(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn dnrm2_overflow_safe() {
        let big = 1e200;
        let n = dnrm2(&[3.0 * big, 4.0 * big]);
        assert!((n - 5.0 * big).abs() / (5.0 * big) < 1e-14);
    }

    #[test]
    fn idamax_first_max() {
        assert_eq!(idamax(&[1.0, -5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn drot_orthogonal() {
        let x0: Vec<f64> = vec![1.0, 0.0];
        let y0: Vec<f64> = vec![0.0, 1.0];
        let mut x = x0.clone();
        let mut y = y0.clone();
        let (c, s) = (0.6, 0.8);
        drot(&mut x, &mut y, c, s);
        // rotation preserves sum of squares per position
        for i in 0..2 {
            let before = x0[i] * x0[i] + y0[i] * y0[i];
            let after = x[i] * x[i] + y[i] * y[i];
            assert!((before - after).abs() < 1e-14);
        }
    }

    #[test]
    fn dgemv_identity() {
        let n = 4;
        let a = Matrix::identity(n);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; n];
        dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut y);
        assert_eq!(y, x);
    }

    #[test]
    fn dgemv_t_matches_transposed_gemv() {
        let mut rng = Rng::new(21);
        let (m, n) = (13, 7);
        let a = Matrix::random(m, n, &mut rng);
        let x = rng.normal_vec(m);
        let mut y1 = rng.normal_vec(n);
        let mut y2 = y1.clone();
        dgemv_t(m, n, 1.5, &a.data, &x, 0.5, &mut y1);
        let at = a.transpose();
        dgemv(n, m, 1.5, &at.data, &x, 0.5, &mut y2);
        assert!(allclose(&y1, &y2, 1e-12, 1e-12));
    }

    #[test]
    fn dtrsv_solves() {
        let mut rng = Rng::new(3);
        let n = 32;
        let a = Matrix::random_lower_triangular(n, &mut rng);
        let b = rng.normal_vec(n);
        let mut x = b.clone();
        dtrsv_lower(n, &a.data, &mut x);
        // residual L x - b
        let mut r = vec![0.0; n];
        dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut r);
        assert!(allclose(&r, &b, 1e-10, 1e-10));
    }

    #[test]
    fn dgemm_identity() {
        let n = 8;
        let id = Matrix::identity(n);
        let mut rng = Rng::new(4);
        let b = Matrix::random(n, n, &mut rng);
        let mut c = vec![0.0; n * n];
        dgemm(n, n, n, 1.0, &id.data, &b.data, 0.0, &mut c);
        assert!(allclose(&c, &b.data, 1e-14, 1e-14));
    }

    #[test]
    fn dsymm_matches_dense() {
        let mut rng = Rng::new(5);
        let n = 16;
        let a = Matrix::random_symmetric(n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut c1 = rng.normal_vec(n * n);
        let mut c2 = c1.clone();
        dsymm_lower(n, n, 1.2, &a.data, &b.data, 0.3, &mut c1);
        dgemm(n, n, n, 1.2, &a.data, &b.data, 0.3, &mut c2);
        assert!(allclose(&c1, &c2, 1e-12, 1e-12));
    }

    #[test]
    fn dtrmm_matches_gemm_on_tril() {
        let mut rng = Rng::new(6);
        let n = 16;
        let a = Matrix::random_lower_triangular(n, &mut rng);
        let b0 = Matrix::random(n, n, &mut rng);
        let mut b = b0.data.clone();
        dtrmm_lower(n, n, 1.5, &a.data, &mut b);
        let mut c = vec![0.0; n * n];
        dgemm(n, n, n, 1.5, &a.data, &b0.data, 0.0, &mut c);
        assert!(allclose(&b, &c, 1e-12, 1e-12));
    }

    #[test]
    fn dsyrk_matches_gemm() {
        let mut rng = Rng::new(7);
        let (n, k) = (12, 20);
        let a = Matrix::random(n, k, &mut rng);
        let c0 = Matrix::random(n, n, &mut rng);
        let mut c1 = c0.data.clone();
        dsyrk_lower(n, k, 2.0, &a.data, 0.5, &mut c1);
        let at = a.transpose();
        let mut c2 = c0.data.clone();
        dgemm(n, n, k, 2.0, &a.data, &at.data, 0.5, &mut c2);
        for i in 0..n {
            for j in 0..=i {
                assert!((c1[i * n + j] - c2[i * n + j]).abs() < 1e-12);
            }
            for j in (i + 1)..n {
                assert_eq!(c1[i * n + j], c0.data[i * n + j]); // untouched
            }
        }
    }

    #[test]
    fn dtrsm_solves() {
        let mut rng = Rng::new(8);
        let (m, n) = (24, 16);
        let a = Matrix::random_lower_triangular(m, &mut rng);
        let b = Matrix::random(m, n, &mut rng);
        let mut x = b.data.clone();
        dtrsm_llnn(m, n, &a.data, &mut x);
        let mut r = vec![0.0; m * n];
        dgemm(m, n, m, 1.0, &a.data, &x, 0.0, &mut r);
        assert!(allclose(&r, &b.data, 1e-10, 1e-10));
    }

    #[test]
    fn dtrmv_matches_gemv_on_tril() {
        let mut rng = Rng::new(9);
        let n = 16;
        let a = Matrix::random_lower_triangular(n, &mut rng);
        let x0 = rng.normal_vec(n);
        let mut x = x0.clone();
        dtrmv_lower(n, &a.data, &mut x);
        let mut y = vec![0.0; n];
        dgemv(n, n, 1.0, &a.data, &x0, 0.0, &mut y);
        assert!(allclose(&x, &y, 1e-12, 1e-12));
    }

    #[test]
    fn dsymv_matches_gemv_dense() {
        let mut rng = Rng::new(10);
        let n = 16;
        let a = Matrix::random_symmetric(n, &mut rng);
        let x = rng.normal_vec(n);
        let mut y1 = rng.normal_vec(n);
        let mut y2 = y1.clone();
        dsymv_lower(n, 0.7, &a.data, &x, 1.3, &mut y1);
        dgemv(n, n, 0.7, &a.data, &x, 1.3, &mut y2);
        assert!(allclose(&y1, &y2, 1e-12, 1e-12));
    }
}
