//! The network serving plane: a dependency-free HTTP/1.1 gateway over
//! the elastic cluster. This is the transport/execution seam the
//! ROADMAP names — everything above this module speaks bytes and
//! status codes, everything below speaks typed [`BlasRequest`]s and
//! typed admission errors, and the seam translates exactly once:
//!
//! - `POST /v1/blas` parses an `ftblas.request.v1` or `v2` envelope
//!   (routine, dims, variant, FT policy, deadline, idempotency key; v2
//!   adds the optional `routing` selection overlay — backend pin,
//!   allow/deny lists, capability requirements), builds the seeded
//!   request, admits it through
//!   [`ClusterHandle::submit_with_retry_routed`], and maps the typed
//!   outcomes onto the wire: [`Error::Overloaded`] → `429` with a
//!   `Retry-After` derived from the [`RetryPolicy`], the planner's
//!   exhaustive [`NoCandidate`](crate::coordinator::plan::NoCandidate)
//!   diagnostics → `400`, a `dim` over the gateway's cap → `413`
//!   *before* any operand is generated (operand memory is O(dim^2)),
//!   deadline exceeded → `504`, [`Error::ShuttingDown`] → `503`.
//! - `GET /healthz` / `/metrics` / `/topology` / `/campaign` /
//!   `/backends` serve the cluster's *live* operational state (the
//!   `ftblas.ledger.v1` snapshot, the routing topology with
//!   slots/salts/generation, the injection campaign's counters, the
//!   `ftblas.backends.v1` capability inventory with per-kernel
//!   selection counts) — read-only views over state that already
//!   existed; the gateway adds no shadow bookkeeping.
//!
//! Shutdown is a graceful drain: stop accepting, serve every
//! connection already admitted, then hand control back so the caller
//! can retire the cluster's ledgers exactly (`accepted == served` is
//! the drain invariant the conformance suite pins).
//!
//! Request payloads are generated server-side from the envelope's
//! `seed` (the same deterministic generators the CLI and traces use),
//! so the wire carries intent, not megabytes of operands, and a
//! response's `checksum` is reproducible by any client holding the
//! envelope. `docs/PROTOCOL.md` documents the full contract.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::blas::Impl;
use crate::config::Profile;
use crate::coordinator::cluster::{ClusterHandle, RetryPolicy};
use crate::coordinator::http::{read_request, Head, ReadError, Response};
use crate::coordinator::metrics::LEDGER_SCHEMA;
use crate::coordinator::plan::{CapRequirement, Planner, SelectionPolicy};
use crate::coordinator::registry::KernelRegistry;
use crate::coordinator::request::{Backend, BlasRequest, BlasResult};
use crate::coordinator::server::Error;
use crate::ft::policy::FtPolicy;
use crate::util::json::Json;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Schema tag of the v1 request envelope.
pub const REQUEST_SCHEMA: &str = "ftblas.request.v1";
/// Schema tag of the v2 request envelope (v1 plus the optional
/// `routing` selection overlay).
pub const REQUEST_SCHEMA_V2: &str = "ftblas.request.v2";
/// Schema tag of the success-response body.
pub const RESPONSE_SCHEMA: &str = "ftblas.response.v1";
/// Schema tag of `GET /backends` (the registry's capability
/// inventory, shared with `ftblas backends --json`).
pub const BACKENDS_SCHEMA: &str = "ftblas.backends.v1";
/// Schema tag of `GET /healthz`.
pub const HEALTH_SCHEMA: &str = "ftblas.health.v1";
/// Schema tag of `GET /topology`.
pub const TOPOLOGY_SCHEMA: &str = "ftblas.topology.v1";
/// Schema tag of `GET /campaign`.
pub const CAMPAIGN_SCHEMA: &str = "ftblas.campaign.v1";

/// Every routine the envelope accepts (the [`BlasRequest`] surface).
pub const ROUTINES: &[&str] = &[
    "dscal", "daxpy", "ddot", "dnrm2", "dasum", "drot", "drotm", "idamax",
    "dgemv", "dtrsv", "dger", "dsymv", "dtrmv", "dgemm", "dsymm", "dtrmm",
    "dtrsm", "dsyrk",
];

/// A parsed `ftblas.request.v1`/`v2` envelope. The wire carries intent
/// — routine, principal dimension, generator seed — and the gateway
/// builds the operand data deterministically from it, so two identical
/// envelopes always produce identical results (and checksums).
#[derive(Clone, Debug, PartialEq)]
pub struct Envelope {
    /// BLAS routine name (one of [`ROUTINES`]).
    pub routine: String,
    /// Principal dimension (vector length or matrix order), >= 1.
    pub dim: usize,
    /// Seed for the deterministic operand generator.
    pub seed: u64,
    /// Optional pinned kernel variant; when set, the gateway requires a
    /// kernel of exactly this variant serving the policy (no silent
    /// fallback substitution).
    pub variant: Option<Impl>,
    /// Optional FT-policy assertion; must match the policy the cluster
    /// was started with (the policy is a cluster property, not a
    /// per-request one).
    pub ft: Option<FtPolicy>,
    /// End-to-end deadline; past it the gateway answers `504`.
    pub deadline_ms: Option<u64>,
    /// Opaque client token, echoed verbatim in the response.
    pub idempotency_key: Option<String>,
    /// Request-scoped selection overlay (v2 only), merged onto the
    /// gateway's base selection with
    /// [`SelectionPolicy::merged_with`] — the request's preferences
    /// outrank the gateway's, its allowlist intersects, its denials and
    /// requirements accumulate.
    pub routing: Option<SelectionPolicy>,
}

impl Envelope {
    /// A minimal envelope for `routine` at dimension `dim`.
    pub fn new(routine: &str, dim: usize) -> Envelope {
        Envelope {
            routine: routine.to_string(),
            dim,
            seed: 7,
            variant: None,
            ft: None,
            deadline_ms: None,
            idempotency_key: None,
            routing: None,
        }
    }

    /// Serialize (the exact inverse of [`Envelope::from_json`]). An
    /// envelope without `routing` serializes as a v1 document —
    /// byte-identical to the pre-v2 wire format.
    pub fn to_json(&self) -> Json {
        let schema = if self.routing.is_some() { REQUEST_SCHEMA_V2 }
                     else { REQUEST_SCHEMA };
        let mut doc = Json::obj()
            .field("schema", Json::Str(schema.into()))
            .field("routine", Json::Str(self.routine.clone()))
            .field("dim", Json::Int(self.dim as u64))
            .field("seed", Json::Int(self.seed));
        if let Some(v) = self.variant {
            doc = doc.field("variant", Json::Str(v.name().into()));
        }
        if let Some(p) = self.ft {
            doc = doc.field("ft", Json::Str(p.name().into()));
        }
        if let Some(d) = self.deadline_ms {
            doc = doc.field("deadline_ms", Json::Int(d));
        }
        if let Some(k) = &self.idempotency_key {
            doc = doc.field("idempotency_key", Json::Str(k.clone()));
        }
        if let Some(sel) = &self.routing {
            doc = doc.field("routing", routing_to_json(sel));
        }
        doc
    }

    /// Decode an envelope from a parsed document. Unknown fields are
    /// ignored (forward compatibility); known fields with the wrong
    /// type or value are errors, not defaults. Both schema versions
    /// parse here; `routing` is the one v2-only field.
    pub fn from_json(doc: &Json) -> std::result::Result<Envelope, String> {
        let v2 = match doc.get("schema").and_then(Json::as_str) {
            Some(REQUEST_SCHEMA) => false,
            Some(REQUEST_SCHEMA_V2) => true,
            other => {
                return Err(format!(
                    "not an {REQUEST_SCHEMA} or {REQUEST_SCHEMA_V2} \
                     envelope (schema {other:?})"))
            }
        };
        let routine = doc
            .get("routine")
            .and_then(Json::as_str)
            .ok_or("missing required string field `routine`")?
            .to_string();
        let uint = |field: &str| -> std::result::Result<Option<u64>, String> {
            match doc.get(field) {
                None => Ok(None),
                Some(Json::Int(v)) => Ok(Some(*v)),
                Some(other) => Err(format!(
                    "field `{field}` wants an unsigned integer, got \
                     {other:?}")),
            }
        };
        let dim64 = uint("dim")?
            .ok_or("missing required integer field `dim`")?;
        if dim64 == 0 {
            return Err("`dim` must be >= 1".into());
        }
        let dim = usize::try_from(dim64)
            .map_err(|_| format!("`dim` {dim64} does not fit this host"))?;
        let seed = uint("seed")?.unwrap_or(7);
        let variant = match doc.get("variant").map(|v| v.as_str()) {
            None => None,
            Some(Some(name)) => Some(Impl::by_name(name).ok_or(format!(
                "unknown variant `{name}` (want naive|blocked|tuned|\
                 simd)"))?),
            Some(None) => return Err("field `variant` wants a string".into()),
        };
        let ft = match doc.get("ft").map(|v| v.as_str()) {
            None => None,
            Some(Some(name)) => Some(FtPolicy::by_name(name).ok_or(
                format!("unknown ft policy `{name}`"))?),
            Some(None) => return Err("field `ft` wants a string".into()),
        };
        let deadline_ms = match uint("deadline_ms")? {
            Some(0) => return Err("`deadline_ms` must be >= 1".into()),
            other => other,
        };
        let idempotency_key = match doc.get("idempotency_key") {
            None => None,
            Some(Json::Str(s)) => Some(s.clone()),
            Some(_) => {
                return Err("field `idempotency_key` wants a string".into())
            }
        };
        let routing = match doc.get("routing") {
            None => None,
            Some(_) if !v2 => {
                return Err(format!(
                    "field `routing` requires schema {REQUEST_SCHEMA_V2}"))
            }
            Some(spec) => Some(routing_from_json(spec)?),
        };
        Ok(Envelope { routine, dim, seed, variant, ft, deadline_ms,
                      idempotency_key, routing })
    }

    /// Parse an envelope straight from body text.
    pub fn parse(text: &str) -> std::result::Result<Envelope, String> {
        Envelope::from_json(&Json::parse(text)
            .map_err(|e| format!("malformed JSON: {e}"))?)
    }

    /// Build the typed request: operands generated deterministically
    /// from `(seed, dim)` — the same generators the CLI's `run` command
    /// uses. `None` for a routine outside [`ROUTINES`].
    pub fn build_request(&self) -> Option<BlasRequest> {
        let n = self.dim;
        let mut rng = Rng::new(self.seed);
        Some(match self.routine.as_str() {
            "dscal" => BlasRequest::Dscal { alpha: 1.5,
                                            x: rng.normal_vec(n) },
            "daxpy" => BlasRequest::Daxpy { alpha: 0.5,
                                            x: rng.normal_vec(n),
                                            y: rng.normal_vec(n) },
            "ddot" => BlasRequest::Ddot { x: rng.normal_vec(n),
                                          y: rng.normal_vec(n) },
            "dnrm2" => BlasRequest::Dnrm2 { x: rng.normal_vec(n) },
            "dasum" => BlasRequest::Dasum { x: rng.normal_vec(n) },
            "drot" => BlasRequest::Drot { x: rng.normal_vec(n),
                                          y: rng.normal_vec(n),
                                          c: 0.6, s: 0.8 },
            "drotm" => BlasRequest::Drotm {
                x: rng.normal_vec(n), y: rng.normal_vec(n),
                param: [-1.0, 0.9, -0.2, 0.3, 1.1],
            },
            "idamax" => BlasRequest::Idamax { x: rng.normal_vec(n) },
            "dgemv" => BlasRequest::Dgemv {
                alpha: 1.0, a: Matrix::random(n, n, &mut rng),
                x: rng.normal_vec(n), beta: 0.0, y: rng.normal_vec(n),
            },
            "dtrsv" => BlasRequest::Dtrsv {
                a: Matrix::random_lower_triangular(n, &mut rng),
                b: rng.normal_vec(n),
            },
            "dger" => BlasRequest::Dger {
                alpha: 1.0, x: rng.normal_vec(n), y: rng.normal_vec(n),
                a: Matrix::random(n, n, &mut rng),
            },
            "dsymv" => BlasRequest::Dsymv {
                alpha: 1.0, a: Matrix::random_symmetric(n, &mut rng),
                x: rng.normal_vec(n), beta: 0.0, y: rng.normal_vec(n),
            },
            "dtrmv" => BlasRequest::Dtrmv {
                a: Matrix::random_lower_triangular(n, &mut rng),
                x: rng.normal_vec(n),
            },
            "dgemm" => BlasRequest::Dgemm {
                alpha: 1.0, a: Matrix::random(n, n, &mut rng),
                b: Matrix::random(n, n, &mut rng), beta: 0.0,
                c: Matrix::zeros(n, n),
            },
            "dsymm" => BlasRequest::Dsymm {
                alpha: 1.0, a: Matrix::random_symmetric(n, &mut rng),
                b: Matrix::random(n, n, &mut rng), beta: 0.0,
                c: Matrix::zeros(n, n),
            },
            "dtrmm" => BlasRequest::Dtrmm {
                alpha: 1.0,
                a: Matrix::random_lower_triangular(n, &mut rng),
                b: Matrix::random(n, n, &mut rng),
            },
            "dtrsm" => BlasRequest::Dtrsm {
                a: Matrix::random_lower_triangular(n, &mut rng),
                b: Matrix::random(n, n, &mut rng),
            },
            "dsyrk" => BlasRequest::Dsyrk {
                alpha: 1.0, a: Matrix::random(n, n, &mut rng), beta: 0.0,
                c: Matrix::zeros(n, n),
            },
            _ => return None,
        })
    }
}

/// Serialize a selection overlay as the v2 `routing` object. Empty
/// lists are omitted; the `backend` pin shorthand is input-only sugar,
/// so serialization always uses the explicit lists.
fn routing_to_json(sel: &SelectionPolicy) -> Json {
    let names = |list: &[Backend]| {
        Json::Arr(list.iter().map(|b| Json::Str(b.name().into())).collect())
    };
    let mut doc = Json::obj();
    if !sel.prefer.is_empty() {
        doc = doc.field("prefer", names(&sel.prefer));
    }
    if !sel.allow.is_empty() {
        doc = doc.field("allow", names(&sel.allow));
    }
    if !sel.deny.is_empty() {
        doc = doc.field("deny", names(&sel.deny));
    }
    if !sel.require.is_empty() {
        doc = doc.field("require", Json::Arr(
            sel.require.iter().map(|r| Json::Str(r.describe())).collect()));
    }
    doc
}

/// Decode the v2 `routing` object: `backend` (a hard pin — sugar for
/// prefer+allow of that one backend), `prefer`, `allow`, `deny`
/// (backend-name arrays), and `require` (`cap=value` strings).
fn routing_from_json(doc: &Json) -> std::result::Result<SelectionPolicy,
                                                        String> {
    let mut sel = SelectionPolicy::default();
    if let Some(v) = doc.get("backend") {
        let name = v.as_str()
            .ok_or("field `routing.backend` wants a string")?;
        let be = Backend::by_name(name).ok_or_else(|| format!(
            "unknown backend `{name}` (want naive|blocked|tuned|simd|\
             pjrt|gpu-sim)"))?;
        sel = SelectionPolicy::pinned(be);
    }
    let backends = |field: &str| -> std::result::Result<Vec<Backend>,
                                                        String> {
        match doc.get(field) {
            None => Ok(Vec::new()),
            Some(Json::Arr(items)) => items
                .iter()
                .map(|v| {
                    let name = v.as_str().ok_or_else(|| format!(
                        "field `routing.{field}` wants backend-name \
                         strings"))?;
                    Backend::by_name(name).ok_or_else(|| format!(
                        "unknown backend `{name}` in `routing.{field}`"))
                })
                .collect(),
            Some(_) => Err(format!(
                "field `routing.{field}` wants an array")),
        }
    };
    for be in backends("prefer")? {
        if !sel.prefer.contains(&be) {
            sel.prefer.push(be);
        }
    }
    for be in backends("allow")? {
        if !sel.allow.contains(&be) {
            sel.allow.push(be);
        }
    }
    for be in backends("deny")? {
        if !sel.deny.contains(&be) {
            sel.deny.push(be);
        }
    }
    match doc.get("require") {
        None => {}
        Some(Json::Arr(items)) => {
            for v in items {
                let spec = v.as_str().ok_or(
                    "field `routing.require` wants `cap=value` strings")?;
                let (key, value) = spec.split_once('=').ok_or_else(
                    || format!("requirement `{spec}` wants `cap=value`"))?;
                sel.require.push(CapRequirement::parse(key, value)?);
            }
        }
        Some(_) => {
            return Err("field `routing.require` wants an array".into())
        }
    }
    Ok(sel)
}

/// Deterministic scalar digest of a result — the reproducibility
/// anchor of the 200 response (any holder of the envelope can recompute
/// it from an identical execution).
pub fn result_checksum(result: &BlasResult) -> f64 {
    match result {
        BlasResult::Scalar(v) => *v,
        BlasResult::Vector(v) => v.iter().sum(),
        BlasResult::Matrix(m) => m.data.iter().sum(),
    }
}

/// Gateway sizing and policy knobs.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// HTTP worker threads draining the accept queue.
    pub workers: usize,
    /// Retry policy wrapped around admission (`Overloaded` sheds ride
    /// out with jittered backoff before the gateway answers `429`).
    pub retry: RetryPolicy,
    /// The gateway's base selection policy — backend preferences,
    /// allow/deny lists, and capability requirements applied to every
    /// request (match the cluster router's selection). A v2 envelope's
    /// `routing` object overlays onto this per request.
    pub selection: SelectionPolicy,
    /// Ceiling on any request's end-to-end deadline (envelopes may ask
    /// for less, never more).
    pub max_deadline: Duration,
    /// Ceiling on the envelope's principal dimension. Operand memory is
    /// O(dim^2) for the matrix routines (a dgemm builds three n*n f64
    /// matrices server-side), so an unbounded `dim` would let one small
    /// POST drive an arbitrarily large allocation; past this cap the
    /// gateway answers `413` before generating any operands.
    pub max_dim: usize,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig {
            workers: 4,
            retry: RetryPolicy::default(),
            selection: SelectionPolicy::for_backend(Backend::NativeTuned),
            max_deadline: Duration::from_secs(30),
            // three 4096^2 f64 matrices ~ 400 MB, the default worst case
            max_dim: 4096,
        }
    }
}

/// Drain accounting, returned by [`Gateway::shutdown`]. The invariant
/// the conformance suite pins: after a graceful drain,
/// `accepted == served` — every connection the accept loop admitted
/// was handled to completion, none abandoned.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatewayStats {
    /// Connections the accept loop enqueued.
    pub accepted: u64,
    /// Connections fully handled (response written or peer gone).
    pub served: u64,
    /// Responses in the 2xx class.
    pub s2xx: u64,
    /// Responses in the 4xx class.
    pub s4xx: u64,
    /// Responses in the 5xx class (504 included).
    pub s5xx: u64,
}

struct Shared {
    cluster: ClusterHandle,
    profile: Profile,
    policy: FtPolicy,
    cfg: GatewayConfig,
    draining: AtomicBool,
    accepted: AtomicU64,
    served: AtomicU64,
    s2xx: AtomicU64,
    s4xx: AtomicU64,
    s5xx: AtomicU64,
}

impl Shared {
    fn count(&self, status: u16) {
        match status {
            200..=299 => self.s2xx.fetch_add(1, Ordering::Relaxed),
            400..=499 => self.s4xx.fetch_add(1, Ordering::Relaxed),
            _ => self.s5xx.fetch_add(1, Ordering::Relaxed),
        };
    }

    fn stats(&self) -> GatewayStats {
        GatewayStats {
            accepted: self.accepted.load(Ordering::Relaxed),
            served: self.served.load(Ordering::Relaxed),
            s2xx: self.s2xx.load(Ordering::Relaxed),
            s4xx: self.s4xx.load(Ordering::Relaxed),
            s5xx: self.s5xx.load(Ordering::Relaxed),
        }
    }
}

/// The running gateway: one accept thread feeding `workers` handler
/// threads over a channel. Dropping without [`Gateway::shutdown`]
/// drains the same way (no detached threads survive).
pub struct Gateway {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `addr` (port 0 picks an ephemeral port) and start serving
    /// the cluster behind `handle`. `profile` and `policy` must be the
    /// ones the cluster was started with — the gateway plans preflight
    /// checks against them.
    pub fn bind(addr: &str, handle: ClusterHandle, profile: Profile,
                policy: FtPolicy, cfg: GatewayConfig) -> Result<Gateway> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("gateway cannot bind {addr}: {e}"))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            cluster: handle,
            profile,
            policy,
            cfg: cfg.clone(),
            draining: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            served: AtomicU64::new(0),
            s2xx: AtomicU64::new(0),
            s4xx: AtomicU64::new(0),
            s5xx: AtomicU64::new(0),
        });
        let (tx, rx) = channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let shared = shared.clone();
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("ftblas-gw-{i}"))
                    .spawn(move || worker_loop(shared, rx))
                    .expect("spawn gateway worker")
            })
            .collect();
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("ftblas-gw-accept".to_string())
                .spawn(move || accept_loop(listener, shared, tx))
                .expect("spawn gateway accept loop")
        };
        Ok(Gateway { shared, local_addr, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Live counters (also available after shutdown via the return
    /// value of [`Gateway::shutdown`]).
    pub fn stats(&self) -> GatewayStats {
        self.shared.stats()
    }

    /// Graceful drain: stop accepting, let the workers finish every
    /// connection already admitted, join all threads, return the final
    /// accounting. The cluster handle stays valid — retire its ledgers
    /// (via `Cluster::shutdown`) after this returns for exact counts.
    pub fn shutdown(mut self) -> GatewayStats {
        self.halt();
        self.shared.stats()
    }

    fn halt(&mut self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        // the accept loop is parked in accept(2); poke it awake with a
        // loopback connection it will see the drain flag on
        let _ = TcpStream::connect(self.local_addr);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        // the accept thread dropped the sender; workers drain the
        // channel backlog and exit on the disconnect
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.halt();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>,
               tx: Sender<TcpStream>) {
    for stream in listener.incoming() {
        if shared.draining.load(Ordering::SeqCst) {
            // the wake-up (or a late client) connected after the drain
            // flag: close it unserved and stop accepting
            break;
        }
        if let Ok(stream) = stream {
            shared.accepted.fetch_add(1, Ordering::Relaxed);
            if tx.send(stream).is_err() {
                break;
            }
        }
    }
    // dropping `tx` here releases the workers once the backlog drains
}

fn worker_loop(shared: Arc<Shared>, rx: Arc<Mutex<Receiver<TcpStream>>>) {
    loop {
        let stream = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match stream {
            Ok(stream) => serve_connection(&shared, stream),
            Err(_) => break, // accept loop gone, backlog drained
        }
    }
}

/// Handle one connection end to end. Every admitted connection counts
/// as served exactly once, whatever happens on the wire — the drain
/// invariant's other half.
fn serve_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_nodelay(true);
    match read_request(&mut stream) {
        Ok((head, body)) => {
            let resp = route(shared, &head, &body);
            shared.count(resp.status);
            let _ = resp.write_to(&mut stream);
        }
        Err(ReadError::Parse(e)) => {
            let resp = error_response(e.status(), &e.to_string());
            shared.count(resp.status);
            let _ = resp.write_to(&mut stream);
        }
        Err(ReadError::Io(_)) | Err(ReadError::Closed) => {
            // transport died or the peer never sent a request (the
            // shutdown wake-up lands here when a worker wins the race
            // for it); nothing is owed
        }
    }
    shared.served.fetch_add(1, Ordering::Relaxed);
}

fn error_response(status: u16, message: &str) -> Response {
    Response::json(status, &Json::obj()
        .field("error", Json::Str(message.into()))
        .field("status", Json::Int(status as u64)))
}

fn route(shared: &Shared, head: &Head, body: &[u8]) -> Response {
    match (head.method.as_str(), head.target.as_str()) {
        ("POST", "/v1/blas") => submit(shared, body),
        ("GET", "/healthz") => healthz(shared),
        ("GET", "/metrics") => metrics(shared),
        ("GET", "/topology") => topology(shared),
        ("GET", "/campaign") => campaign(shared),
        ("GET", "/backends") => backends(shared),
        (_, "/v1/blas") => {
            error_response(405, "POST only").header("allow", "POST")
        }
        (_, "/healthz" | "/metrics" | "/topology" | "/campaign"
            | "/backends") => {
            error_response(405, "GET only").header("allow", "GET")
        }
        (_, target) => Response::json(404, &Json::obj()
            .field("error", Json::Str(format!("no route `{target}`")))
            .field("routes", Json::Arr(
                ["/v1/blas", "/healthz", "/metrics", "/topology",
                 "/campaign", "/backends"]
                    .iter()
                    .map(|r| Json::Str((*r).into()))
                    .collect()))),
    }
}

/// The `Retry-After` pair derived from the retry policy: the backoff
/// step a client should wait after the gateway itself exhausted
/// `attempts` retries — the next step of the same exponential,
/// clamped at the policy's cap. Whole seconds for the header (HTTP
/// grammar), exact milliseconds in the body.
fn retry_after(policy: &RetryPolicy) -> (u64, u64) {
    let step = policy
        .base
        .saturating_mul(1u32 << policy.attempts.min(20))
        .min(policy.cap)
        .max(policy.base);
    let ms = (step.as_millis() as u64).max(1);
    let secs = (step.as_secs_f64().ceil() as u64).max(1);
    (secs, ms)
}

fn submit(shared: &Shared, body: &[u8]) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(text) => text,
        Err(_) => return error_response(400, "body is not UTF-8"),
    };
    let env = match Envelope::parse(text) {
        Ok(env) => env,
        Err(msg) => return error_response(400, &msg),
    };
    if let Some(asked) = env.ft {
        if asked != shared.policy {
            return error_response(400, &format!(
                "ft policy mismatch: this gateway serves `{}`, the \
                 envelope asked for `{}` (the policy is a cluster \
                 property; drop the field or match it)",
                shared.policy.name(), asked.name()));
        }
    }
    if !ROUTINES.contains(&env.routine.as_str()) {
        return Response::json(400, &Json::obj()
            .field("error", Json::Str(format!(
                "unknown routine `{}`", env.routine)))
            .field("routines", Json::Arr(
                ROUTINES.iter().map(|r| Json::Str((*r).into()))
                    .collect())));
    }
    // every refusal must fire before build_request: operand generation
    // is O(dim^2) memory for the matrix routines, so nothing may
    // allocate until the envelope is fully admitted
    if env.dim > shared.cfg.max_dim {
        return Response::json(413, &Json::obj()
            .field("error", Json::Str(format!(
                "`dim` {} exceeds this gateway's cap of {} (operand \
                 memory is O(dim^2); raise --max-dim to serve larger \
                 requests)", env.dim, shared.cfg.max_dim)))
            .field("max_dim", Json::Int(shared.cfg.max_dim as u64)));
    }
    if let Err(diag) = preflight(shared, &env) {
        return error_response(400, &diag);
    }
    if shared.draining.load(Ordering::SeqCst) {
        return error_response(503, "gateway is draining");
    }
    let req = match env.build_request() {
        Some(req) => req,
        // unreachable: ROUTINES gated above and the two tables are
        // pinned equal by `every_listed_routine_builds_a_request`
        None => return error_response(500, "routine table out of sync"),
    };
    let deadline = env
        .deadline_ms
        .map(Duration::from_millis)
        .unwrap_or(shared.cfg.max_deadline)
        .min(shared.cfg.max_deadline);
    let started = std::time::Instant::now();
    let (admitted, retries) = shared.cluster.submit_with_retry_routed(
        req, &shared.cfg.retry, env.routing.as_ref());
    let rx = match admitted {
        Ok(rx) => rx,
        Err(e @ Error::Overloaded { .. }) => {
            let (secs, ms) = retry_after(&shared.cfg.retry);
            return Response::json(429, &e.to_json()
                .field("retries", Json::Int(retries as u64))
                .field("retry_after_ms", Json::Int(ms)))
                .header("retry-after", &secs.to_string());
        }
        // preflight runs the same selection, so this arm only fires
        // when the cluster's base selection is stricter than the
        // gateway's — still a client-addressable 400
        Err(e @ Error::NoCandidate { .. }) => {
            return Response::json(400, &e.to_json());
        }
        Err(e @ Error::ShuttingDown { .. }) => {
            return Response::json(503, &e.to_json());
        }
    };
    let wait = deadline.saturating_sub(started.elapsed());
    match rx.recv_timeout(wait) {
        Ok(Ok(resp)) => {
            let mut doc = Json::obj()
                .field("schema", Json::Str(RESPONSE_SCHEMA.into()))
                .field("routine", Json::Str(env.routine.clone()))
                .field("dim", Json::Int(env.dim as u64))
                .field("seed", Json::Int(env.seed))
                .field("kernel", Json::Str(resp.kernel.into()))
                .field("backend", Json::Str(resp.backend.name().into()))
                .field("policy", Json::Str(shared.policy.name().into()))
                .field("exec_seconds", Json::Num(resp.exec_seconds))
                .field("retries", Json::Int(retries as u64))
                .field("ft", Json::obj()
                    .field("detected", Json::Int(resp.ft.errors_detected))
                    .field("corrected",
                           Json::Int(resp.ft.errors_corrected)))
                .field("checksum",
                       Json::Num(result_checksum(&resp.result)));
            if let Some(key) = &env.idempotency_key {
                doc = doc.field("idempotency_key", Json::Str(key.clone()));
            }
            Response::json(200, &doc)
        }
        Ok(Err(e)) => error_response(500, &format!("execution failed: {e}")),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            // the gateway abandons the *wait*, not the work: the
            // admitted request keeps executing in the cluster and will
            // land in /metrics. Say so in the body — a client retrying
            // a 504 immediately doubles the load exactly when the
            // system is slowest (docs/PROTOCOL.md, "504 semantics").
            Response::json(504, &Json::obj()
                .field("error", Json::Str("deadline exceeded".into()))
                .field("deadline_ms",
                       Json::Int(deadline.as_millis() as u64))
                .field("request_abandoned", Json::Bool(false))
                .field("note", Json::Str(
                    "the admitted request keeps executing and will be \
                     accounted in /metrics; back off before retrying"
                        .into())))
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            error_response(500, "cluster dropped the request")
        }
    }
}

/// Planner preflight: refuse up front what execution could never
/// serve, with the planner's own exhaustive diagnostics (every
/// considered descriptor and the capability it missed). A pinned v1
/// `variant` stays a strict admission-time assertion — the selection
/// ladder would silently substitute a different kernel, which is
/// exactly what a client pinning a variant does not want.
fn preflight(shared: &Shared, env: &Envelope)
             -> std::result::Result<(), String> {
    let policy = shared.policy;
    if let Some(v) = env.variant {
        let registry = KernelRegistry::global();
        let serves = registry
            .for_routine(&env.routine)
            .into_iter()
            .any(|k| k.supports(policy) && k.variant == v);
        if !serves {
            return Err(format!(
                "no candidate kernel: routine `{}` has no `{}`-variant \
                 kernel serving policy `{}` (drop the pin or pick a \
                 served variant)",
                env.routine, v.name(), policy.name()));
        }
        return Ok(());
    }
    let sel = match &env.routing {
        Some(overlay) => shared.cfg.selection.merged_with(overlay),
        None => shared.cfg.selection.clone(),
    };
    Planner::new(&shared.profile)
        .select_dims(&env.routine, env.dim, &sel, policy)
        .map(|_| ())
        .map_err(|e| e.to_string())
}

fn healthz(shared: &Shared) -> Response {
    let snap = shared.cluster.metrics();
    let (ups, downs) = shared.cluster.scale_events();
    let draining = shared.draining.load(Ordering::SeqCst);
    let pooled = !shared.profile.no_pool;
    let doc = Json::obj()
        .field("schema", Json::Str(HEALTH_SCHEMA.into()))
        .field("status", Json::Str(
            if draining { "draining" } else { "ok" }.into()))
        .field("shards", Json::Int(shared.cluster.shard_count() as u64))
        .field("scale_ups", Json::Int(ups))
        .field("scale_downs", Json::Int(downs))
        .field("pool", Json::obj()
            .field("enabled", Json::Bool(pooled))
            .field("workers", Json::Int(snap.pool.workers))
            .field("live", Json::Bool(!pooled || snap.pool.workers > 0))
            .field("tasks_submitted", Json::Int(snap.pool.tasks_submitted))
            .field("tasks_executed", Json::Int(snap.pool.tasks_executed)))
        .field("campaign", Json::Str(
            if shared.cluster.campaign().is_some() { "active" }
            else { "none" }.into()))
        .field("policy", Json::Str(shared.policy.name().into()));
    Response::json(200, &doc)
}

fn metrics(shared: &Shared) -> Response {
    // the exact merged ledger — the same ftblas.ledger.v1 document the
    // soak report embeds, served live
    let doc = shared.cluster.metrics().to_json();
    debug_assert_eq!(doc.get("schema").and_then(Json::as_str),
                     Some(LEDGER_SCHEMA));
    Response::json(200, &doc)
}

fn topology(shared: &Shared) -> Response {
    let topo = shared.cluster.topology();
    let doc = Json::obj()
        .field("schema", Json::Str(TOPOLOGY_SCHEMA.into()))
        .field("shards", Json::Arr(topo.shards.iter().map(|s| {
            Json::obj()
                .field("slot", Json::Int(s.slot as u64))
                .field("salt", Json::Int(s.salt))
                .field("queue_depth", Json::Int(s.queue_depth as u64))
        }).collect()))
        .field("next_generation", Json::Int(topo.next_generation))
        .field("scale_ups", Json::Int(topo.scale_ups))
        .field("scale_downs", Json::Int(topo.scale_downs));
    Response::json(200, &doc)
}

fn campaign(shared: &Shared) -> Response {
    let doc = match shared.cluster.campaign() {
        None => Json::obj()
            .field("schema", Json::Str(CAMPAIGN_SCHEMA.into()))
            .field("active", Json::Bool(false)),
        Some(c) => {
            let cfg = c.config();
            Json::obj()
                .field("schema", Json::Str(CAMPAIGN_SCHEMA.into()))
                .field("active", Json::Bool(true))
                .field("seed", Json::Int(cfg.seed))
                .field("rate_per_min", Json::Num(cfg.rate_per_min))
                .field("stride", Json::Int(cfg.stride))
                .field("target", Json::Str(cfg.target.name().into()))
                .field("injected", Json::Int(c.injected()))
                .field("suppressed", Json::Int(c.suppressed()))
        }
    };
    Response::json(200, &doc)
}

fn backends(shared: &Shared) -> Response {
    // the exact ftblas.backends.v1 inventory — the same serializer the
    // `ftblas backends` subcommand prints, with live selection counts
    // and the attached PJRT backend's health probe
    let doc = shared.cluster.backends_json();
    debug_assert_eq!(doc.get("schema").and_then(Json::as_str),
                     Some(BACKENDS_SCHEMA));
    Response::json(200, &doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelope_round_trips_through_json() {
        let env = Envelope {
            routine: "dgemm".into(),
            dim: 96,
            seed: 0xABCD,
            variant: Some(Impl::Simd),
            ft: Some(FtPolicy::Hybrid),
            deadline_ms: Some(2500),
            idempotency_key: Some("req-\"quoted\"/π".into()),
            routing: None,
        };
        let text = env.to_json().render();
        assert!(text.contains(REQUEST_SCHEMA),
                "routing-free envelopes stay on the v1 wire format");
        assert_eq!(Envelope::parse(&text).unwrap(), env);
        // minimal envelope: optional fields default
        let min = Envelope::new("ddot", 64);
        assert_eq!(Envelope::parse(&min.to_json().render()).unwrap(), min);
    }

    #[test]
    fn v2_routing_round_trips_and_desugars_the_pin() {
        let mut env = Envelope::new("dgemm", 48);
        env.routing = Some(SelectionPolicy {
            prefer: vec![Backend::GpuSim],
            allow: vec![Backend::GpuSim, Backend::NativeTuned],
            deny: vec![Backend::Pjrt],
            require: vec![CapRequirement::Threaded(false)],
        });
        let text = env.to_json().render();
        assert!(text.contains(REQUEST_SCHEMA_V2),
                "an envelope carrying routing serializes as v2");
        assert_eq!(Envelope::parse(&text).unwrap(), env);
        // the `backend` shorthand pins: prefer + allow of that backend
        let pinned = Envelope::parse(
            r#"{"schema":"ftblas.request.v2","routine":"dgemm","dim":8,
                "routing":{"backend":"gpu-sim"}}"#).unwrap();
        assert_eq!(pinned.routing.unwrap(),
                   SelectionPolicy::pinned(Backend::GpuSim));
    }

    #[test]
    fn envelope_rejects_bad_documents() {
        for (body, needle) in [
            ("{}", "schema"),
            (r#"{"schema":"ftblas.request.v3","routine":"ddot","dim":4}"#,
             "schema"),
            (r#"{"schema":"ftblas.request.v1","dim":4}"#, "routine"),
            (r#"{"schema":"ftblas.request.v1","routine":"ddot"}"#, "dim"),
            (r#"{"schema":"ftblas.request.v1","routine":"ddot","dim":0}"#,
             "dim"),
            (r#"{"schema":"ftblas.request.v1","routine":"ddot","dim":4,
                 "variant":"mkl"}"#, "variant"),
            (r#"{"schema":"ftblas.request.v1","routine":"ddot","dim":4,
                 "deadline_ms":0}"#, "deadline_ms"),
            (r#"{"schema":"ftblas.request.v1","routine":"ddot","dim":4,
                 "routing":{"backend":"pjrt"}}"#, "routing"),
            (r#"{"schema":"ftblas.request.v2","routine":"ddot","dim":4,
                 "routing":{"backend":"mkl"}}"#, "backend"),
            (r#"{"schema":"ftblas.request.v2","routine":"ddot","dim":4,
                 "routing":{"deny":["tpu"]}}"#, "deny"),
            (r#"{"schema":"ftblas.request.v2","routine":"ddot","dim":4,
                 "routing":{"require":["precision"]}}"#, "cap=value"),
            (r#"{"schema":"ftblas.request.v2","routine":"ddot","dim":4,
                 "routing":{"require":["scheme=tmr"]}}"#, "scheme"),
            ("not json at all", "JSON"),
        ] {
            let err = Envelope::parse(body).unwrap_err();
            assert!(err.contains(needle),
                    "`{err}` should mention {needle} for {body}");
        }
    }

    #[test]
    fn every_listed_routine_builds_a_request() {
        for r in ROUTINES {
            let env = Envelope::new(r, 8);
            let req = env.build_request()
                .unwrap_or_else(|| panic!("{r} must build"));
            assert_eq!(req.routine(), *r);
        }
        assert!(Envelope::new("zgemm", 8).build_request().is_none());
    }

    #[test]
    fn identical_envelopes_build_identical_requests() {
        let env = Envelope::new("ddot", 32);
        let (a, b) = (env.build_request().unwrap(),
                      env.build_request().unwrap());
        match (a, b) {
            (BlasRequest::Ddot { x: xa, y: ya },
             BlasRequest::Ddot { x: xb, y: yb }) => {
                assert_eq!(xa, xb);
                assert_eq!(ya, yb);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn retry_after_derives_from_the_policy() {
        let policy = RetryPolicy::default();
        let (secs, ms) = retry_after(&policy);
        // default: 500us * 2^5 = 16ms, under the 20ms cap
        assert_eq!(ms, 16);
        assert_eq!(secs, 1, "sub-second backoff still advertises >= 1s");
        let long = RetryPolicy {
            attempts: 3,
            base: Duration::from_secs(1),
            cap: Duration::from_secs(6),
            jitter_seed: 1,
        };
        assert_eq!(retry_after(&long), (6, 6000), "cap clamps the step");
    }

    #[test]
    fn checksums_are_deterministic_per_result_kind() {
        assert_eq!(result_checksum(&BlasResult::Scalar(2.5)), 2.5);
        assert_eq!(result_checksum(&BlasResult::Vector(vec![1.0, 2.0])),
                   3.0);
        let m = Matrix { rows: 1, cols: 2, data: vec![3.0, 4.0] };
        assert_eq!(result_checksum(&BlasResult::Matrix(m)), 7.0);
    }
}
