//! The threaded serving loop: clients submit [`BlasRequest`]s and receive
//! [`BlasResponse`]s over per-request channels; a worker pool drains the
//! batching queue through the router; an optional injector arms planned
//! faults (the error-injection experiments of paper §6.3 run through
//! exactly this path).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::request::{BlasRequest, BlasResponse};
use crate::coordinator::router::Router;
use crate::ft::injector::{Injector, InjectorConfig};
use crate::ft::policy::FtPolicy;

struct Job {
    req: BlasRequest,
    enqueued: Instant,
    reply: Sender<Result<BlasResponse>>,
}

struct Shared {
    batcher: Mutex<Batcher<Job>>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    injector: Mutex<Injector>,
    steps: AtomicU64,
}

/// Handle for submitting requests; cheap to clone.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: BlasRequest) -> Receiver<Result<BlasResponse>> {
        let (reply, rx) = channel();
        let key = req.batch_key();
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push(key, Job { req, enqueued: Instant::now(), reply });
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Submit and wait.
    pub fn call(&self, req: BlasRequest) -> Result<BlasResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }
}

/// The server: a worker pool over one shared router.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start with `workers` native worker threads. The router (and its
    /// PJRT handle, which is Send) is shared read-only.
    pub fn start(router: Router, policy: FtPolicy, workers: usize,
                 injection: Option<InjectorConfig>,
                 expected_requests: usize) -> Server {
        let injector = match injection {
            Some(cfg) => {
                // plan faults across the expected request stream; positions
                // are interpreted per-routine inside the router
                Injector::plan(&cfg, expected_requests.max(1), 64, 64)
            }
            None => Injector::empty(),
        };
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(16)),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::new(),
            injector: Mutex::new(injector),
            steps: AtomicU64::new(0),
        });
        let router = Arc::new(router);
        let workers = (0..workers.max(1))
            .map(|w| {
                let shared = shared.clone();
                let router = router.clone();
                std::thread::Builder::new()
                    .name(format!("ftblas-worker-{w}"))
                    .spawn(move || worker_loop(shared, router, policy))
                    .expect("spawn worker")
            })
            .collect();
        Server { shared, workers }
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Stop accepting work and join the workers (pending jobs finish).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, router: Arc<Router>, policy: FtPolicy) {
    loop {
        let batch = {
            let mut b = shared.batcher.lock().unwrap();
            loop {
                if !b.is_empty() {
                    break b.next_batch();
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(b, std::time::Duration::from_millis(50))
                    .unwrap();
                b = guard;
            }
        };
        for pending in batch {
            let job = pending.item;
            let step = shared.steps.fetch_add(1, Ordering::SeqCst) as usize;
            let fault = {
                let mut inj = shared.injector.lock().unwrap();
                inj.take(step).map(|mut f| {
                    // clamp the planned position into this request's range
                    let dim = job.req.dim();
                    f.i %= dim.max(1);
                    f.j %= dim.max(1);
                    f.step = 1; // strike the second panel/chunk when stepped
                    f
                })
            };
            let injected = fault.is_some() as u64;
            match router.execute(&job.req, policy, fault) {
                Ok(resp) => {
                    shared.metrics.record_completion(
                        job.req.routine(),
                        resp.exec_seconds,
                        job.enqueued.elapsed().as_secs_f64(),
                        resp.ft.errors_detected,
                        resp.ft.errors_corrected,
                        injected,
                    );
                    let _ = job.reply.send(Ok(resp));
                }
                Err(e) => {
                    shared.metrics.record_failure();
                    let _ = job.reply.send(Err(e));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;
    use crate::coordinator::request::Backend;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    fn native_server(policy: FtPolicy, inj: Option<InjectorConfig>) -> Server {
        let router = Router::native_only(Profile::default(), Backend::NativeTuned);
        Server::start(router, policy, 3, inj, 64)
    }

    #[test]
    fn serves_concurrent_requests() {
        let server = native_server(FtPolicy::None, None);
        let handle = server.handle();
        let mut rng = Rng::new(5);
        let reqs: Vec<BlasRequest> = (0..24)
            .map(|i| {
                if i % 2 == 0 {
                    BlasRequest::Ddot { x: rng.normal_vec(256), y: rng.normal_vec(256) }
                } else {
                    BlasRequest::Dscal { alpha: 2.0, x: rng.normal_vec(128) }
                }
            })
            .collect();
        let rxs: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.ft.errors_detected, 0);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 24);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn injection_is_detected_and_corrected() {
        let cfg = InjectorConfig { count: 8, ..Default::default() };
        let server = native_server(FtPolicy::Hybrid, Some(cfg));
        let handle = server.handle();
        let mut rng = Rng::new(6);
        let l = Matrix::random_lower_triangular(64, &mut rng);
        let mut oracle = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..32 {
            let b = rng.normal_vec(64);
            let mut want = b.clone();
            crate::blas::naive::dtrsv_lower(64, &l.data, &mut want);
            oracle.push(want);
            rxs.push(handle.submit(BlasRequest::Dtrsv { a: l.clone(), b }));
        }
        for (rx, want) in rxs.into_iter().zip(oracle) {
            let resp = rx.recv().unwrap().unwrap();
            let got = resp.result.as_vector().unwrap();
            assert!(crate::util::matrix::allclose(got, &want, 1e-8, 1e-8));
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 32);
        assert!(m.errors_injected >= 1, "planned faults should fire");
        assert_eq!(m.errors_detected, m.errors_injected,
                   "every injected fault must be detected");
        assert_eq!(m.errors_corrected, m.errors_detected);
    }
}
