//! The per-shard serving engine: clients submit [`BlasRequest`]s and
//! receive [`BlasResponse`]s over per-request channels; a worker pool
//! drains the batching queue through the router; an optional injector
//! arms planned faults (the error-injection experiments of paper §6.3
//! run through exactly this path). A [`Server`] is one self-contained
//! shard — worker pool, kernel-keyed batcher, thread-budget ledger,
//! admission watermark, metrics ledger — and
//! [`crate::coordinator::cluster::Cluster`] composes several of them
//! behind a rendezvous-routing front-end.
//!
//! The pipeline is plan-aware end to end:
//!
//! 1. **Admission** — `submit` resolves the request's [`ExecutionPlan`]
//!    through the shared [`PlanCache`] (memoized by routine × dim ×
//!    policy × selection) and enqueues the job keyed by **planned
//!    kernel id**, so requests that run the same registered kernel
//!    batch together regardless of shape. Every admitted job is
//!    planned — PJRT and GPU-sim requests resolve to their own registry
//!    descriptors — and a request no descriptor can serve is rejected
//!    at admission with a typed [`Error::NoCandidate`] carrying the
//!    planner's exhaustive per-descriptor diagnostics. When the profile
//!    sets an `admission_depth`, a submission arriving at a full queue
//!    is shed with a typed [`Error::Overloaded`] (and a `shed` count in
//!    the ledger) instead of growing the queue without bound.
//! 2. **Scheduling** — workers drain the oldest *admissible* group: a
//!    thread-budget ledger debits each in-flight batch's thread grant
//!    against the configured budget, deferring MT-kernel batches that
//!    would oversubscribe it while serial batches flow past. When the
//!    shard runs inside a cluster, a grant is an **admission ticket**
//!    against the cluster's persistent
//!    [`crate::runtime::pool::ComputePool`]: the same ledger now bounds
//!    pool *occupancy* (concurrent band tasks) rather than a
//!    spawned-thread count — the pool is sized from the same
//!    `Profile.thread_budget`, so tickets and capacity stay in one
//!    currency.
//! 3. **Execution** — workers run the pre-resolved plan via
//!    [`Router::execute_planned`]; no planner lookup happens on the hot
//!    path (plans that selected the PJRT descriptor are forwarded to
//!    the executor thread inside the router). A
//!    drained batch of ≥2 small GEMMs whose shared plan has a
//!    batch-fused sibling kernel
//!    ([`crate::coordinator::registry::KernelRegistry::batched_sibling`])
//!    short-circuits into ONE [`Router::execute_batch`] call — one
//!    pooled work queue under at most one threading frame instead of
//!    per-item kernel launches (counted as `batches_fused` /
//!    `items_fused` in the ledger).
//!
//! Completions land in the per-kernel metrics ledger — tagged with the
//! profile's latency-SLO target for the executed kernel — together with
//! the plan-cache, deferral, and shed counters.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::SloTable;
use crate::coordinator::batcher::{Batcher, Pending};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::plan::{ExecutionPlan, PlanCache, Planner};
use crate::coordinator::registry::{KernelId, KernelRegistry};
use crate::coordinator::request::{Backend, BlasRequest, BlasResponse};
use crate::coordinator::router::Router;
use crate::ft::injector::{Fault, Injector, InjectorConfig};
use crate::ft::policy::FtPolicy;

/// Typed admission failures — distinguishable from kernel errors so
/// clients can back off and retry instead of treating a shed as a
/// computation failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Error {
    /// The target shard's pending queue is at its admission watermark;
    /// the submission was shed (counted in the ledger) rather than
    /// queued.
    Overloaded { shard: usize, depth: usize, limit: usize },
    /// The shard is shutting down: its workers are draining out, so a
    /// queued job could never execute — reject instead of letting the
    /// client's `recv` hang on a reply that will never come.
    ShuttingDown { shard: usize },
    /// No registered kernel satisfies the request under the effective
    /// selection policy. `detail` is the planner's exhaustive
    /// diagnostic: every descriptor considered and the capability each
    /// one missed (the gateway maps this to a 400 with the text
    /// attached).
    NoCandidate { shard: usize, detail: String },
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Overloaded { shard, depth, limit } => write!(
                f,
                "shard {shard} overloaded: queue depth {depth} at admission \
                 limit {limit}"
            ),
            Error::ShuttingDown { shard } => {
                write!(f, "shard {shard} is shutting down")
            }
            Error::NoCandidate { shard, detail } => {
                write!(f, "shard {shard}: {detail}")
            }
        }
    }
}

impl std::error::Error for Error {}

impl Error {
    /// Machine-readable form for the wire: a JSON object carrying the
    /// error kind, the shard, the admission numbers (for `Overloaded`),
    /// and the human-readable message. The gateway chains extra fields
    /// onto this (retry counts, back-off hints) before serializing.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let base = Json::obj().field("error", Json::Str(self.to_string()));
        match self {
            Error::Overloaded { shard, depth, limit } => base
                .field("kind", Json::Str("overloaded".into()))
                .field("shard", Json::Int(*shard as u64))
                .field("queue_depth", Json::Int(*depth as u64))
                .field("admission_limit", Json::Int(*limit as u64)),
            Error::ShuttingDown { shard } => base
                .field("kind", Json::Str("shutting_down".into()))
                .field("shard", Json::Int(*shard as u64)),
            Error::NoCandidate { shard, detail } => base
                .field("kind", Json::Str("no_candidate".into()))
                .field("shard", Json::Int(*shard as u64))
                .field("detail", Json::Str(detail.clone())),
        }
    }
}

/// Result of an admission attempt: a receiver for the (eventual)
/// response, or the typed admission rejection.
pub type Admitted = std::result::Result<Receiver<Result<BlasResponse>>, Error>;

/// Scheduling key of a queued job: the kernel the admission-time
/// planner chose (every admitted job is planned — PJRT and GPU-sim
/// requests resolve to their own registry descriptors) plus the plan's
/// thread grant, so the budget check needs no job inspection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct BatchKey {
    kernel: KernelId,
    threads: u16,
}

impl BatchKey {
    /// Threads a batch with this key occupies while in flight — the
    /// size of its admission ticket against the compute pool (or, with
    /// `--no-pool`, the scoped threads its frame will spawn).
    fn thread_cost(&self) -> usize {
        self.threads.max(1) as usize
    }
}

struct Job {
    req: BlasRequest,
    /// Admission-time plan.
    plan: ExecutionPlan,
    enqueued: Instant,
    reply: Sender<Result<BlasResponse>>,
}

/// A drained batch of jobs.
type Batch = Vec<Pending<BatchKey, Job>>;

/// Scheduler state guarded by one mutex: the queue plus the
/// thread-budget ledger (checked and debited atomically).
struct Sched {
    batcher: Batcher<BatchKey, Job>,
    /// Sum of thread costs of in-flight batches.
    in_flight_threads: usize,
    /// Anti-starvation aging: the FIFO-head group's key and how many
    /// drains have bypassed it on budget grounds. Reset whenever the
    /// head drains or a different group reaches the head.
    head_age: Option<(BatchKey, u32)>,
}

impl Sched {
    /// Drain the oldest batch whose thread cost fits the remaining
    /// budget, debiting the ledger. An empty ledger admits any batch
    /// (a grant larger than the whole budget runs alone rather than
    /// starving). Returns the batch and its debited cost.
    ///
    /// **Anti-starvation aging**: a budget-deferred MT group at the
    /// FIFO head can otherwise be bypassed indefinitely — every serial
    /// drain keeps the ledger non-empty, so the MT grant never fits.
    /// After `age_limit` bypasses of the same head group, the budget is
    /// *reserved* for it: no younger group drains until the head fits
    /// (in-flight batches crediting the ledger back eventually admit
    /// it, at worst via the empty-ledger escape). Sustained serial
    /// traffic therefore delays an MT batch by a bounded amount instead
    /// of forever; the reservation is counted in the ledger.
    ///
    /// Deferrals are recorded only when a younger batch actually
    /// bypassed an over-budget group — a real scheduling decision. A
    /// fruitless pass (nothing admissible, worker goes back to waiting)
    /// is not counted, so the metric reflects contention rather than
    /// how often idle workers re-poll.
    fn pop_admissible(&mut self, budget: usize, age_limit: usize,
                      metrics: &Metrics) -> Option<(Batch, usize)> {
        let in_flight = self.in_flight_threads;
        let head = self.batcher.head_key();
        let reserved = matches!(
            (&self.head_age, head),
            (Some((aged, n)), Some(h)) if *aged == h && *n >= age_limit as u32
        );
        let drain = self.batcher.next_batch_where(|k| {
            let fits = in_flight == 0 || in_flight + k.thread_cost() <= budget;
            fits && (!reserved || Some(*k) == head)
        });
        if !drain.batch.is_empty() {
            metrics.record_deferrals(drain.deferred as u64);
        }
        // aging bookkeeping: the head either drained (reset), was
        // bypassed by the drained batch (count it), or nothing drained
        // (state unchanged — an idle re-poll is not a bypass)
        match (drain.batch.first().map(|p| p.key), head) {
            (Some(k), Some(h)) if k == h => self.head_age = None,
            (Some(_), Some(h)) => {
                let n = match self.head_age {
                    Some((aged, n)) if aged == h => n + 1,
                    _ => 1,
                };
                if n as usize == age_limit {
                    metrics.record_starvation_reserve();
                }
                self.head_age = Some((h, n));
            }
            _ => {}
        }
        let first = drain.batch.first()?;
        let cost = first.key.thread_cost();
        self.in_flight_threads += cost;
        metrics.record_in_flight(self.in_flight_threads as u64);
        Some((drain.batch, cost))
    }
}

struct Shared {
    sched: Mutex<Sched>,
    cv: Condvar,
    shutdown: AtomicBool,
    metrics: Metrics,
    plans: PlanCache,
    router: Arc<Router>,
    policy: FtPolicy,
    thread_budget: usize,
    /// Bypass count after which the scheduler reserves the budget for
    /// a deferred FIFO-head group (from `Profile.starvation_limit`).
    starvation_limit: usize,
    /// This engine's shard index (0 for a standalone server).
    shard: usize,
    /// Queue-depth watermark; `None` = unbounded admission.
    admission_depth: Option<usize>,
    /// Latency-SLO targets from the profile.
    slo: SloTable,
    injector: Mutex<Injector>,
    steps: AtomicU64,
}

impl Shared {
    /// Snapshot with the plan-cache counters folded in and the
    /// injection mode labeled: `"campaign"` when the shared router
    /// carries a live [`crate::ft::injector::InjectionCampaign`],
    /// `"per-call"` when this shard armed a planned [`Injector`], empty
    /// otherwise.
    fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        let (hits, misses) = self.plans.stats();
        snap.plan_cache_hits = hits;
        snap.plan_cache_misses = misses;
        snap.injection_mode = if self.router.campaign().is_some() {
            "campaign"
        } else if self.injector.lock().unwrap().planned() > 0 {
            "per-call"
        } else {
            ""
        };
        snap
    }
}

/// Handle for submitting requests; cheap to clone.
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Submit a request; returns a receiver for the response.
    ///
    /// Admission does the planning: the request is resolved through the
    /// memoized plan cache and queued under its planned kernel id, so
    /// the worker that drains it executes the plan without another
    /// lookup. A shed submission ([`Error::Overloaded`]) surfaces as an
    /// error on the returned receiver; use [`ServerHandle::try_submit`]
    /// to get the typed rejection synchronously.
    pub fn submit(&self, req: BlasRequest) -> Receiver<Result<BlasResponse>> {
        match self.try_submit(req) {
            Ok(rx) => rx,
            Err(e) => {
                let (reply, rx) = channel();
                let _ = reply.send(Err(anyhow::Error::new(e)));
                rx
            }
        }
    }

    /// Submit with typed admission control: plans the request under the
    /// router's effective selection policy, then enqueues it unless the
    /// queue is at the admission watermark. A request no registered
    /// descriptor can serve is rejected here with
    /// [`Error::NoCandidate`] and the planner's full diagnostics.
    pub fn try_submit(&self, req: BlasRequest) -> Admitted {
        let policy = self.shared.policy;
        let sel = self.shared.router.selection_for(&req, policy);
        let plan = self
            .shared
            .plans
            .resolve(req.routine(), req.dim(), policy, &sel);
        let Some(plan) = plan else {
            let detail = Planner::new(self.shared.plans.profile())
                .select_dims(req.routine(), req.dim(), &sel, policy)
                .expect_err("cache said no plan exists")
                .to_string();
            return Err(Error::NoCandidate { shard: self.shared.shard,
                                            detail });
        };
        self.enqueue(req, plan).map_err(|(e, _)| e)
    }

    /// Cluster entry: enqueue a request whose plan was already resolved
    /// by the cluster's shared cache (no shard-local planning).
    pub(crate) fn submit_planned(&self, req: BlasRequest,
                                 plan: ExecutionPlan) -> Admitted {
        self.enqueue(req, plan).map_err(|(e, _)| e)
    }

    /// [`ServerHandle::submit_planned`] that hands a rejected request
    /// back to the caller, so retry wrappers re-submit the same value
    /// without a defensive clone per attempt.
    pub(crate) fn submit_planned_returning(
        &self, req: BlasRequest, plan: ExecutionPlan)
        -> std::result::Result<Receiver<Result<BlasResponse>>,
                               (Error, BlasRequest)> {
        self.enqueue(req, plan)
    }

    /// The single enqueue path: admission watermark, batch-key
    /// derivation, push, wake. Rejections return the request unconsumed
    /// alongside the typed error.
    fn enqueue(&self, req: BlasRequest, plan: ExecutionPlan)
               -> std::result::Result<Receiver<Result<BlasResponse>>,
                                      (Error, BlasRequest)> {
        let key = BatchKey {
            kernel: plan.kernel_id,
            threads: plan.thread_cost() as u16,
        };
        let (reply, rx) = channel();
        {
            let mut s = self.shared.sched.lock().unwrap();
            // checked under the scheduler lock: the last worker decides
            // to exit while holding it (shutdown && empty queue), so a
            // push racing shutdown either lands before that decision —
            // and is drained — or is rejected here, never orphaned
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err((Error::ShuttingDown { shard: self.shared.shard },
                            req));
            }
            if let Some(limit) = self.shared.admission_depth {
                let depth = s.batcher.len();
                if depth >= limit {
                    drop(s);
                    self.shared.metrics.record_shed();
                    return Err((Error::Overloaded {
                        shard: self.shared.shard,
                        depth,
                        limit,
                    }, req));
                }
            }
            s.batcher
                .push(key, Job { req, plan, enqueued: Instant::now(), reply });
            self.shared.metrics.record_queue_depth(s.batcher.len() as u64);
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Submit and wait.
    pub fn call(&self, req: BlasRequest) -> Result<BlasResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    /// Live pending-queue depth — the cluster's least-loaded routing
    /// tiebreak reads this.
    pub fn queue_depth(&self) -> usize {
        self.shared.sched.lock().unwrap().batcher.len()
    }

    /// Cheap cumulative `(completed, shed, slo_burns)` counters — what
    /// the cluster's autoscaler samples every interval (a full
    /// [`ServerHandle::metrics`] snapshot clones every retained latency
    /// sample, far too heavy for a sampling loop).
    pub fn pressure(&self) -> (u64, u64, u64) {
        self.shared.metrics.pressure()
    }

    /// Snapshot of this shard's ledger (plan-cache counters included).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.snapshot()
    }
}

/// The server: a worker pool over one shared router.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start with `workers` native worker threads. The router (and its
    /// PJRT handle, which is Send) is shared read-only.
    ///
    /// The batch window comes from `Profile.max_batch` and the thread
    /// budget from `Profile.thread_budget` (defaulting to
    /// `Profile.threads × workers` — the capacity the profile's machine
    /// dedicates to this pool). The budget is clamped to at least one
    /// full MT grant (`Profile.threads`), so in-flight grants never
    /// exceed it.
    pub fn start(router: Router, policy: FtPolicy, workers: usize,
                 injection: Option<InjectorConfig>,
                 expected_requests: usize) -> Server {
        Server::start_shard(0, Arc::new(router), policy, workers, injection,
                            expected_requests)
    }

    /// Start one shard of a cluster: same engine, but sharing the
    /// (read-only) router with its sibling shards and tagged with a
    /// shard index for typed overload errors. The admission watermark
    /// and SLO table come from the router's profile.
    pub fn start_shard(shard: usize, router: Arc<Router>, policy: FtPolicy,
                       workers: usize, injection: Option<InjectorConfig>,
                       expected_requests: usize) -> Server {
        let injector = match injection {
            Some(cfg) => {
                // plan faults across the expected request stream; positions
                // are interpreted per-routine inside the router
                Injector::plan(&cfg, expected_requests.max(1), 64, 64)
            }
            None => Injector::empty(),
        };
        let workers = workers.max(1);
        let profile = router.profile.clone();
        // clamp to one full MT grant: a planned grant cannot shrink, so
        // a smaller budget could never admit an MT batch — this keeps
        // `max_in_flight_threads <= thread_budget` an unconditional
        // invariant instead of one the empty-ledger escape can break
        let thread_budget = profile
            .thread_budget
            .unwrap_or_else(|| profile.threads.max(1) * workers)
            .max(profile.threads.max(1));
        let shared = Arc::new(Shared {
            sched: Mutex::new(Sched {
                batcher: Batcher::new(profile.max_batch),
                in_flight_threads: 0,
                head_age: None,
            }),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            metrics: Metrics::new(),
            starvation_limit: profile.starvation_limit.max(1),
            shard,
            admission_depth: profile.admission_depth,
            slo: profile.slo.clone(),
            plans: PlanCache::new(profile),
            router,
            policy,
            thread_budget,
            injector: Mutex::new(injector),
            steps: AtomicU64::new(0),
        });
        shared.metrics.set_thread_budget(thread_budget as u64);
        let workers = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ftblas-worker-{w}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        Server { shared, workers }
    }

    /// A submission handle; cheap to clone.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: self.shared.clone() }
    }

    /// Snapshot of this engine's ledger.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.snapshot()
    }

    /// Stop accepting work and join the workers (pending jobs finish).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Credits a batch's thread cost back to the ledger on drop — also on
/// panic, so a kernel that unwinds mid-batch cannot leak its debit and
/// permanently defer MT batches (or hang shutdown).
struct CostCredit<'a> {
    shared: &'a Shared,
    cost: usize,
}

impl Drop for CostCredit<'_> {
    fn drop(&mut self) {
        {
            let mut s = self.shared.sched.lock().unwrap();
            s.in_flight_threads -= self.cost;
        }
        // an admission slot opened: every waiter re-checks the budget
        self.shared.cv.notify_all();
    }
}

/// Batch-fusion fast path. A drained batch is kernel-uniform (the
/// batcher keys planned jobs by kernel id), so when its plan's kernel
/// has a batched sibling and every item's principal dim clears the
/// sibling's small-dim ceiling, the whole batch executes as ONE
/// [`Router::execute_batch`] call: one pooled (item × row-band) work
/// queue under at most one threading frame sized by the batch's debited
/// thread grant, arena-shared packing, per-item [`crate::ft::FtReport`]s.
///
/// Faults are armed per item **in batch order** against the *batched*
/// kernel's id and scheme — completions land in the ledger under the
/// batched kernel's name, so campaign occurrence accounting balances
/// exactly against the per-item ledger rows (no double or dropped
/// strikes).
///
/// Returns `None` when the batch was fully served (every reply sent),
/// or hands the batch back unchanged for the per-item path.
fn try_fused(shared: &Shared, router: &Router, batch: Batch,
             threads: usize) -> Option<Batch> {
    if batch.len() < 2 {
        return Some(batch); // nothing to fuse
    }
    let plan = batch[0].item.plan;
    let registry = KernelRegistry::global();
    let Some(bk) = registry.batched_sibling(plan.kernel) else {
        return Some(batch);
    };
    if !batch.iter().all(|p| bk.admits_batch(p.item.req.dim())) {
        return Some(batch);
    }
    let bk_id = registry.id_of(bk).expect("batched kernels live in the table");
    let started = Instant::now();
    let mut faults: Vec<Option<Fault>> = Vec::with_capacity(batch.len());
    let mut queue_s: Vec<f64> = Vec::with_capacity(batch.len());
    for pending in &batch {
        let job = &pending.item;
        queue_s.push(started.duration_since(job.enqueued).as_secs_f64());
        // same precedence as the per-item path: a live campaign outranks
        // the shard's planned per-call injector
        let fault = match router.campaign() {
            Some(campaign) => {
                campaign.arm(bk_id, bk.scheme, job.req.dim().max(1))
            }
            None => {
                let step = shared.steps.fetch_add(1, Ordering::SeqCst) as usize;
                let mut inj = shared.injector.lock().unwrap();
                inj.take(step).map(|mut f| {
                    let dim = job.req.dim();
                    f.i %= dim.max(1);
                    f.j %= dim.max(1);
                    f.step = 1; // strike the second panel/chunk
                    f
                })
            }
        };
        faults.push(fault);
    }
    let reqs: Vec<(&BlasRequest, Option<Fault>)> = batch
        .iter()
        .zip(&faults)
        .map(|(p, f)| (&p.item.req, *f))
        .collect();
    let resps = router.execute_batch(bk, &reqs, threads);
    drop(reqs);
    shared.metrics.record_batch_fusion(bk.name, batch.len() as u64);
    for (((pending, resp), fault), qs) in
        batch.into_iter().zip(resps).zip(faults).zip(queue_s)
    {
        let job = pending.item;
        shared.metrics.record_completion(
            resp.kernel,
            job.req.routine(),
            resp.exec_seconds,
            job.enqueued.elapsed().as_secs_f64(),
            qs,
            resp.ft.errors_detected,
            resp.ft.errors_corrected,
            fault.is_some() as u64,
            shared.slo.target(resp.kernel, bk.level),
        );
        let _ = job.reply.send(Ok(resp));
    }
    None
}

fn worker_loop(shared: Arc<Shared>) {
    let router = shared.router.clone();
    loop {
        let (batch, cost) = {
            let mut s = shared.sched.lock().unwrap();
            loop {
                if !s.batcher.is_empty() {
                    if let Some(got) = s.pop_admissible(shared.thread_budget,
                                                        shared.starvation_limit,
                                                        &shared.metrics)
                    {
                        break got;
                    }
                    // nothing admissible right now: wait for an
                    // in-flight batch to credit the ledger back
                }
                if shared.shutdown.load(Ordering::SeqCst) && s.batcher.is_empty()
                {
                    return;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(s, std::time::Duration::from_millis(50))
                    .unwrap();
                s = guard;
            }
        };
        let _credit = CostCredit { shared: shared.as_ref(), cost };
        // small-GEMM fast path: a kernel-uniform batch whose kernel has
        // a batched sibling executes as one fused call (replies sent
        // inside); anything else falls back to the per-item loop below
        let Some(batch) = try_fused(&shared, &router, batch, cost) else {
            // refresh this worker's packing-arena totals into the
            // ledger (cumulative per thread; latest value wins)
            shared.metrics.record_arena();
            continue;
        };
        for pending in batch {
            let job = pending.item;
            let started = Instant::now();
            let queue_s = started.duration_since(job.enqueued).as_secs_f64();
            // campaign mode outranks the per-call plan: a live campaign
            // (shared through the router by every shard, including
            // shards spawned mid-run) arms scheme-aware, rate-gated
            // strikes per planned execution; otherwise the shard's own
            // planned injector fires on its call steps.
            let fault = match router.campaign() {
                Some(campaign) => {
                    campaign.arm(job.plan.kernel_id, job.plan.kernel.scheme,
                                 job.req.dim().max(1))
                }
                None => {
                    let step =
                        shared.steps.fetch_add(1, Ordering::SeqCst) as usize;
                    let mut inj = shared.injector.lock().unwrap();
                    inj.take(step).map(|mut f| {
                        // clamp the planned position into this
                        // request's range
                        let dim = job.req.dim();
                        f.i %= dim.max(1);
                        f.j %= dim.max(1);
                        f.step = 1; // strike the second panel/chunk
                        f
                    })
                }
            };
            let injected = fault.is_some() as u64;
            // SLO targets key off the executed kernel's BLAS level
            let level = job.plan.kernel.level;
            // the hot path: every job carries its admission-time plan;
            // PJRT-selected plans are forwarded inside the router
            let result = router.execute_planned(&job.plan, &job.req, fault);
            match result {
                Ok(resp) => {
                    shared.metrics.record_completion(
                        resp.kernel,
                        job.req.routine(),
                        resp.exec_seconds,
                        job.enqueued.elapsed().as_secs_f64(),
                        queue_s,
                        resp.ft.errors_detected,
                        resp.ft.errors_corrected,
                        injected,
                        shared.slo.target(resp.kernel, level),
                    );
                    let _ = job.reply.send(Ok(resp));
                }
                Err(e) => {
                    shared.metrics.record_failure();
                    let _ = job.reply.send(Err(e));
                }
            }
        }
        shared.metrics.record_arena();
        // _credit drops here: ledger credited back, waiters notified
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;
    use crate::coordinator::plan::{CapRequirement, PlanCache, SelectionPolicy};
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    fn native_server(policy: FtPolicy, inj: Option<InjectorConfig>) -> Server {
        let router = Router::native_only(Profile::default(), Backend::NativeTuned);
        Server::start(router, policy, 3, inj, 64)
    }

    #[test]
    fn serves_concurrent_requests() {
        let server = native_server(FtPolicy::None, None);
        let handle = server.handle();
        let mut rng = Rng::new(5);
        let reqs: Vec<BlasRequest> = (0..24)
            .map(|i| {
                if i % 2 == 0 {
                    BlasRequest::Ddot { x: rng.normal_vec(256), y: rng.normal_vec(256) }
                } else {
                    BlasRequest::Dscal { alpha: 2.0, x: rng.normal_vec(128) }
                }
            })
            .collect();
        let rxs: Vec<_> = reqs.iter().cloned().map(|r| handle.submit(r)).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.ft.errors_detected, 0);
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 24);
        assert_eq!(m.failed, 0);
        // admission planned every request: one miss per distinct
        // (routine, dim) key, hits for the rest
        assert_eq!(m.plan_cache_misses, 2);
        assert_eq!(m.plan_cache_hits, 22);
        // per-kernel ledger entries carry the executed kernel names
        assert!(m.kernels.contains_key("ddot/tuned"), "{:?}", m.kernels.keys());
        assert!(m.kernels.contains_key("dscal/tuned"));
        assert_eq!(m.kernels["ddot/tuned"].completed, 12);
    }

    #[test]
    fn injection_is_detected_and_corrected() {
        let cfg = InjectorConfig { count: 8, ..Default::default() };
        let server = native_server(FtPolicy::Hybrid, Some(cfg));
        let handle = server.handle();
        let mut rng = Rng::new(6);
        let l = Matrix::random_lower_triangular(64, &mut rng);
        let mut oracle = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..32 {
            let b = rng.normal_vec(64);
            let mut want = b.clone();
            crate::blas::naive::dtrsv_lower(64, &l.data, &mut want);
            oracle.push(want);
            rxs.push(handle.submit(BlasRequest::Dtrsv { a: l.clone(), b }));
        }
        for (rx, want) in rxs.into_iter().zip(oracle) {
            let resp = rx.recv().unwrap().unwrap();
            let got = resp.result.as_vector().unwrap();
            assert!(crate::util::matrix::allclose(got, &want, 1e-8, 1e-8));
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 32);
        assert!(m.errors_injected >= 1, "planned faults should fire");
        assert_eq!(m.errors_detected, m.errors_injected,
                   "every injected fault must be detected");
        assert_eq!(m.errors_corrected, m.errors_detected);
        assert_eq!(m.errors_escaped, 0);
        assert_eq!(m.injection_mode, "per-call");
        // FT counters attributed to the kernel that actually ran
        let k = &m.kernels["dtrsv/dmr"];
        assert_eq!(k.errors_detected, m.errors_detected);
    }

    /// Campaign mode end to end on one engine: a router-carried
    /// campaign (stride 1, unbounded rate) strikes every protected
    /// execution, every strike is detected and corrected, results stay
    /// correct, and the ledger labels the mode.
    #[test]
    fn campaign_strikes_are_detected_and_labeled() {
        use crate::ft::injector::CampaignConfig;
        let campaign = CampaignConfig {
            stride: 1,
            rate_per_min: f64::INFINITY,
            ..Default::default()
        };
        let router = Router::native_only(Profile::default(),
                                         Backend::NativeTuned)
            .with_campaign(campaign);
        let server = Server::start(router, FtPolicy::Hybrid, 3, None, 0);
        let handle = server.handle();
        let mut rng = Rng::new(0xCA);
        let mut rxs = Vec::new();
        let mut oracle = Vec::new();
        for _ in 0..16 {
            let x = rng.normal_vec(512);
            let y = rng.normal_vec(512);
            oracle.push(x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>());
            rxs.push(handle.submit(BlasRequest::Ddot { x, y }));
        }
        for (rx, want) in rxs.into_iter().zip(oracle) {
            let resp = rx.recv().unwrap().unwrap();
            let got = resp.result.as_scalar().unwrap();
            assert!((got - want).abs() <= 1e-8 * (1.0 + want.abs()),
                    "struck ddot must still be corrected: {got} vs {want}");
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 16);
        assert_eq!(m.errors_injected, 16,
                   "stride 1 + unbounded rate strikes every execution");
        assert_eq!(m.errors_detected, 16);
        assert_eq!(m.errors_corrected, 16);
        assert_eq!(m.errors_escaped, 0);
        assert_eq!(m.injection_mode, "campaign");
        assert_eq!(m.kernels["ddot/dmr"].errors_injected, 16);
    }

    /// A campaign targeting only the fused-ABFT paths leaves DMR
    /// traffic unstruck — scheme-aware targeting at the worker.
    #[test]
    fn campaign_targeting_skips_out_of_scope_schemes() {
        use crate::ft::injector::{CampaignConfig, CampaignTarget};
        let campaign = CampaignConfig {
            stride: 1,
            rate_per_min: f64::INFINITY,
            target: CampaignTarget::Fused,
            ..Default::default()
        };
        let router = Router::native_only(Profile::default(),
                                         Backend::NativeTuned)
            .with_campaign(campaign);
        let server = Server::start(router, FtPolicy::Hybrid, 2, None, 0);
        let handle = server.handle();
        let mut rng = Rng::new(0xD0);
        let rxs: Vec<_> = (0..8)
            .map(|_| handle.submit(BlasRequest::Ddot {
                x: rng.normal_vec(256),
                y: rng.normal_vec(256),
            }))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 8);
        assert_eq!(m.errors_injected, 0,
                   "a fused-only campaign must not strike DMR kernels");
        assert_eq!(m.injection_mode, "campaign",
                   "the mode labels the campaign even when it never fired");
    }

    /// Deterministic scheduler check: with an MT group at the head of
    /// the queue and the ledger nearly full, the serial group flows
    /// past (one deferral) and the MT group drains once the ledger is
    /// credited back.
    #[test]
    fn scheduler_defers_mt_batches_over_budget() {
        let profile = Profile::cascade_sim(); // threads = 4
        let cache = PlanCache::new(profile.clone());
        let tuned = SelectionPolicy::for_backend(Backend::NativeTuned);
        let mt = cache
            .resolve("dgemm", 96, FtPolicy::None, &tuned)
            .unwrap();
        assert_eq!(mt.kernel.name, "dgemm/tuned-mt");
        let serial = cache
            .resolve("ddot", 256, FtPolicy::None, &tuned)
            .unwrap();
        let metrics = Metrics::new();
        let mut sched = Sched {
            batcher: Batcher::new(8),
            // one MT batch already executing
            in_flight_threads: mt.thread_cost(),
            head_age: None,
        };
        let job = |plan: &ExecutionPlan, req: BlasRequest| {
            let key = BatchKey {
                kernel: plan.kernel_id,
                threads: plan.thread_cost() as u16,
            };
            let (reply, _rx) = channel();
            std::mem::forget(_rx); // keep the send side alive for the test
            (key, Job { req, plan: *plan, enqueued: Instant::now(), reply })
        };
        let mut rng = Rng::new(0xBEEF);
        let gemm = BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(96, 96, &mut rng),
            b: Matrix::random(96, 96, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(96, 96),
        };
        let dot = BlasRequest::Ddot {
            x: rng.normal_vec(256),
            y: rng.normal_vec(256),
        };
        let (k1, j1) = job(&mt, gemm);
        sched.batcher.push(k1, j1);
        let (k2, j2) = job(&serial, dot);
        sched.batcher.push(k2, j2);
        // budget 6: in-flight 4 + MT 4 > 6 defers, + serial 1 = 5 fits
        let (batch, cost) = sched.pop_admissible(6, 4, &metrics).unwrap();
        assert_eq!(cost, 1, "serial batch must flow past the deferred MT");
        assert_eq!(batch[0].key.threads, 1);
        assert_eq!(sched.in_flight_threads, 5);
        // nothing admissible for the MT batch until the ledger drains
        assert!(sched.pop_admissible(6, 4, &metrics).is_none());
        sched.in_flight_threads = 0;
        let (batch, cost) = sched.pop_admissible(6, 4, &metrics).unwrap();
        assert_eq!(cost, 4);
        assert_eq!(batch[0].key.threads, 4);
        let snap = metrics.snapshot();
        // exactly one real bypass: the serial batch jumping the MT
        // group; the fruitless pass in between is not counted
        assert_eq!(snap.deferrals, 1);
        assert_eq!(snap.max_in_flight_threads, 5);
    }

    /// Anti-starvation aging, on a deterministic schedule: an MT group
    /// at the FIFO head under a tight budget is bypassed by serial
    /// traffic exactly `age_limit` times, after which the budget is
    /// reserved for it — younger serial groups stop draining even
    /// though they fit — until the ledger empties and the head runs.
    #[test]
    fn aged_head_group_reserves_the_budget() {
        let profile = Profile::cascade_sim(); // threads = 4
        let cache = PlanCache::new(profile.clone());
        let tuned = SelectionPolicy::for_backend(Backend::NativeTuned);
        let mt = cache
            .resolve("dgemm", 96, FtPolicy::None, &tuned)
            .unwrap();
        let serial = cache
            .resolve("ddot", 256, FtPolicy::None, &tuned)
            .unwrap();
        let metrics = Metrics::new();
        let mut sched = Sched {
            batcher: Batcher::new(1), // one item per drain: exact schedule
            in_flight_threads: 4,     // an MT batch is already executing
            head_age: None,
        };
        let job = |plan: &ExecutionPlan, req: BlasRequest| {
            let key = BatchKey {
                kernel: plan.kernel_id,
                threads: plan.thread_cost() as u16,
            };
            let (reply, _rx) = channel();
            std::mem::forget(_rx);
            (key, Job { req, plan: *plan, enqueued: Instant::now(), reply })
        };
        let mut rng = Rng::new(0xA9E);
        let gemm = || BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::zeros(96, 96),
            b: Matrix::zeros(96, 96),
            beta: 0.0,
            c: Matrix::zeros(96, 96),
        };
        let (mk, mj) = job(&mt, gemm());
        sched.batcher.push(mk, mj);
        // sustained serial traffic behind the MT head
        for _ in 0..4 {
            let (sk, sj) = job(&serial, BlasRequest::Ddot {
                x: rng.normal_vec(256),
                y: rng.normal_vec(256),
            });
            sched.batcher.push(sk, sj);
        }
        const LIMIT: usize = 2;
        // budget 6, in-flight 4: MT (4 more) never fits, serial (1) does.
        // Bypass 1 and 2 drain serial batches and age the head...
        for bypass in 1..=LIMIT {
            let (batch, cost) =
                sched.pop_admissible(6, LIMIT, &metrics).unwrap();
            assert_eq!(cost, 1, "bypass {bypass} must drain a serial batch");
            assert_eq!(batch[0].key.threads, 1);
            sched.in_flight_threads -= 1; // the serial batch completes
        }
        // ...and from now on the budget is reserved: serial batches
        // still fit the arithmetic, but the aged head fences them out
        assert!(sched.pop_admissible(6, LIMIT, &metrics).is_none(),
                "reservation must block younger serial batches");
        assert_eq!(sched.batcher.len(), 3, "two serial drained, two wait");
        // the in-flight MT batch finally credits the ledger back
        sched.in_flight_threads = 0;
        let (batch, cost) = sched.pop_admissible(6, LIMIT, &metrics).unwrap();
        assert_eq!(cost, 4, "the aged MT head drains first");
        assert_eq!(batch[0].key.threads, 4);
        // reservation cleared: the remaining serial traffic flows again
        let (_, cost) = sched.pop_admissible(6, LIMIT, &metrics).unwrap();
        assert_eq!(cost, 1);
        let snap = metrics.snapshot();
        assert_eq!(snap.starvation_reserves, 1,
                   "crossing the limit is counted once");
        assert_eq!(snap.deferrals, LIMIT as u64,
                   "only the real bypasses count as deferrals");
    }

    /// End-to-end batch fusion: a pile of same-plan small DGEMMs drains
    /// as ONE fused call on the batched sibling kernel, under a live
    /// campaign. The first (large, unfusable) request pins the single
    /// worker so the small ones provably group into one batch; every
    /// armed strike is detected and corrected, completions land under
    /// the batched kernel's ledger entry, the fusion counters fire, and
    /// results stay correct.
    #[test]
    fn small_gemm_batches_fuse_through_the_batched_kernel() {
        use crate::ft::injector::CampaignConfig;
        let campaign = CampaignConfig {
            stride: 1,
            rate_per_min: f64::INFINITY,
            ..Default::default()
        };
        let router = Router::native_only(Profile::default(),
                                         Backend::NativeSimd)
            .with_campaign(campaign);
        // ONE worker: it picks up the head-of-queue pin request — a
        // large DTRSV, whose plan keys a *different* batch group than
        // the small GEMMs — and executes it (~ms) while the 16 small
        // submissions (microseconds of clone work each) pile into one
        // kernel-keyed group, which then drains as a single fused batch
        let server = Server::start(router, FtPolicy::Hybrid, 1, None, 0);
        let handle = server.handle();
        let mut rng = Rng::new(0xBA7C);
        let big = 1536;
        let l = Matrix::random_lower_triangular(big, &mut rng);
        let mut rxs = vec![handle.submit(BlasRequest::Dtrsv {
            a: l,
            b: rng.normal_vec(big),
        })];
        let n = 32; // small: plans serial, fuses through the sibling
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut want = vec![0.0; n * n];
        crate::blas::naive::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0,
                                  &mut want);
        for _ in 0..16 {
            rxs.push(handle.submit(BlasRequest::Dgemm {
                alpha: 1.0,
                a: a.clone(),
                b: b.clone(),
                beta: 0.0,
                c: Matrix::zeros(n, n),
            }));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.ft.errors_detected, 1,
                       "stride-1 campaign strikes every protected item");
            assert_eq!(resp.ft.errors_corrected, 1);
            if i > 0 {
                let got = resp.result.as_matrix().unwrap();
                assert!(crate::util::matrix::allclose(&got.data, &want,
                                                      1e-7, 1e-7),
                        "struck small GEMM {i} must still be corrected");
            }
        }
        let m = server.shutdown();
        assert_eq!(m.completed, 17);
        assert_eq!(m.failed, 0);
        // the fusion fast path fired and the ledger says so
        assert!(m.batches_fused >= 1, "no batch fused");
        assert!(m.items_fused >= 2, "fused batches carry ≥2 items");
        let k = &m.kernels["dgemm/batched-abft-fused-simd"];
        assert!(k.completed >= 2, "fused completions land under the \
                                   batched kernel's name");
        assert!(k.max_items_per_batch >= 2);
        assert_eq!(k.errors_escaped, 0);
        // exact campaign balance across fused and per-item executions
        assert_eq!(m.errors_injected, 17);
        assert_eq!(m.errors_detected, 17);
        assert_eq!(m.errors_corrected, 17);
        assert_eq!(m.errors_escaped, 0);
        assert_eq!(m.injection_mode, "campaign");
    }

    /// An unsatisfiable selection policy is rejected at admission with
    /// the planner's exhaustive diagnostics — no job is ever queued.
    #[test]
    fn unsatisfiable_selection_is_rejected_at_admission() {
        let sel = SelectionPolicy {
            require: vec![CapRequirement::Precision("f32".into())],
            ..SelectionPolicy::default()
        };
        let router = Router::native_only(Profile::default(),
                                         Backend::NativeTuned)
            .with_selection(sel);
        let server = Server::start(router, FtPolicy::None, 1, None, 0);
        let handle = server.handle();
        let req = BlasRequest::Ddot { x: vec![1.0; 8], y: vec![1.0; 8] };
        let err = handle.try_submit(req).unwrap_err();
        let Error::NoCandidate { shard, detail } = &err else {
            panic!("expected NoCandidate, got {err:?}");
        };
        assert_eq!(*shard, 0);
        assert!(detail.contains("no candidate kernel for ddot"));
        assert!(detail.contains("lacks required precision=f32"));
        let json = err.to_json().render();
        assert!(json.contains("\"kind\":\"no_candidate\""), "{json}");
        let m = server.shutdown();
        assert_eq!(m.completed, 0);
    }

    /// The admission error is typed (clients match on it to back off)
    /// and survives an anyhow round-trip, which is how `submit`'s
    /// receiver surfaces it.
    #[test]
    fn overloaded_error_is_typed_and_printable() {
        let e = Error::Overloaded { shard: 1, depth: 8, limit: 8 };
        assert_eq!(e.to_string(),
                   "shard 1 overloaded: queue depth 8 at admission limit 8");
        let any = anyhow::Error::new(e.clone());
        assert_eq!(any.downcast_ref::<Error>(), Some(&e));
        assert_eq!(Error::ShuttingDown { shard: 0 }.to_string(),
                   "shard 0 is shutting down");
    }

    /// A submission racing shutdown is rejected with the typed error
    /// instead of being queued behind workers that already exited
    /// (which would hang the client's recv forever).
    #[test]
    fn submissions_after_shutdown_are_rejected_not_orphaned() {
        let server = native_server(FtPolicy::None, None);
        let handle = server.handle();
        drop(server); // sets the shutdown flag and joins the workers
        let req = BlasRequest::Ddot { x: vec![1.0; 8], y: vec![1.0; 8] };
        assert!(matches!(handle.try_submit(req.clone()),
                         Err(Error::ShuttingDown { shard: 0 })));
        // the infallible entry surfaces it through the receiver
        let err = handle.submit(req).recv().unwrap().unwrap_err();
        assert_eq!(err.downcast_ref::<Error>(),
                   Some(&Error::ShuttingDown { shard: 0 }));
    }

    /// A budget below one full MT grant could never admit an MT batch,
    /// so `Server::start` clamps it up — keeping the oversubscription
    /// invariant (`max_in_flight_threads <= thread_budget`) absolute.
    #[test]
    fn thread_budget_clamps_to_one_full_grant() {
        let profile = Profile::cascade_sim().with_thread_budget(1);
        let router = Router::native_only(profile, Backend::NativeTuned);
        let server = Server::start(router, FtPolicy::None, 2, None, 0);
        let m = server.shutdown();
        assert_eq!(m.thread_budget, 4, "clamped to cascade's MT grant");
    }
}
