//! Elastic shard scaling: the decision logic that grows and shrinks the
//! serving cluster between [`Profile::min_shards`] and
//! [`Profile::max_shards`].
//!
//! FT-BLAS's claim is that fault tolerance must survive production
//! throughput; FT-GEMM (arXiv:2305.02444) extends the hybrid DMR/ABFT
//! strategy to sustained multi-core load. The serving analog is that
//! the tier must *adapt capacity to load*, not just shed it: bursts
//! should recruit shards, and a calm tier should hand capacity back.
//!
//! The [`ScalingController`] is deliberately **pure**: it consumes
//! cumulative [`TierSample`]s (live queue depth plus the cluster's
//! shed / SLO-burn / completion counters), maintains a sliding window
//! of per-interval deltas, and returns a [`ScaleDecision`]. All
//! threading, locking, and actual shard surgery live in
//! [`crate::coordinator::cluster`]; this module can be unit-tested with
//! synthetic sample streams.
//!
//! ## Decision rules
//!
//! - **Grow** (immediately, on fresh evidence) when any window interval
//!   shed submissions, when the live per-shard queue depth reaches
//!   `grow_depth`, or when the window's SLO burn fraction reaches
//!   `grow_burn_rate` — and the tier is below `max_shards`.
//! - **Shrink** (conservatively, on a full calm window) only when every
//!   interval in a *full* window was calm: zero sheds, per-shard depth
//!   at or below `shrink_depth`, and burn fraction below
//!   `grow_burn_rate` — and the tier is above `min_shards`.
//! - **Hold** otherwise. After any Grow/Shrink the window is cleared,
//!   so the next decision waits for evidence gathered under the new
//!   topology (hysteresis against flapping).

use std::collections::VecDeque;
use std::time::Duration;

use crate::config::Profile;

/// Tuning for the elastic scaling loop. Built from a [`Profile`] via
/// [`ScalingConfig::from_profile`]; the shard bounds come from the
/// profile, the thresholds have serving-sim defaults.
#[derive(Clone, Debug)]
pub struct ScalingConfig {
    /// The controller never shrinks below this many shards.
    pub min_shards: usize,
    /// The controller never grows past this many shards.
    pub max_shards: usize,
    /// Sampling cadence of the controller loop.
    pub interval: Duration,
    /// Sliding-window length, in samples. Growth triggers on any
    /// pressured sample; shrink requires a *full* calm window.
    pub window: usize,
    /// Per-shard live queue depth that signals pressure. Defaults to
    /// half the profile's admission watermark (pressure should trigger
    /// before shedding does), or 4.0 when admission is unbounded.
    pub grow_depth: f64,
    /// Per-shard live queue depth at or below which an interval counts
    /// as calm.
    pub shrink_depth: f64,
    /// SLO burn fraction (burns / completions over the window) that
    /// signals pressure.
    pub grow_burn_rate: f64,
    /// Print a line on every scale event (the `ftblas serve` CLI turns
    /// this on; library embedders keep it off).
    pub verbose: bool,
}

impl ScalingConfig {
    /// Derive a config from a profile: bounds from
    /// `min_shards`/`max_shards`, `grow_depth` from the admission
    /// watermark when one is set.
    pub fn from_profile(p: &Profile) -> ScalingConfig {
        ScalingConfig {
            min_shards: p.min_shards.max(1),
            max_shards: p.max_shards.max(p.min_shards.max(1)),
            interval: Duration::from_millis(25),
            window: 4,
            grow_depth: p
                .admission_depth
                .map(|d| (d as f64 * 0.5).max(1.0))
                .unwrap_or(4.0),
            shrink_depth: 0.5,
            grow_burn_rate: 0.5,
            verbose: false,
        }
    }

    /// Same config with a different sampling cadence.
    pub fn with_interval(mut self, interval: Duration) -> ScalingConfig {
        self.interval = interval;
        self
    }

    /// Whether the bounds leave the controller any room to act.
    pub fn elastic(&self) -> bool {
        self.min_shards < self.max_shards
    }
}

/// One cumulative observation of the serving tier, taken at a sample
/// instant. Counters are monotone totals since cluster start (the
/// controller differences consecutive samples itself); `queue_depth`
/// is the live pending total across shards at the instant.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TierSample {
    /// Live shard count.
    pub shards: usize,
    /// Live pending-queue total across all shards.
    pub queue_depth: usize,
    /// Cumulative submissions shed at admission watermarks.
    pub shed: u64,
    /// Cumulative SLO burns across the per-kernel ledgers.
    pub slo_burns: u64,
    /// Cumulative completions.
    pub completed: u64,
}

/// What the controller wants done to the tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Spawn one more shard.
    Grow,
    /// Drain and retire one shard.
    Shrink,
    /// Leave the topology alone.
    Hold,
}

/// Per-interval deltas derived from two consecutive samples.
#[derive(Clone, Copy, Debug)]
struct IntervalLoad {
    /// Live queue depth per shard at the sample instant.
    depth_per_shard: f64,
    shed: u64,
    burns: u64,
    completed: u64,
}

/// The sliding-window scaling policy. Feed it one [`TierSample`] per
/// interval via [`ScalingController::observe`]; it answers with a
/// [`ScaleDecision`]. Pure state machine — no clocks, no threads.
pub struct ScalingController {
    cfg: ScalingConfig,
    window: VecDeque<IntervalLoad>,
    last: Option<TierSample>,
}

impl ScalingController {
    /// A controller with an empty window (first decision is always
    /// bounds enforcement or Hold).
    pub fn new(cfg: ScalingConfig) -> ScalingController {
        ScalingController { cfg, window: VecDeque::new(), last: None }
    }

    /// The config this controller runs under.
    pub fn config(&self) -> &ScalingConfig {
        &self.cfg
    }

    /// Ingest one sample and decide. Growth reacts to any pressured
    /// interval in the window; shrink demands a full calm window; both
    /// clear the window so the next decision re-gathers evidence under
    /// the new topology.
    pub fn observe(&mut self, s: TierSample) -> ScaleDecision {
        let prev = self.last.replace(s);
        let (shed, burns, completed) = match prev {
            // counters are cumulative; saturate so a merged-ledger
            // hiccup can never poison the window with huge deltas
            Some(p) => (s.shed.saturating_sub(p.shed),
                        s.slo_burns.saturating_sub(p.slo_burns),
                        s.completed.saturating_sub(p.completed)),
            None => (s.shed, s.slo_burns, s.completed),
        };
        self.window.push_back(IntervalLoad {
            depth_per_shard: s.queue_depth as f64 / s.shards.max(1) as f64,
            shed,
            burns,
            completed,
        });
        while self.window.len() > self.cfg.window.max(1) {
            self.window.pop_front();
        }
        // bounds enforcement outranks the signals
        if s.shards < self.cfg.min_shards {
            self.window.clear();
            return ScaleDecision::Grow;
        }
        if s.shards > self.cfg.max_shards {
            self.window.clear();
            return ScaleDecision::Shrink;
        }
        let burn_frac = {
            let (b, c) = self.window.iter().fold((0u64, 0u64), |(b, c), w| {
                (b + w.burns, c + w.completed)
            });
            if c == 0 { 0.0 } else { b as f64 / c as f64 }
        };
        // any pressured interval in the window counts: shed deltas and
        // burn counts integrate over the interval, and a queue-depth
        // spike caught by one sample stays persuasive for a full window
        // rather than having to land on the latest tick
        let pressured = self
            .window
            .iter()
            .any(|w| w.shed > 0 || w.depth_per_shard >= self.cfg.grow_depth)
            || burn_frac >= self.cfg.grow_burn_rate;
        if pressured && s.shards < self.cfg.max_shards {
            self.window.clear();
            return ScaleDecision::Grow;
        }
        let calm = self.window.len() >= self.cfg.window.max(1)
            && self.window.iter().all(|w| {
                w.shed == 0 && w.depth_per_shard <= self.cfg.shrink_depth
            })
            && burn_frac < self.cfg.grow_burn_rate;
        if calm && s.shards > self.cfg.min_shards {
            self.window.clear();
            return ScaleDecision::Shrink;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(min: usize, max: usize) -> ScalingConfig {
        ScalingConfig {
            min_shards: min,
            max_shards: max,
            interval: Duration::from_millis(25),
            window: 3,
            grow_depth: 4.0,
            shrink_depth: 0.5,
            grow_burn_rate: 0.5,
            verbose: false,
        }
    }

    fn sample(shards: usize, depth: usize, shed: u64, burns: u64,
              completed: u64) -> TierSample {
        TierSample { shards, queue_depth: depth, shed,
                     slo_burns: burns, completed }
    }

    #[test]
    fn sheds_trigger_growth_immediately() {
        let mut c = ScalingController::new(cfg(1, 4));
        assert_eq!(c.observe(sample(1, 0, 0, 0, 0)), ScaleDecision::Hold);
        // one shed interval is enough — no full window needed
        assert_eq!(c.observe(sample(1, 0, 3, 0, 10)), ScaleDecision::Grow);
    }

    #[test]
    fn queue_depth_triggers_growth_without_sheds() {
        let mut c = ScalingController::new(cfg(1, 4));
        // depth 9 over 2 shards = 4.5 per shard >= grow_depth 4.0
        assert_eq!(c.observe(sample(2, 9, 0, 0, 5)), ScaleDecision::Grow);
        // the window was cleared: a calm next sample holds
        assert_eq!(c.observe(sample(3, 0, 0, 0, 6)), ScaleDecision::Hold);
    }

    #[test]
    fn burn_rate_triggers_growth() {
        let mut c = ScalingController::new(cfg(1, 4));
        // 6 of 10 completions burned their SLO in the first interval
        assert_eq!(c.observe(sample(2, 0, 0, 6, 10)), ScaleDecision::Grow);
    }

    #[test]
    fn growth_respects_the_ceiling() {
        let mut c = ScalingController::new(cfg(1, 2));
        assert_eq!(c.observe(sample(2, 50, 9, 0, 1)), ScaleDecision::Hold,
                   "at max_shards pressure cannot grow");
    }

    #[test]
    fn shrink_needs_a_full_calm_window() {
        let mut c = ScalingController::new(cfg(1, 4));
        assert_eq!(c.observe(sample(3, 0, 0, 0, 10)), ScaleDecision::Hold);
        assert_eq!(c.observe(sample(3, 0, 0, 0, 11)), ScaleDecision::Hold,
                   "two calm samples < window of three");
        assert_eq!(c.observe(sample(3, 0, 0, 0, 12)), ScaleDecision::Shrink);
        // window cleared by the decision: calm must re-accumulate
        assert_eq!(c.observe(sample(2, 0, 0, 0, 12)), ScaleDecision::Hold);
    }

    #[test]
    fn one_pressured_interval_resets_the_calm_run() {
        let mut c = ScalingController::new(cfg(1, 4));
        assert_eq!(c.observe(sample(4, 0, 0, 0, 0)), ScaleDecision::Hold);
        assert_eq!(c.observe(sample(4, 0, 0, 0, 0)), ScaleDecision::Hold);
        // a shed in the third interval both blocks shrink and grows...
        assert_eq!(c.observe(sample(4, 0, 2, 0, 4)), ScaleDecision::Hold,
                   "...unless already at max — then it holds");
        // (4 == max_shards here, so pressure holds instead of growing)
        assert_eq!(c.observe(sample(4, 0, 2, 0, 4)), ScaleDecision::Hold);
    }

    #[test]
    fn shrink_respects_the_floor() {
        let mut c = ScalingController::new(cfg(2, 4));
        for _ in 0..6 {
            let d = c.observe(sample(2, 0, 0, 0, 0));
            assert_eq!(d, ScaleDecision::Hold, "at min_shards calm holds");
        }
    }

    #[test]
    fn bounds_enforcement_outranks_signals() {
        let mut c = ScalingController::new(cfg(2, 4));
        // below the floor: grow even under pressure-free calm
        assert_eq!(c.observe(sample(1, 0, 0, 0, 0)), ScaleDecision::Grow);
        // above the ceiling: shrink even while shedding
        let mut c = ScalingController::new(cfg(1, 2));
        assert_eq!(c.observe(sample(3, 90, 9, 9, 9)), ScaleDecision::Shrink);
    }

    #[test]
    fn cumulative_counters_are_differenced() {
        // the very first sample has no baseline: its raw totals count
        // as one interval, so a history of sheds reads as pressure
        let mut c = ScalingController::new(cfg(1, 4));
        assert_eq!(c.observe(sample(2, 0, 1000, 0, 5000)),
                   ScaleDecision::Grow);
        // with a baseline established, *flat* cumulative totals are
        // calm intervals — the stale history cannot re-trigger growth,
        // and a full calm window shrinks
        let mut c = ScalingController::new(cfg(1, 4));
        c.observe(sample(2, 0, 1000, 0, 5000)); // baseline (clears window)
        assert_eq!(c.observe(sample(2, 0, 1000, 0, 5000)),
                   ScaleDecision::Hold);
        assert_eq!(c.observe(sample(2, 0, 1000, 0, 5000)),
                   ScaleDecision::Hold);
        assert_eq!(c.observe(sample(2, 0, 1000, 0, 5000)),
                   ScaleDecision::Shrink,
                   "three flat intervals fill the calm window");
    }

    #[test]
    fn from_profile_derives_thresholds() {
        let p = Profile::skylake_sim().with_shard_bounds(1, 4)
            .with_admission_depth(16);
        let cfg = ScalingConfig::from_profile(&p);
        assert_eq!((cfg.min_shards, cfg.max_shards), (1, 4));
        assert!(cfg.elastic());
        assert_eq!(cfg.grow_depth, 8.0, "half the admission watermark");
        let p = Profile::skylake_sim().with_shard_bounds(2, 2);
        let cfg = ScalingConfig::from_profile(&p);
        assert!(!cfg.elastic());
        assert_eq!(cfg.grow_depth, 4.0, "unbounded admission default");
    }
}
