//! Sharded serving tier: an **elastic** cluster of per-shard [`Server`]
//! engines behind one admission front-end.
//!
//! [`ClusterHandle::submit`] is the cluster's admission point. Each
//! request is planned once through the cluster's shared [`PlanCache`]
//! and routed to a shard by **rendezvous hashing on the planned kernel
//! id**, so one kernel's traffic always lands on one shard and the
//! shard-local kernel-keyed batching stays effective. Rendezvous scores
//! are deliberately coarse (16-bit): score ties are where the live
//! least-loaded tiebreak — fed by each shard's current queue depth —
//! gets to act, while routing stays deterministic per key at a fixed
//! topology.
//!
//! Each shard is a full engine (worker pool, batcher, thread-budget
//! ledger, per-shard metrics) and enforces its own queue-depth
//! admission watermark, shedding excess submissions as typed
//! [`Error::Overloaded`] instead of queueing without bound. Per-shard
//! fault accounting stays independent while serving — the shape FT-GEMM
//! (arXiv:2305.02444) uses for per-stream ABFT state — and ledgers are
//! merged exactly at read time via [`MetricsSnapshot::merge`]: counters
//! sum, latency summaries are recomputed from every retained sample,
//! never from per-shard means.
//!
//! ## Elasticity
//!
//! The shard set is mutable at runtime, between the profile's
//! `min_shards`/`max_shards` bounds:
//!
//! - **Grow** ([`ClusterHandle::scale_up`]): a new [`Server`] engine is
//!   spawned on the shared `Arc<Router>` and appended at the next slot
//!   with a **fresh rendezvous salt** ([`salt_for`] over a
//!   monotonically increasing generation). Rendezvous hashing makes the
//!   migration minimal by construction: survivors' scores are
//!   untouched, so the only kernel-id keys that change owner are
//!   exactly those the new shard now wins — ~1/(n+1) of the key space —
//!   and re-salting means a slot that is drained and later re-grown
//!   claims a *different* slice each generation instead of recalling
//!   the old one. The migrated-key count lands in the merged ledger.
//! - **Shrink** ([`ClusterHandle::scale_down`]): the newest slot is the
//!   victim (removing the top slot is the rendezvous-minimal drain:
//!   only keys the victim owned move, each falling back to its
//!   second-choice shard). The victim is first unrouted — removed from
//!   the topology under the write lock, so no new submission can reach
//!   it — then drained: its workers finish every queued batch, its
//!   final [`MetricsSnapshot`] is retired into the survivor ledger, and
//!   only then is the engine joined. In-flight requests are never
//!   dropped; their responses arrive on the receivers the clients
//!   already hold.
//!
//! Scaling can be driven manually (the two methods above) or by the
//! [`ScalingController`] sampling loop that [`Cluster::start`] spawns
//! when the config carries a [`ScalingConfig`]
//! ([`crate::coordinator::autoscale`] documents the decision rules).
//!
//! ## Injection campaigns
//!
//! When the config carries a [`CampaignConfig`], the cluster starts a
//! cluster-wide [`InjectionCampaign`] and threads it through the shared
//! `Arc<Router>`. Because the campaign's strike schedule is a pure
//! function of `(seed, KernelId, occurrence)` and its occurrence
//! counters are cluster-wide, the campaign is **elasticity-proof**:
//!
//! - a shard spawned by `scale_up` mid-run inherits its slice of the
//!   campaign — the strikes of whatever kernels rendezvous routing
//!   assigns it — the moment its workers start, with no hand-off;
//! - a kernel migrated to a fresh-salted shard *continues* its
//!   occurrence sequence instead of replaying it (no double
//!   injection);
//! - `scale_down` retires the victim's strike outcomes (injected /
//!   detected / corrected / escaped) exactly, with its ledger.
//!
//! `ftblas soak` drives this end to end and gates CI on the outcome.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use anyhow::anyhow;

use crate::config::Profile;
use crate::coordinator::autoscale::{ScaleDecision, ScalingConfig,
                                    ScalingController, TierSample};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::plan::{ExecutionPlan, PlanCache, Planner,
                               SelectionPolicy};
use crate::coordinator::registry::{self, KernelRegistry};
use crate::coordinator::request::{BlasRequest, BlasResponse};
use crate::coordinator::router::Router;
use crate::coordinator::server::{Admitted, Server, ServerHandle};
use crate::ft::injector::{CampaignConfig, InjectionCampaign, InjectorConfig};
use crate::ft::policy::FtPolicy;
use crate::runtime::pool::ComputePool;
use crate::util::rng::Rng;

pub use crate::coordinator::server::Error;

/// Cluster sizing. Routing and admission knobs (`shards` here is the
/// starting instance count; the per-shard `admission_depth` watermark,
/// the SLO table, and the elastic `min_shards`/`max_shards` bounds)
/// live on [`Profile`], so one profile describes the whole tier.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Starting shard (engine) count; clamped to at least 1.
    pub shards: usize,
    /// Native worker threads per shard.
    pub workers_per_shard: usize,
    /// **Per-call** fault-injection config, split across the starting
    /// shards (independent per-shard plans with derived seeds; shards
    /// grown later join uninjected — their traffic was not in the
    /// plan). For rate-based, topology-proof injection use `campaign`
    /// instead; a live campaign takes precedence at the workers.
    pub injection: Option<InjectorConfig>,
    /// Expected request volume (sizes each shard's injection plan).
    pub expected_requests: usize,
    /// Cluster-wide **injection campaign**: a seeded, rate-based,
    /// scheme-aware strike schedule owned by the cluster and shared by
    /// every shard through the `Arc<Router>` — shards the autoscaler
    /// spawns mid-run deterministically inherit their slice of it (the
    /// strikes of the kernels routing assigns them), and a drained
    /// shard's strike outcomes are retired exactly with its ledger.
    pub campaign: Option<CampaignConfig>,
    /// When set, [`Cluster::start`] spawns a [`ScalingController`]
    /// sampling thread that grows/shrinks the tier automatically.
    /// `None` = fixed-size (manual `scale_up`/`scale_down` still work,
    /// bounded by the profile).
    pub autoscale: Option<ScalingConfig>,
}

impl ClusterConfig {
    /// Sizing from a profile: starting shards, workers per shard, no
    /// per-call injection, the profile's campaign knobs, and an
    /// autoscaler iff the profile's shard bounds are elastic.
    pub fn from_profile(p: &Profile) -> ClusterConfig {
        ClusterConfig {
            shards: p.shards,
            workers_per_shard: p.workers,
            injection: None,
            expected_requests: 0,
            campaign: p.campaign.clone(),
            autoscale: p.elastic().then(|| ScalingConfig::from_profile(p)),
        }
    }
}

/// Base salt for the rendezvous hash (chosen so the registry's
/// kernel-id key space spreads across small shard counts; see the
/// coverage proptest).
const ROUTE_SALT: u64 = 0xD1B5_4A32_D192_ED03;

/// Generation stride for [`salt_for`] (the 64-bit golden ratio, so
/// successive generations of one slot land far apart in salt space).
const GENERATION_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// SplitMix64 finalizer — the avalanche step behind the rendezvous
/// scores.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Rendezvous salt of a shard slot at a given spawn generation.
/// Generation 0 reproduces the fixed-topology salts of the pre-elastic
/// tier (`ROUTE_SALT ^ slot`); every regrow of a slot bumps the
/// generation, so the slot claims a fresh pseudo-random key slice
/// instead of recalling the one its previous occupant held.
pub fn salt_for(slot: usize, generation: u64) -> u64 {
    ROUTE_SALT ^ (slot as u64) ^ generation.wrapping_mul(GENERATION_STRIDE)
}

/// 16-bit rendezvous score of `(key, salt)`. Coarse on purpose: equal
/// scores are rare but reachable, and they are exactly where the live
/// least-loaded tiebreak acts.
pub fn rendezvous_score_salted(key: u64, salt: u64) -> u64 {
    mix64(key ^ mix64(salt)) >> 48
}

/// [`rendezvous_score_salted`] at a slot's generation-0 salt — the
/// fixed-topology score (tests, simulation).
pub fn rendezvous_score(key: u64, shard: usize) -> u64 {
    rendezvous_score_salted(key, salt_for(shard, 0))
}

/// The shared routing core: highest rendezvous score wins; equal
/// scores fall to the shallower live queue, then the lower slot index.
/// `depth_of` is only called on score ties (~2⁻¹⁶ of key pairs), so the
/// hot path never touches shard state — the cluster passes a closure
/// that locks a shard's scheduler only when the tiebreak actually needs
/// its queue depth. Deterministic for fixed depths, and since depths
/// only matter on ties, a key's shard is stable at a fixed topology in
/// steady state.
fn route_core<S, F>(key: u64, shards: usize, salt_of: S, mut depth_of: F)
                    -> usize
where
    S: Fn(usize) -> u64,
    F: FnMut(usize) -> usize,
{
    assert!(shards > 0, "route needs at least one shard");
    // pass 1: pure rendezvous argmax (lowest index on equal scores)
    let mut best = 0;
    let mut best_score = rendezvous_score_salted(key, salt_of(0));
    let mut tied = false;
    for s in 1..shards {
        let score = rendezvous_score_salted(key, salt_of(s));
        if score > best_score {
            best = s;
            best_score = score;
            tied = false;
        } else if score == best_score {
            tied = true;
        }
    }
    if !tied {
        return best;
    }
    // pass 2 (rare): the tie falls to the shallowest queue; a strict
    // comparison keeps the lower index on equal depths
    let mut best_depth = depth_of(best);
    for s in (best + 1)..shards {
        if rendezvous_score_salted(key, salt_of(s)) == best_score {
            let depth = depth_of(s);
            if depth < best_depth {
                best = s;
                best_depth = depth;
            }
        }
    }
    best
}

/// Route over generation-0 salts (the fixed-topology view); depths are
/// fetched lazily, only on rendezvous ties.
pub fn route_with<F: FnMut(usize) -> usize>(key: u64, shards: usize,
                                            depth_of: F) -> usize {
    route_core(key, shards, |s| salt_for(s, 0), depth_of)
}

/// Route over an explicit per-shard salt slice — the elastic tier's
/// view, where a regrown slot carries a fresh-generation salt.
pub fn route_salted_with<F: FnMut(usize) -> usize>(key: u64, salts: &[u64],
                                                   depth_of: F) -> usize {
    route_core(key, salts.len(), |s| salts[s], depth_of)
}

/// [`route_with`] over a pre-collected depth slice (tests, simulation).
pub fn route(key: u64, depths: &[usize]) -> usize {
    route_with(key, depths.len(), |s| depths[s])
}

/// [`route_salted_with`] over a pre-collected depth slice.
pub fn route_salted(key: u64, salts: &[u64], depths: &[usize]) -> usize {
    route_salted_with(key, salts, |s| depths[s])
}

/// Routing key of a request: the planned kernel id. Every admitted job
/// is planned — native, PJRT, and GPU-sim requests all resolve to
/// registry-resident descriptors — so one kernel's traffic always lands
/// on one shard and the shard-local kernel-keyed batching stays
/// effective.
pub fn route_key(plan: &ExecutionPlan) -> u64 {
    plan.kernel_id.0 as u64
}

/// Bounded retry policy for [`ClusterHandle::submit_with_retry`]:
/// exponential backoff with deterministic jitter around the typed
/// [`Error::Overloaded`] shed. Sheds mean "the shard's queue is full
/// *right now*" — under bursty arrivals a short, jittered wait usually
/// lands in the drain phase, so clients retry instead of losing work.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retry attempts after the first submission (0 = no retries).
    pub attempts: u32,
    /// Backoff before the first retry; doubles per retry.
    pub base: std::time::Duration,
    /// Ceiling on one backoff step (the exponential is clamped here).
    pub cap: std::time::Duration,
    /// Base seed for the jitter stream (each retry adds a uniform
    /// fraction of `base`). Every `submit_with_retry` call mixes a
    /// per-cluster call counter into this seed, so concurrent callers
    /// sharing one policy still draw distinct jitter and de-synchronize
    /// instead of colliding in lockstep.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 5,
            base: std::time::Duration::from_micros(500),
            cap: std::time::Duration::from_millis(20),
            jitter_seed: 0x5EED,
        }
    }
}

/// A live shard slot: its routing salt plus the engine handle.
struct ShardEntry {
    /// Slot index (stable while live; reused after a shrink+regrow,
    /// but with a fresh-generation salt).
    slot: usize,
    salt: u64,
    handle: ServerHandle,
}

/// A live engine owned by the cluster (the join side of a slot).
struct Engine {
    slot: usize,
    server: Server,
}

/// Scale-event counters, folded into merged snapshots.
#[derive(Default)]
struct ScaleStats {
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
    keys_migrated: AtomicU64,
}

struct ClusterShared {
    plans: PlanCache,
    router: Arc<Router>,
    policy: FtPolicy,
    workers_per_shard: usize,
    /// Elastic bounds from the profile; manual and automatic scaling
    /// both respect them.
    min_shards: usize,
    max_shards: usize,
    /// The live routing topology. Submissions hold the read lock from
    /// route through enqueue, so a scale-down (write lock) can never
    /// unroute a shard while a submission is mid-flight toward it —
    /// the drain invariant needs no per-request retry loop.
    topology: RwLock<Vec<ShardEntry>>,
    /// The engines behind the topology. This mutex also serializes
    /// scale operations (one grow/shrink at a time).
    engines: Mutex<Vec<Engine>>,
    /// Final ledgers of drained (retired) shards — merged into every
    /// cluster-wide snapshot so scale-downs never lose history.
    retired: Mutex<Vec<MetricsSnapshot>>,
    /// Monotone spawn-generation counter; starting shards take
    /// generation 0 (the fixed-topology salts), every later spawn a
    /// fresh one.
    next_generation: AtomicU64,
    /// Monotone `submit_with_retry` call counter — mixed into the
    /// retry policy's jitter seed so concurrent callers draw distinct
    /// backoff jitter.
    retry_calls: AtomicU64,
    stats: ScaleStats,
    stop: AtomicBool,
}

impl ClusterShared {
    /// Count the registry kernel-id keys whose owner differs between
    /// two salt vectors (zero-depth routing: the deterministic,
    /// steady-state view of the topology).
    fn migrated_keys(old: &[u64], new: &[u64]) -> u64 {
        let ids = KernelRegistry::global().entries().len() as u64;
        (0..ids)
            .filter(|&k| {
                let a = if old.is_empty() { usize::MAX }
                        else { route_salted_with(k, old, |_| 0) };
                let b = route_salted_with(k, new, |_| 0);
                a != b
            })
            .count() as u64
    }

    /// Grow by one shard. Returns the new shard count, or an error at
    /// the `max_shards` ceiling.
    fn scale_up(&self) -> anyhow::Result<usize> {
        // the engines mutex serializes scale ops end to end
        let mut engines = self.engines.lock().unwrap();
        if self.stop.load(Ordering::SeqCst) {
            return Err(anyhow!("cluster is shut down"));
        }
        let old_salts: Vec<u64> = {
            let topo = self.topology.read().unwrap();
            if topo.len() >= self.max_shards {
                return Err(anyhow!("cluster already at max_shards ({})",
                                   self.max_shards));
            }
            topo.iter().map(|e| e.salt).collect()
        };
        let slot = old_salts.len();
        let generation = self.next_generation.fetch_add(1, Ordering::SeqCst);
        let salt = salt_for(slot, generation);
        let server = Server::start_shard(slot, self.router.clone(),
                                         self.policy, self.workers_per_shard,
                                         None, 0);
        let handle = server.handle();
        let mut new_salts = old_salts.clone();
        new_salts.push(salt);
        let migrated = Self::migrated_keys(&old_salts, &new_salts);
        {
            let mut topo = self.topology.write().unwrap();
            topo.push(ShardEntry { slot, salt, handle });
        }
        engines.push(Engine { slot, server });
        self.stats.scale_ups.fetch_add(1, Ordering::Relaxed);
        self.stats.keys_migrated.fetch_add(migrated, Ordering::Relaxed);
        Ok(slot + 1)
    }

    /// Shrink by one shard: unroute the newest slot, drain it, retire
    /// its ledger. Returns the new shard count, or an error at the
    /// `min_shards` floor. Blocks until the victim's queue is fully
    /// drained — zero in-flight requests are dropped.
    fn scale_down(&self) -> anyhow::Result<usize> {
        let mut engines = self.engines.lock().unwrap();
        // unroute the victim and park a provisional snapshot in
        // `retired` in ONE topology write scope: readers that take the
        // topology lock and then the retired lock (sample, the merged
        // snapshots) therefore see the victim in exactly one of the two
        // sets — never both (double-count) and never neither (a dip
        // that a differencing autoscaler would misread as fresh
        // pressure when it reverses). Mid-drain reads undercount only
        // the victim's in-drain completions; counters never go
        // backwards. Scale ops are serialized by the engines mutex, so
        // the provisional entry's index is stable until the exact final
        // ledger replaces it below.
        let (victim_entry, remaining, provisional) = {
            let mut topo = self.topology.write().unwrap();
            if topo.len() <= self.min_shards {
                return Err(anyhow!("cluster already at min_shards ({})",
                                   self.min_shards));
            }
            // the newest slot is the rendezvous-minimal victim: only
            // keys it owned migrate, survivors' scores are untouched
            let victim = topo
                .pop()
                .expect("min_shards >= 1 keeps the topology non-empty");
            let remaining: Vec<u64> = topo.iter().map(|e| e.salt).collect();
            let mut retired = self.retired.lock().unwrap();
            retired.push(victim.handle.metrics());
            (victim, remaining, retired.len() - 1)
        };
        let mut old_salts = remaining.clone();
        old_salts.push(victim_entry.salt);
        let migrated = Self::migrated_keys(&old_salts, &remaining);
        // the victim is unrouted; drain it outside the topology lock so
        // admissions to the survivors proceed while it finishes
        let pos = engines
            .iter()
            .position(|e| e.slot == victim_entry.slot)
            .expect("routed shard must have a live engine");
        let engine = engines.remove(pos);
        let final_ledger = engine.server.shutdown();
        self.retired.lock().unwrap()[provisional] = final_ledger;
        self.stats.scale_downs.fetch_add(1, Ordering::Relaxed);
        self.stats.keys_migrated.fetch_add(migrated, Ordering::Relaxed);
        Ok(remaining.len())
    }

    /// Cheap cumulative tier counters for the autoscaler: live queue
    /// depth plus (completed, shed, burns) summed over live shards and
    /// retired ledgers — retirement moves counters between the two
    /// sets, so the totals the controller differences stay monotone.
    fn sample(&self) -> TierSample {
        // the retired read nests inside the topology read scope:
        // scale_down migrates a shard topology→retired atomically under
        // the write lock, so one consistent scope counts every shard
        // exactly once and the totals stay monotone across drains
        let topo = self.topology.read().unwrap();
        let mut queue_depth = 0usize;
        let mut completed = 0u64;
        let mut shed = 0u64;
        let mut burns = 0u64;
        for e in topo.iter() {
            queue_depth += e.handle.queue_depth();
            let (c, s, b) = e.handle.pressure();
            completed += c;
            shed += s;
            burns += b;
        }
        for r in self.retired.lock().unwrap().iter() {
            completed += r.completed;
            shed += r.shed;
            burns += r.slo_burns();
        }
        TierSample { shards: topo.len(), queue_depth, shed,
                     slo_burns: burns, completed }
    }

    /// Fold the shared plan-cache and scale counters into a merged
    /// snapshot.
    fn finish_snapshot(&self, shards: &[MetricsSnapshot]) -> MetricsSnapshot {
        let retired = self.retired.lock().unwrap();
        let mut parts: Vec<MetricsSnapshot> = retired.clone();
        parts.extend_from_slice(shards);
        let mut merged = MetricsSnapshot::merge(&parts);
        let (hits, misses) = self.plans.stats();
        merged.plan_cache_hits += hits;
        merged.plan_cache_misses += misses;
        merged.scale_ups = self.stats.scale_ups.load(Ordering::Relaxed);
        merged.scale_downs = self.stats.scale_downs.load(Ordering::Relaxed);
        merged.keys_migrated = self.stats.keys_migrated.load(Ordering::Relaxed);
        // pool counters are cluster-level (one pool shared by every
        // shard via the router), so they are stamped once here — the
        // per-shard snapshots carry zeros and the merge stays exact
        if let Some(pool) = self.router.pool() {
            merged.pool = pool.stats();
        }
        merged
    }

    /// Consistent cluster-wide snapshot: the live ledgers are collected
    /// and merged with the retired set inside one topology read scope,
    /// so a concurrent scale-down (which migrates a shard between the
    /// two sets under the write lock) can never double-count or drop a
    /// shard in the merged view.
    fn merged_snapshot(&self) -> MetricsSnapshot {
        let topo = self.topology.read().unwrap();
        let live: Vec<MetricsSnapshot> =
            topo.iter().map(|e| e.handle.metrics()).collect();
        // finish_snapshot locks `retired` while we still hold the
        // topology read lock — the same topology→retired order
        // scale_down nests under its write lock
        self.finish_snapshot(&live)
    }
}

/// One live routing slot, as reported by [`ClusterHandle::topology`].
#[derive(Clone, Debug)]
pub struct ShardSlot {
    /// Slot index (stable while live; reused after a shrink+regrow).
    pub slot: usize,
    /// The slot's rendezvous salt — [`salt_for`]`(slot, generation)`,
    /// so a reused slot is distinguishable from its predecessor.
    pub salt: u64,
    /// The shard's live queue depth at snapshot time.
    pub queue_depth: usize,
}

/// A read-only snapshot of the live routing topology — the admin
/// surface behind the gateway's `GET /topology` route. Taken under one
/// topology read scope, so the slot list is internally consistent
/// (never a mid-scale half-view).
#[derive(Clone, Debug)]
pub struct TopologySnapshot {
    /// Live slots in routing order.
    pub shards: Vec<ShardSlot>,
    /// The generation the *next* spawned shard will take (monotone;
    /// starting shards took generation 0).
    pub next_generation: u64,
    /// Cumulative grow events.
    pub scale_ups: u64,
    /// Cumulative shrink events.
    pub scale_downs: u64,
}

/// Handle for submitting requests to the cluster; cheap to clone.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<ClusterShared>,
}

impl ClusterHandle {
    /// The shared admission front half: resolve the request's plan
    /// through the shared cache and derive its routing key. Both
    /// `submit` and `shard_for` go through here, so key derivation can
    /// never drift between the two. A per-request routing overlay (the
    /// wire contract's `routing` object) merges into the cluster's base
    /// selection; an unsatisfiable selection surfaces as
    /// [`Error::NoCandidate`] carrying the planner's full per-descriptor
    /// diagnostics.
    fn plan_key(&self, req: &BlasRequest,
                routing: Option<&SelectionPolicy>)
                -> Result<(ExecutionPlan, u64), Error> {
        let policy = self.shared.policy;
        let base = self.shared.router.selection_for(req, policy);
        let sel = match routing {
            Some(overlay) => base.merged_with(overlay),
            None => base,
        };
        let Some(plan) = self
            .shared
            .plans
            .resolve(req.routine(), req.dim(), policy, &sel)
        else {
            // re-run selection outside the cache for the exhaustive
            // per-descriptor miss list; shard 0 = rejected at the door
            let detail = Planner::new(self.shared.plans.profile())
                .select_dims(req.routine(), req.dim(), &sel, policy)
                .expect_err("cache said no plan exists")
                .to_string();
            return Err(Error::NoCandidate { shard: 0, detail });
        };
        Ok((plan, route_key(&plan)))
    }

    /// The registry's backend inventory (`ftblas.backends.v1`), with the
    /// attached PJRT backend's live health probe folded in — the single
    /// serializer behind both the gateway's `/backends` route and the
    /// `ftblas backends` subcommand.
    pub fn backends_json(&self) -> crate::util::json::Json {
        registry::backends_json(self.shared.router.pjrt_health())
    }

    /// Admit a request: plan it once (shared cache), route it to its
    /// shard, enqueue it there. Returns the typed [`Error::Overloaded`]
    /// when the target shard's queue is at its admission watermark.
    ///
    /// The topology read lock is held from route through enqueue, so a
    /// concurrent scale-down can never drain the target shard out from
    /// under an admitted request.
    ///
    /// ```
    /// use ftblas::config::Profile;
    /// use ftblas::coordinator::cluster::{Cluster, ClusterConfig};
    /// use ftblas::coordinator::request::{Backend, BlasRequest};
    /// use ftblas::coordinator::router::Router;
    /// use ftblas::ft::policy::FtPolicy;
    ///
    /// let router = Router::native_only(Profile::default(),
    ///                                  Backend::NativeTuned);
    /// let cluster = Cluster::start(router, FtPolicy::None,
    ///                              ClusterConfig {
    ///                                  workers_per_shard: 1,
    ///                                  ..ClusterConfig::from_profile(
    ///                                      &Profile::default())
    ///                              });
    /// let handle = cluster.handle();
    /// let rx = handle
    ///     .submit(BlasRequest::Ddot { x: vec![1.0; 64], y: vec![2.0; 64] })
    ///     .expect("unbounded admission never sheds");
    /// let resp = rx.recv().unwrap().unwrap();
    /// assert_eq!(resp.result.as_scalar(), Some(128.0));
    /// cluster.shutdown();
    /// ```
    pub fn submit(&self, req: BlasRequest) -> Admitted {
        self.submit_returning(req, None).map_err(|(e, _)| e)
    }

    /// [`ClusterHandle::submit`] with a per-request selection overlay:
    /// the overlay's preferences take precedence over the cluster's base
    /// selection, its allowlist intersects, and its denies/requirements
    /// accumulate (see [`SelectionPolicy::merged_with`]).
    pub fn submit_routed(&self, req: BlasRequest,
                         routing: &SelectionPolicy) -> Admitted {
        self.submit_returning(req, Some(routing)).map_err(|(e, _)| e)
    }

    /// [`ClusterHandle::submit`] that hands a rejected request back to
    /// the caller — the no-clone substrate under `submit_with_retry`.
    fn submit_returning(&self, req: BlasRequest,
                        routing: Option<&SelectionPolicy>)
                        -> Result<std::sync::mpsc::Receiver<
                                      anyhow::Result<BlasResponse>>,
                                  (Error, BlasRequest)> {
        let (plan, key) = match self.plan_key(&req, routing) {
            Ok(pk) => pk,
            Err(e) => return Err((e, req)),
        };
        let topo = self.shared.topology.read().unwrap();
        if topo.is_empty() {
            // the cluster was shut down while this handle survived
            return Err((Error::ShuttingDown { shard: 0 }, req));
        }
        let shard = route_core(key, topo.len(), |s| topo[s].salt,
                               |s| topo[s].handle.queue_depth());
        topo[shard].handle.submit_planned_returning(req, plan)
    }

    /// [`ClusterHandle::submit`] with bounded exponential backoff and
    /// deterministic jitter around [`Error::Overloaded`] sheds. Returns
    /// the final admission outcome plus how many retries were spent;
    /// non-overload rejections (shutdown) surface immediately.
    ///
    /// ```
    /// use ftblas::config::Profile;
    /// use ftblas::coordinator::cluster::{Cluster, ClusterConfig,
    ///                                    RetryPolicy};
    /// use ftblas::coordinator::request::{Backend, BlasRequest};
    /// use ftblas::coordinator::router::Router;
    /// use ftblas::ft::policy::FtPolicy;
    ///
    /// let router = Router::native_only(Profile::default(),
    ///                                  Backend::NativeTuned);
    /// let cluster = Cluster::start(router, FtPolicy::None,
    ///                              ClusterConfig {
    ///                                  workers_per_shard: 1,
    ///                                  ..ClusterConfig::from_profile(
    ///                                      &Profile::default())
    ///                              });
    /// let handle = cluster.handle();
    /// let req = BlasRequest::Ddot { x: vec![1.0; 32], y: vec![1.0; 32] };
    /// let (admitted, retries) =
    ///     handle.submit_with_retry(req, &RetryPolicy::default());
    /// assert_eq!(retries, 0, "an idle cluster admits on the first try");
    /// admitted.unwrap().recv().unwrap().unwrap();
    /// cluster.shutdown();
    /// ```
    pub fn submit_with_retry(&self, req: BlasRequest, policy: &RetryPolicy)
                             -> (Admitted, u32) {
        self.submit_with_retry_routed(req, policy, None)
    }

    /// [`ClusterHandle::submit_with_retry`] with an optional per-request
    /// selection overlay — the gateway's submission path. Planning
    /// failures ([`Error::NoCandidate`]) are not retried: the registry
    /// is static, so a selection that admits no candidate now never
    /// will.
    pub fn submit_with_retry_routed(&self, req: BlasRequest,
                                    policy: &RetryPolicy,
                                    routing: Option<&SelectionPolicy>)
                                    -> (Admitted, u32) {
        // per-call seed: concurrent callers sharing one policy must not
        // draw identical jitter, or their retries collide in lockstep
        let call = self.shared.retry_calls.fetch_add(1, Ordering::Relaxed);
        let mut jitter = Rng::new(policy.jitter_seed ^ mix64(call));
        let mut backoff = policy.base;
        // rejected submissions hand the request back, so each retry
        // re-submits the same value — no clone per attempt
        let mut req = req;
        for attempt in 0..=policy.attempts {
            match self.submit_returning(req, routing) {
                Err((Error::Overloaded { .. }, returned))
                    if attempt < policy.attempts =>
                {
                    req = returned;
                    let pause = backoff.min(policy.cap)
                        + policy.base.mul_f64(jitter.uniform());
                    std::thread::sleep(pause);
                    backoff = backoff.saturating_mul(2);
                }
                Err((e, _)) => return (Err(e), attempt),
                Ok(rx) => return (Ok(rx), attempt),
            }
        }
        unreachable!("the final attempt always returns")
    }

    /// The shard `submit` would route this request to right now
    /// (panics on a shut-down cluster, which has no shards left, and on
    /// a request the cluster's base selection cannot plan).
    pub fn shard_for(&self, req: &BlasRequest) -> usize {
        let (_, key) = self
            .plan_key(req, None)
            .expect("shard_for called with an unplannable request");
        let topo = self.shared.topology.read().unwrap();
        route_core(key, topo.len(), |s| topo[s].salt,
                   |s| topo[s].handle.queue_depth())
    }

    /// Submit and wait (sheds surface as errors).
    pub fn call(&self, req: BlasRequest) -> anyhow::Result<BlasResponse> {
        self.submit(req)
            .map_err(anyhow::Error::new)?
            .recv()
            .map_err(|_| anyhow!("cluster dropped the request"))?
    }

    /// Live shard count.
    pub fn shard_count(&self) -> usize {
        self.shared.topology.read().unwrap().len()
    }

    /// Cumulative `(scale_ups, scale_downs)` — a cheap poll for callers
    /// watching the elastic tier (no ledger merge, no sample clones).
    pub fn scale_events(&self) -> (u64, u64) {
        (self.shared.stats.scale_ups.load(Ordering::Relaxed),
         self.shared.stats.scale_downs.load(Ordering::Relaxed))
    }

    /// Grow the tier by one shard (also the autoscaler's actuator).
    /// Fails at the profile's `max_shards` ceiling.
    pub fn scale_up(&self) -> anyhow::Result<usize> {
        self.shared.scale_up()
    }

    /// Drain and retire one shard (also the autoscaler's actuator).
    /// Blocks until the victim finishes its queue; fails at the
    /// profile's `min_shards` floor.
    pub fn scale_down(&self) -> anyhow::Result<usize> {
        self.shared.scale_down()
    }

    /// Exact cluster-wide snapshot: live per-shard ledgers merged with
    /// every retired shard's final ledger, plus the shared plan-cache
    /// and scale counters (consistent under concurrent scaling).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.merged_snapshot()
    }

    /// The cluster's live injection campaign, if one is running (the
    /// soak driver reads its armed/suppressed counters to cross-check
    /// the ledger).
    pub fn campaign(&self) -> Option<&InjectionCampaign> {
        self.shared.router.campaign()
    }

    /// Consistent snapshot of the live routing topology: every slot's
    /// index, salt, and queue depth, plus the generation counter and
    /// cumulative scale events (collected under one topology read
    /// scope — a concurrent scale op appears entirely or not at all).
    pub fn topology(&self) -> TopologySnapshot {
        let topo = self.shared.topology.read().unwrap();
        let shards = topo
            .iter()
            .map(|e| ShardSlot {
                slot: e.slot,
                salt: e.salt,
                queue_depth: e.handle.queue_depth(),
            })
            .collect();
        TopologySnapshot {
            shards,
            next_generation: self.shared
                .next_generation
                .load(Ordering::SeqCst),
            scale_ups: self.shared.stats.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.shared
                .stats
                .scale_downs
                .load(Ordering::Relaxed),
        }
    }
}

/// The cluster: an elastic set of [`Server`] engines over one shared
/// read-only router.
pub struct Cluster {
    shared: Arc<ClusterShared>,
    controller: Option<JoinHandle<()>>,
}

impl Cluster {
    /// Start `cfg.shards` engines sharing one router. Injection plans
    /// are split across the starting shards (independent seeds, counts
    /// divided with the remainder on the low shards). Note the split
    /// assumes roughly balanced traffic: each shard plans its share
    /// over its own expected stream, so a shard that routing starves of
    /// requests fires fewer of its planned faults — cluster totals are
    /// an upper bound, not a guarantee (the ledger's `errors_injected`
    /// reports what actually fired).
    ///
    /// With `cfg.autoscale` set, a [`ScalingController`] thread samples
    /// the tier every `interval` and grows/shrinks it inside the
    /// profile's shard bounds; [`Cluster::shutdown`] joins it.
    pub fn start(router: Router, policy: FtPolicy, mut cfg: ClusterConfig)
                 -> Cluster {
        let n = cfg.shards.max(1);
        // the cluster owns the campaign: started here, carried by the
        // shared router so every shard — starting or spawned mid-run —
        // arms strikes from the same clock, rate budget, and
        // cluster-wide occurrence counters
        let router = match cfg.campaign.take() {
            Some(campaign) => router.with_campaign(campaign),
            None => router,
        };
        // the cluster owns the compute pool the same way: one
        // persistent work-stealing worker set, sized from the profile's
        // thread budget, carried by the shared router so every shard —
        // starting or spawned mid-run — submits band tasks to the same
        // long-lived workers instead of fork/joining per call.
        // `--no-pool` (or a pre-attached pool) leaves the router as-is.
        let router = if router.pool.is_none() && !router.profile.no_pool {
            let workers = router.profile.pool_worker_count();
            router.with_pool(Arc::new(ComputePool::new(workers)))
        } else {
            router
        };
        let router = Arc::new(router);
        let profile = router.profile.clone();
        // an explicit starting size outside the profile's bounds widens
        // the bounds to include it, so the tier never starts somewhere
        // the scale ops could not legally keep it (nor somewhere the
        // controller would immediately fight)
        let min_shards = profile.min_shards.max(1).min(n);
        let max_shards = profile.max_shards.max(min_shards).max(n);
        let expected_per_shard = cfg.expected_requests.div_ceil(n);
        let mut engines = Vec::with_capacity(n);
        let mut entries = Vec::with_capacity(n);
        for s in 0..n {
            let injection = cfg.injection.clone().map(|mut c| {
                c.seed = c.seed.wrapping_add(s as u64);
                c.count = c.count / n + usize::from(s < c.count % n);
                c
            });
            let server = Server::start_shard(s, router.clone(), policy,
                                             cfg.workers_per_shard.max(1),
                                             injection, expected_per_shard);
            entries.push(ShardEntry {
                slot: s,
                salt: salt_for(s, 0),
                handle: server.handle(),
            });
            engines.push(Engine { slot: s, server });
        }
        let shared = Arc::new(ClusterShared {
            plans: PlanCache::new(profile.clone()),
            router,
            policy,
            workers_per_shard: cfg.workers_per_shard.max(1),
            min_shards,
            max_shards,
            topology: RwLock::new(entries),
            engines: Mutex::new(engines),
            retired: Mutex::new(Vec::new()),
            next_generation: AtomicU64::new(1),
            retry_calls: AtomicU64::new(0),
            stats: ScaleStats::default(),
            stop: AtomicBool::new(false),
        });
        let controller = cfg
            .autoscale
            .take()
            .map(|mut scfg| {
                // the cluster's effective bounds may be wider than the
                // profile's (see above); the controller must enforce the
                // same ones or it would fight the starting topology
                scfg.min_shards = min_shards;
                scfg.max_shards = max_shards;
                scfg
            })
            .filter(ScalingConfig::elastic)
            .map(|scfg| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name("ftblas-autoscale".to_string())
                    .spawn(move || controller_loop(shared, scfg))
                    .expect("spawn autoscale controller")
            });
        Cluster { shared, controller }
    }

    /// A submission handle; cheap to clone, shares the topology.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: self.shared.clone() }
    }

    /// Live shard count.
    pub fn shard_count(&self) -> usize {
        self.shared.topology.read().unwrap().len()
    }

    /// Grow the tier by one shard (see [`ClusterHandle::scale_up`]).
    pub fn scale_up(&self) -> anyhow::Result<usize> {
        self.shared.scale_up()
    }

    /// Drain and retire one shard (see [`ClusterHandle::scale_down`]).
    pub fn scale_down(&self) -> anyhow::Result<usize> {
        self.shared.scale_down()
    }

    /// Per-shard snapshots of the **live** shards, in slot order (each
    /// shard's plan-cache counters are zero in cluster mode — planning
    /// happens in the cluster's shared cache). Retired shards'
    /// ledgers are folded into [`Cluster::metrics`], not listed here.
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        let topo = self.shared.topology.read().unwrap();
        topo.iter().map(|e| e.handle.metrics()).collect()
    }

    /// Final ledgers of shards retired by scale-downs, in drain order.
    pub fn retired_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shared.retired.lock().unwrap().clone()
    }

    /// Exact cluster-wide snapshot (see [`MetricsSnapshot::merge`]):
    /// live shards plus retired ledgers plus shared-cache and scale
    /// counters (consistent under concurrent scaling).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.merged_snapshot()
    }

    /// The cluster's live injection campaign, if one is running (see
    /// [`ClusterHandle::campaign`]).
    pub fn campaign(&self) -> Option<&InjectionCampaign> {
        self.shared.router.campaign()
    }

    /// Stop the autoscaler, stop accepting work, drain every live
    /// shard, and return the exact merged snapshot (including every
    /// retired shard's ledger).
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let snaps = self.halt();
        self.shared.finish_snapshot(&snaps)
    }

    /// The shared teardown: stop the controller, unroute everything,
    /// drain and join every live engine. Idempotent (a second call
    /// finds nothing to stop). Returns the engines' final ledgers.
    fn halt(&mut self) -> Vec<MetricsSnapshot> {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(c) = self.controller.take() {
            let _ = c.join();
        }
        let engines: Vec<Engine> = {
            let mut engines = self.shared.engines.lock().unwrap();
            self.shared.topology.write().unwrap().clear();
            engines.drain(..).collect()
        };
        engines.into_iter().map(|e| e.server.shutdown()).collect()
    }
}

/// Dropping the cluster without [`Cluster::shutdown`] must not leak
/// threads: the controller owns an `Arc<ClusterShared>` (which owns
/// every engine), so an un-stopped controller would keep all worker
/// pools alive for the life of the process. Drop mirrors `shutdown`
/// minus the returned snapshot — pending jobs still finish.
impl Drop for Cluster {
    fn drop(&mut self) {
        self.halt();
    }
}

/// The autoscaler loop: sample, decide, actuate. Decision rules live in
/// [`ScalingController`]; this loop only owns the clock and the
/// actuation calls (which are bounds-checked again inside
/// `scale_up`/`scale_down`, so a racing manual scale cannot push the
/// tier out of bounds).
fn controller_loop(shared: Arc<ClusterShared>, cfg: ScalingConfig) {
    let verbose = cfg.verbose;
    let interval = cfg.interval;
    let mut controller = ScalingController::new(cfg);
    // sleep in short slices so a shutdown never waits out a long
    // sampling interval just to join this thread
    let slice = std::time::Duration::from_millis(10).min(interval);
    while !shared.stop.load(Ordering::SeqCst) {
        let wake = std::time::Instant::now() + interval;
        loop {
            let now = std::time::Instant::now();
            if now >= wake || shared.stop.load(Ordering::SeqCst) {
                break;
            }
            std::thread::sleep(slice.min(wake - now));
        }
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let sample = shared.sample();
        match controller.observe(sample) {
            ScaleDecision::Grow => {
                if let Ok(n) = shared.scale_up() {
                    if verbose {
                        println!("autoscale: grew to {n} shards \
                                  (queue={}, shed={})",
                                 sample.queue_depth, sample.shed);
                    }
                }
            }
            ScaleDecision::Shrink => {
                if let Ok(n) = shared.scale_down() {
                    if verbose {
                        println!("autoscale: drained one shard, {n} remain");
                    }
                }
            }
            ScaleDecision::Hold => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;
    use crate::coordinator::request::Backend;
    use crate::util::rng::Rng;

    #[test]
    fn routing_is_deterministic_per_key() {
        for key in [0u64, 1, 42, 1 << 63, u64::MAX] {
            for shards in 1..=6 {
                let depths = vec![0; shards];
                assert_eq!(route(key, &depths), route(key, &depths));
                assert!(route(key, &depths) < shards);
            }
        }
    }

    /// Generation 0 reproduces the fixed-topology salts, so the legacy
    /// helpers and the salted ones agree there.
    #[test]
    fn generation_zero_salts_match_the_fixed_topology() {
        for shard in 0..8 {
            assert_eq!(salt_for(shard, 0), ROUTE_SALT ^ shard as u64);
        }
        let salts: Vec<u64> = (0..4).map(|s| salt_for(s, 0)).collect();
        let depths = [0usize; 4];
        for key in 0..512u64 {
            assert_eq!(route(key, &depths), route_salted(key, &salts, &depths));
        }
    }

    /// Regrowing a slot at a fresh generation changes its salt — the
    /// slot claims a different key slice instead of recalling the old
    /// one.
    #[test]
    fn fresh_generations_resalt_a_slot() {
        assert_ne!(salt_for(1, 0), salt_for(1, 1));
        assert_ne!(salt_for(1, 1), salt_for(1, 2));
        let base = [salt_for(0, 0)];
        let gen0: Vec<u64> = (0..512)
            .filter(|&k| route_salted(k, &[base[0], salt_for(1, 0)], &[0, 0])
                         == 1)
            .collect();
        let gen1: Vec<u64> = (0..512)
            .filter(|&k| route_salted(k, &[base[0], salt_for(1, 1)], &[0, 0])
                         == 1)
            .collect();
        assert_ne!(gen0, gen1, "a regrown slot must claim a fresh slice");
    }

    /// Equal rendezvous scores are where the live queue depths act: the
    /// tie falls to the shallower queue, then the lower shard index.
    #[test]
    fn score_ties_fall_to_the_shallower_queue() {
        // the 16-bit scores make ties reachable by scan (~2^16 keys)
        let key = (0u64..)
            .find(|&k| rendezvous_score(k, 0) == rendezvous_score(k, 1))
            .unwrap();
        assert_eq!(route(key, &[5, 0]), 1, "tie goes to the shallow shard");
        assert_eq!(route(key, &[0, 5]), 0);
        assert_eq!(route(key, &[3, 3]), 0, "equal depth falls to the index");
    }

    /// Routing keys follow the planned kernel id: native and peer plans
    /// for the same routine land on (potentially) different shards, and
    /// one kernel's traffic always shares one key.
    #[test]
    fn route_keys_follow_the_planned_kernel_id() {
        let cache = PlanCache::new(Profile::skylake_sim());
        let tuned = SelectionPolicy::for_backend(Backend::NativeTuned);
        let plan = cache
            .resolve("dgemm", 64, FtPolicy::None, &tuned)
            .unwrap();
        assert_eq!(route_key(&plan), plan.kernel_id.0 as u64);
        // a peer backend's plan keys by its own descriptor id
        let pjrt = SelectionPolicy::for_backend(Backend::Pjrt);
        let peer = cache
            .resolve("dgemm", 64, FtPolicy::None, &pjrt)
            .unwrap();
        assert_eq!(peer.kernel.name, "dgemm/pjrt");
        assert_ne!(route_key(&peer), route_key(&plan));
        // the same selection re-plans to the same key
        let again = cache
            .resolve("dgemm", 64, FtPolicy::None, &tuned)
            .unwrap();
        assert_eq!(route_key(&again), route_key(&plan));
    }

    /// A single-shard cluster behaves like the plain server: requests
    /// complete, and the merged snapshot carries the shared plan-cache
    /// counters (the shard-local caches are bypassed).
    #[test]
    fn single_shard_cluster_serves_and_counts_plans() {
        let router =
            Router::native_only(Profile::default(), Backend::NativeTuned);
        let cfg = ClusterConfig {
            shards: 1,
            workers_per_shard: 2,
            injection: None,
            expected_requests: 0,
            campaign: None,
            autoscale: None,
        };
        let cluster = Cluster::start(router, FtPolicy::None, cfg);
        let handle = cluster.handle();
        let mut rng = Rng::new(0xC0);
        for _ in 0..6 {
            let resp = handle
                .call(BlasRequest::Ddot {
                    x: rng.normal_vec(128),
                    y: rng.normal_vec(128),
                })
                .unwrap();
            assert_eq!(resp.kernel, "ddot/tuned");
        }
        let shard_snaps = cluster.shard_metrics();
        assert_eq!(shard_snaps.len(), 1);
        assert_eq!(shard_snaps[0].plan_cache_misses, 0,
                   "shard-local caches are bypassed in cluster mode");
        let m = cluster.shutdown();
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 0);
        assert_eq!(m.shed, 0);
        assert_eq!(m.scale_ups, 0);
        assert_eq!(m.scale_downs, 0);
        // one shape, planned once in the cluster's shared cache
        assert_eq!(m.plan_cache_misses, 1);
        assert_eq!(m.plan_cache_hits, 5);
    }

    /// Manual scaling respects the profile's shard bounds, in both
    /// directions.
    #[test]
    fn manual_scaling_respects_the_profile_bounds() {
        let profile = Profile::default().with_shard_bounds(1, 2);
        let router = Router::native_only(profile, Backend::NativeTuned);
        let cfg = ClusterConfig {
            shards: 1,
            workers_per_shard: 1,
            injection: None,
            expected_requests: 0,
            campaign: None,
            autoscale: None,
        };
        let cluster = Cluster::start(router, FtPolicy::None, cfg);
        assert_eq!(cluster.shard_count(), 1);
        assert!(cluster.scale_down().is_err(), "already at the floor");
        assert_eq!(cluster.scale_up().unwrap(), 2);
        assert!(cluster.scale_up().is_err(), "already at the ceiling");
        assert_eq!(cluster.scale_down().unwrap(), 1);
        let m = cluster.shutdown();
        assert_eq!(m.scale_ups, 1);
        assert_eq!(m.scale_downs, 1);
        assert!(m.keys_migrated > 0,
                "growing past one shard must migrate some kernel ids");
    }
}
