//! Sharded serving tier: a cluster of per-shard [`Server`] engines
//! behind one admission front-end.
//!
//! [`ClusterHandle::submit`] is the cluster's admission point. Each
//! request is planned once through the cluster's shared [`PlanCache`]
//! and routed to a shard by **rendezvous hashing on the planned kernel
//! id**, so one kernel's traffic always lands on one shard and the
//! shard-local kernel-keyed batching stays effective. Rendezvous scores
//! are deliberately coarse (16-bit): score ties are where the live
//! least-loaded tiebreak — fed by each shard's current queue depth —
//! gets to act, while routing stays deterministic per key at a fixed
//! shard count.
//!
//! Each shard is a full engine (worker pool, batcher, thread-budget
//! ledger, per-shard metrics) and enforces its own queue-depth
//! admission watermark, shedding excess submissions as typed
//! [`Error::Overloaded`] instead of queueing without bound. Per-shard
//! fault accounting stays independent while serving — the shape FT-GEMM
//! (arXiv:2305.02444) uses for per-stream ABFT state — and ledgers are
//! merged exactly at read time via [`MetricsSnapshot::merge`]: counters
//! sum, latency summaries are recomputed from every retained sample,
//! never from per-shard means.

use std::sync::Arc;

use anyhow::anyhow;

use crate::config::Profile;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::plan::{ExecutionPlan, PlanCache};
use crate::coordinator::request::{BlasRequest, BlasResponse};
use crate::coordinator::router::Router;
use crate::coordinator::server::{Admitted, Server, ServerHandle};
use crate::ft::injector::InjectorConfig;
use crate::ft::policy::FtPolicy;

pub use crate::coordinator::server::Error;

/// Cluster sizing. Routing and admission knobs (`shards` here is the
/// instance count; the per-shard `admission_depth` watermark and the
/// SLO table) live on [`Profile`], so one profile describes the whole
/// tier.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Shard (engine) count; clamped to at least 1.
    pub shards: usize,
    /// Native worker threads per shard.
    pub workers_per_shard: usize,
    /// Fault-injection config, split across shards (independent
    /// per-shard plans with derived seeds).
    pub injection: Option<InjectorConfig>,
    /// Expected request volume (sizes each shard's injection plan).
    pub expected_requests: usize,
}

impl ClusterConfig {
    pub fn from_profile(p: &Profile) -> ClusterConfig {
        ClusterConfig {
            shards: p.shards,
            workers_per_shard: p.workers,
            injection: None,
            expected_requests: 0,
        }
    }
}

/// Salt for the rendezvous hash (chosen so the registry's kernel-id key
/// space spreads across small shard counts; see the coverage proptest).
const ROUTE_SALT: u64 = 0xD1B5_4A32_D192_ED03;

/// SplitMix64 finalizer — the avalanche step behind the rendezvous
/// scores.
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// 16-bit rendezvous score of `(key, shard)`. Coarse on purpose: equal
/// scores are rare but reachable, and they are exactly where the live
/// least-loaded tiebreak acts.
pub fn rendezvous_score(key: u64, shard: usize) -> u64 {
    mix64(key ^ mix64(ROUTE_SALT ^ shard as u64)) >> 48
}

/// Pick the shard for a routing key: highest rendezvous score wins;
/// equal scores fall to the shallower live queue, then the lower shard
/// index. `depth_of` is only called on score ties (~2⁻¹⁶ of key pairs),
/// so the hot path never touches shard state — the cluster passes a
/// closure that locks a shard's scheduler only when the tiebreak
/// actually needs its queue depth. Deterministic for fixed depths, and
/// since depths only matter on ties, a key's shard is stable at a
/// fixed shard count in steady state.
pub fn route_with<F: FnMut(usize) -> usize>(key: u64, shards: usize,
                                            mut depth_of: F) -> usize {
    assert!(shards > 0, "route needs at least one shard");
    // pass 1: pure rendezvous argmax (lowest index on equal scores)
    let mut best = 0;
    let mut best_score = rendezvous_score(key, 0);
    let mut tied = false;
    for s in 1..shards {
        let score = rendezvous_score(key, s);
        if score > best_score {
            best = s;
            best_score = score;
            tied = false;
        } else if score == best_score {
            tied = true;
        }
    }
    if !tied {
        return best;
    }
    // pass 2 (rare): the tie falls to the shallowest queue; a strict
    // comparison keeps the lower index on equal depths
    let mut best_depth = depth_of(best);
    for s in (best + 1)..shards {
        if rendezvous_score(key, s) == best_score {
            let depth = depth_of(s);
            if depth < best_depth {
                best = s;
                best_depth = depth;
            }
        }
    }
    best
}

/// [`route_with`] over a pre-collected depth slice (tests, simulation).
pub fn route(key: u64, depths: &[usize]) -> usize {
    route_with(key, depths.len(), |s| depths[s])
}

/// Routing key of a request: planned jobs key by kernel id (one
/// kernel's batches stay on one shard); unplanned (PJRT) jobs fall back
/// to an FNV-1a hash of `(routine, dim)` — their batches group by shape
/// anyway — tagged in bit 63 so the two key spaces cannot collide.
pub fn route_key(plan: Option<&ExecutionPlan>, routine: &str, dim: usize)
                 -> u64 {
    match plan {
        Some(p) => p.kernel_id.0 as u64,
        None => {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for b in routine.bytes().chain(dim.to_le_bytes()) {
                h ^= b as u64;
                h = h.wrapping_mul(0x0100_0000_01b3);
            }
            h | (1 << 63)
        }
    }
}

struct ClusterShared {
    plans: PlanCache,
    router: Arc<Router>,
    policy: FtPolicy,
    handles: Vec<ServerHandle>,
}

/// Handle for submitting requests to the cluster; cheap to clone.
#[derive(Clone)]
pub struct ClusterHandle {
    shared: Arc<ClusterShared>,
}

impl ClusterHandle {
    /// The shared admission front half: plan once (shared cache), then
    /// route — depths are fetched lazily, only on rendezvous ties.
    fn plan_and_route(&self, req: &BlasRequest)
                      -> (Option<ExecutionPlan>, usize) {
        let policy = self.shared.policy;
        let backend = self.shared.router.resolve(req, policy);
        let plan = self
            .shared
            .plans
            .resolve(req.routine(), req.dim(), policy, backend);
        let key = route_key(plan.as_ref(), req.routine(), req.dim());
        let handles = &self.shared.handles;
        let shard =
            route_with(key, handles.len(), |s| handles[s].queue_depth());
        (plan, shard)
    }

    /// Admit a request: plan it once (shared cache), route it to its
    /// shard, enqueue it there. Returns the typed [`Error::Overloaded`]
    /// when the target shard's queue is at its admission watermark.
    pub fn submit(&self, req: BlasRequest) -> Admitted {
        let (plan, shard) = self.plan_and_route(&req);
        self.shared.handles[shard].submit_planned(req, plan)
    }

    /// The shard `submit` would route this request to right now.
    pub fn shard_for(&self, req: &BlasRequest) -> usize {
        self.plan_and_route(req).1
    }

    /// Submit and wait (sheds surface as errors).
    pub fn call(&self, req: BlasRequest) -> anyhow::Result<BlasResponse> {
        self.submit(req)
            .map_err(anyhow::Error::new)?
            .recv()
            .map_err(|_| anyhow!("cluster dropped the request"))?
    }

    /// Exact cluster-wide snapshot: per-shard ledgers merged plus the
    /// shared plan-cache counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        let snaps: Vec<MetricsSnapshot> =
            self.shared.handles.iter().map(|h| h.metrics()).collect();
        merge_with_plans(&snaps, &self.shared.plans)
    }
}

fn merge_with_plans(shards: &[MetricsSnapshot], plans: &PlanCache)
                    -> MetricsSnapshot {
    let mut merged = MetricsSnapshot::merge(shards);
    let (hits, misses) = plans.stats();
    merged.plan_cache_hits += hits;
    merged.plan_cache_misses += misses;
    merged
}

/// The cluster: `shards` independent [`Server`] engines over one shared
/// read-only router.
pub struct Cluster {
    shards: Vec<Server>,
    shared: Arc<ClusterShared>,
}

impl Cluster {
    /// Start `cfg.shards` engines sharing one router. Injection plans
    /// are split across shards (independent seeds, counts divided with
    /// the remainder on the low shards). Note the split assumes roughly
    /// balanced traffic: each shard plans its share over its own
    /// expected stream, so a shard that routing starves of requests
    /// fires fewer of its planned faults — cluster totals are an upper
    /// bound, not a guarantee (the ledger's `errors_injected` reports
    /// what actually fired).
    pub fn start(router: Router, policy: FtPolicy, cfg: ClusterConfig)
                 -> Cluster {
        let n = cfg.shards.max(1);
        let router = Arc::new(router);
        let profile = router.profile.clone();
        let expected_per_shard = cfg.expected_requests.div_ceil(n);
        let shards: Vec<Server> = (0..n)
            .map(|s| {
                let injection = cfg.injection.clone().map(|mut c| {
                    c.seed = c.seed.wrapping_add(s as u64);
                    c.count = c.count / n + usize::from(s < c.count % n);
                    c
                });
                Server::start_shard(s, router.clone(), policy,
                                    cfg.workers_per_shard.max(1), injection,
                                    expected_per_shard)
            })
            .collect();
        let handles = shards.iter().map(|s| s.handle()).collect();
        let shared = Arc::new(ClusterShared {
            plans: PlanCache::new(profile),
            router,
            policy,
            handles,
        });
        Cluster { shards, shared }
    }

    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle { shared: self.shared.clone() }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard snapshots, in shard order (each shard's plan-cache
    /// counters are zero in cluster mode — planning happens in the
    /// cluster's shared cache).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shards.iter().map(|s| s.metrics()).collect()
    }

    /// Exact cluster-wide snapshot (see [`MetricsSnapshot::merge`]).
    pub fn metrics(&self) -> MetricsSnapshot {
        merge_with_plans(&self.shard_metrics(), &self.shared.plans)
    }

    /// Stop accepting work, drain every shard, and return the exact
    /// merged snapshot.
    pub fn shutdown(self) -> MetricsSnapshot {
        let Cluster { shards, shared } = self;
        let snaps: Vec<MetricsSnapshot> =
            shards.into_iter().map(|s| s.shutdown()).collect();
        merge_with_plans(&snaps, &shared.plans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Profile;
    use crate::coordinator::request::Backend;
    use crate::util::rng::Rng;

    #[test]
    fn routing_is_deterministic_per_key() {
        for key in [0u64, 1, 42, 1 << 63, u64::MAX] {
            for shards in 1..=6 {
                let depths = vec![0; shards];
                assert_eq!(route(key, &depths), route(key, &depths));
                assert!(route(key, &depths) < shards);
            }
        }
    }

    /// Equal rendezvous scores are where the live queue depths act: the
    /// tie falls to the shallower queue, then the lower shard index.
    #[test]
    fn score_ties_fall_to_the_shallower_queue() {
        // the 16-bit scores make ties reachable by scan (~2^16 keys)
        let key = (0u64..)
            .find(|&k| rendezvous_score(k, 0) == rendezvous_score(k, 1))
            .unwrap();
        assert_eq!(route(key, &[5, 0]), 1, "tie goes to the shallow shard");
        assert_eq!(route(key, &[0, 5]), 0);
        assert_eq!(route(key, &[3, 3]), 0, "equal depth falls to the index");
    }

    /// Planned and unplanned key spaces cannot collide (bit-63 tag).
    #[test]
    fn route_keys_partition_planned_and_direct() {
        let cache = PlanCache::new(Profile::skylake_sim());
        let plan = cache
            .resolve("dgemm", 64, FtPolicy::None, Backend::NativeTuned)
            .unwrap();
        let planned = route_key(Some(&plan), "dgemm", 64);
        let direct = route_key(None, "dgemm", 64);
        assert_eq!(planned, plan.kernel_id.0 as u64);
        assert_ne!(planned, direct);
        assert_eq!(direct >> 63, 1);
        // direct keys separate by shape and routine
        assert_ne!(route_key(None, "dgemm", 64), route_key(None, "dgemm", 65));
        assert_ne!(route_key(None, "dgemm", 64), route_key(None, "dsymm", 64));
    }

    /// A single-shard cluster behaves like the plain server: requests
    /// complete, and the merged snapshot carries the shared plan-cache
    /// counters (the shard-local caches are bypassed).
    #[test]
    fn single_shard_cluster_serves_and_counts_plans() {
        let router =
            Router::native_only(Profile::default(), Backend::NativeTuned);
        let cfg = ClusterConfig {
            shards: 1,
            workers_per_shard: 2,
            injection: None,
            expected_requests: 0,
        };
        let cluster = Cluster::start(router, FtPolicy::None, cfg);
        let handle = cluster.handle();
        let mut rng = Rng::new(0xC0);
        for _ in 0..6 {
            let resp = handle
                .call(BlasRequest::Ddot {
                    x: rng.normal_vec(128),
                    y: rng.normal_vec(128),
                })
                .unwrap();
            assert_eq!(resp.kernel, "ddot/tuned");
        }
        let shard_snaps = cluster.shard_metrics();
        assert_eq!(shard_snaps.len(), 1);
        assert_eq!(shard_snaps[0].plan_cache_misses, 0,
                   "shard-local caches are bypassed in cluster mode");
        let m = cluster.shutdown();
        assert_eq!(m.completed, 6);
        assert_eq!(m.failed, 0);
        assert_eq!(m.shed, 0);
        // one shape, planned once in the cluster's shared cache
        assert_eq!(m.plan_cache_misses, 1);
        assert_eq!(m.plan_cache_hits, 5);
    }
}
