//! Dedicated PJRT executor thread.
//!
//! `runtime::Engine` is not `Send` (the xla crate's client is Rc-backed),
//! so one thread owns it and serves artifact calls over channels. The
//! handle is cheap to clone and `Send`, so native workers and the router
//! can all submit work.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::engine::{ArgView, Engine};

/// Owned argument crossing the channel to the executor thread.
#[derive(Clone, Debug)]
pub enum OwnedArg {
    /// A scalar operand.
    Scalar(f64),
    /// A rank-1 operand.
    Vec1(Vec<f64>),
    /// A row-major matrix operand with (rows, cols).
    Mat(Vec<f64>, usize, usize),
}

impl OwnedArg {
    fn view(&self) -> ArgView<'_> {
        match self {
            OwnedArg::Scalar(v) => ArgView::Scalar(*v),
            OwnedArg::Vec1(v) => ArgView::Vec1(v),
            OwnedArg::Mat(d, r, c) => ArgView::Mat(d, *r, *c),
        }
    }
}

enum Msg {
    Call {
        artifact: String,
        args: Vec<OwnedArg>,
        reply: Sender<Result<Vec<Vec<f64>>>>,
    },
    Warmup {
        artifact: String,
        reply: Sender<Result<()>>,
    },
    ListArtifacts {
        reply: Sender<Vec<String>>,
    },
    Stats {
        reply: Sender<(u64, u64)>,
    },
    Shutdown,
}

/// Cloneable, Send handle to the executor thread.
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Msg>,
}

// Sender<T> is Send+Sync for Send T; Msg is Send.
impl PjrtHandle {
    /// Execute an artifact; blocks until the result crosses back.
    pub fn call(&self, artifact: &str, args: Vec<OwnedArg>) -> Result<Vec<Vec<f64>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Call { artifact: artifact.to_string(), args, reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Pre-compile an artifact (moves compile cost off the request path).
    pub fn warmup(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warmup { artifact: artifact.to_string(), reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    /// Names of every loadable artifact.
    pub fn artifacts(&self) -> Result<Vec<String>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::ListArtifacts { reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))
    }

    /// (compiles, executions)
    pub fn stats(&self) -> Result<(u64, u64)> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Stats { reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))
    }

    /// Ask the executor thread to exit (idempotent).
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// The executor: spawn with the artifact directory; join on drop of the
/// last handle + shutdown.
pub struct PjrtExecutor {
    /// The channel-backed handle callers clone and use.
    pub handle: PjrtHandle,
    thread: Option<JoinHandle<()>>,
}

impl PjrtExecutor {
    /// Spawn the single executor thread over an artifact directory
    /// (fails if the PJRT engine cannot initialize there).
    pub fn spawn(artifact_dir: PathBuf) -> Result<PjrtExecutor> {
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || run_loop(artifact_dir, rx, ready_tx))
            .expect("spawn pjrt executor");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor died during startup"))??;
        Ok(PjrtExecutor { handle: PjrtHandle { tx }, thread: Some(thread) })
    }
}

impl Drop for PjrtExecutor {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn run_loop(dir: PathBuf, rx: Receiver<Msg>, ready: Sender<Result<()>>) {
    let mut engine = match Engine::new(&dir) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Call { artifact, args, reply } => {
                let views: Vec<ArgView> = args.iter().map(|a| a.view()).collect();
                let _ = reply.send(engine.call(&artifact, &views));
            }
            Msg::Warmup { artifact, reply } => {
                let _ = reply.send(engine.ensure_compiled(&artifact));
            }
            Msg::ListArtifacts { reply } => {
                let names = engine
                    .manifest()
                    .specs
                    .iter()
                    .map(|s| s.name.clone())
                    .collect();
                let _ = reply.send(names);
            }
            Msg::Stats { reply } => {
                let _ = reply.send((engine.compiles, engine.executions));
            }
            Msg::Shutdown => break,
        }
    }
}
