//! Typed BLAS requests and responses — the coordinator's wire format.

use crate::ft::FtReport;
use crate::util::matrix::Matrix;

/// Which backend executed (or should execute) a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Naive native loops (LAPACK-reference stand-in).
    NativeNaive,
    /// Blocked native kernels (OpenBLAS/BLIS stand-in).
    NativeBlocked,
    /// Tuned native kernels (FT-BLAS Ori native).
    NativeTuned,
    /// Runtime-probed AVX2+FMA microkernels (tuned-scalar fallback
    /// off-AVX2).
    NativeSimd,
    /// AOT Pallas/XLA artifact via PJRT.
    Pjrt,
    /// Simulated GPU executor: warp-tiled fused-ABFT GEMM tiers
    /// (arXiv 2305.01024's block/warp checksum hierarchy, emulated on
    /// the host so selection and soak can target a heterogeneous tier).
    GpuSim,
}

impl Backend {
    /// Every backend, in registry/report order.
    pub const ALL: [Backend; 6] = [
        Backend::NativeNaive,
        Backend::NativeBlocked,
        Backend::NativeTuned,
        Backend::NativeSimd,
        Backend::Pjrt,
        Backend::GpuSim,
    ];

    /// CLI/report name of the backend.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::NativeNaive => "naive",
            Backend::NativeBlocked => "blocked",
            Backend::NativeTuned => "tuned",
            Backend::NativeSimd => "simd",
            Backend::Pjrt => "pjrt",
            Backend::GpuSim => "gpu-sim",
        }
    }

    /// Parse a backend name (the CLI's `--backend`).
    pub fn by_name(s: &str) -> Option<Backend> {
        match s {
            "naive" => Some(Backend::NativeNaive),
            "blocked" => Some(Backend::NativeBlocked),
            "tuned" => Some(Backend::NativeTuned),
            "simd" => Some(Backend::NativeSimd),
            "pjrt" => Some(Backend::Pjrt),
            "gpu-sim" => Some(Backend::GpuSim),
            _ => None,
        }
    }

    /// Whether this backend is one of the four native variant families
    /// (the serial/MT kernels compiled into the binary). PJRT and the
    /// GPU simulator are peer backends with their own descriptors.
    pub fn is_native(&self) -> bool {
        !matches!(self, Backend::Pjrt | Backend::GpuSim)
    }

    /// The native backend a kernel variant reports as.
    pub const fn for_variant(v: crate::blas::Impl) -> Backend {
        match v {
            crate::blas::Impl::Naive => Backend::NativeNaive,
            crate::blas::Impl::Blocked => Backend::NativeBlocked,
            crate::blas::Impl::Tuned => Backend::NativeTuned,
            crate::blas::Impl::Simd => Backend::NativeSimd,
        }
    }

    /// The kernel variant a native backend requests (the non-native
    /// peer backends — PJRT, GPU-sim — have none).
    pub fn variant(&self) -> Option<crate::blas::Impl> {
        match self {
            Backend::NativeNaive => Some(crate::blas::Impl::Naive),
            Backend::NativeBlocked => Some(crate::blas::Impl::Blocked),
            Backend::NativeTuned => Some(crate::blas::Impl::Tuned),
            Backend::NativeSimd => Some(crate::blas::Impl::Simd),
            Backend::Pjrt | Backend::GpuSim => None,
        }
    }
}

/// A BLAS call. Matrices are dense row-major; triangular routines read
/// the lower triangle (the case the paper presents).
#[derive(Clone, Debug)]
pub enum BlasRequest {
    // ---- Level 1
    /// x ← αx.
    Dscal { alpha: f64, x: Vec<f64> },
    /// y ← αx + y.
    Daxpy { alpha: f64, x: Vec<f64>, y: Vec<f64> },
    /// xᵀy.
    Ddot { x: Vec<f64>, y: Vec<f64> },
    /// ‖x‖₂.
    Dnrm2 { x: Vec<f64> },
    /// Σ|xᵢ|.
    Dasum { x: Vec<f64> },
    /// Givens rotation of (x, y).
    Drot { x: Vec<f64>, y: Vec<f64>, c: f64, s: f64 },
    /// Modified Givens rotation (flagged parameter form).
    Drotm { x: Vec<f64>, y: Vec<f64>, param: [f64; 5] },
    /// Index of max |xᵢ|.
    Idamax { x: Vec<f64> },
    // ---- Level 2
    /// y ← αAx + βy.
    Dgemv { alpha: f64, a: Matrix, x: Vec<f64>, beta: f64, y: Vec<f64> },
    /// Solve Lx = b (lower triangular).
    Dtrsv { a: Matrix, b: Vec<f64> },
    /// A ← αxyᵀ + A.
    Dger { alpha: f64, x: Vec<f64>, y: Vec<f64>, a: Matrix },
    /// y ← αAx + βy, A symmetric.
    Dsymv { alpha: f64, a: Matrix, x: Vec<f64>, beta: f64, y: Vec<f64> },
    /// x ← Lx (lower triangular).
    Dtrmv { a: Matrix, x: Vec<f64> },
    // ---- Level 3
    /// C ← αAB + βC.
    Dgemm { alpha: f64, a: Matrix, b: Matrix, beta: f64, c: Matrix },
    /// C ← αAB + βC, A symmetric.
    Dsymm { alpha: f64, a: Matrix, b: Matrix, beta: f64, c: Matrix },
    /// B ← αLB (lower triangular).
    Dtrmm { alpha: f64, a: Matrix, b: Matrix },
    /// Solve LX = B (lower triangular).
    Dtrsm { a: Matrix, b: Matrix },
    /// C ← αAAᵀ + βC.
    Dsyrk { alpha: f64, a: Matrix, beta: f64, c: Matrix },
}

/// BLAS level of a request (selects the FT scheme under the hybrid
/// policy: DMR for 1/2, ABFT for 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Level {
    /// Vector-vector (memory-bound; DMR-protected).
    L1,
    /// Matrix-vector (memory-bound; DMR-protected).
    L2,
    /// Matrix-matrix (compute-bound; ABFT-protected).
    L3,
}

impl BlasRequest {
    /// Lowercase BLAS routine name.
    pub fn routine(&self) -> &'static str {
        match self {
            BlasRequest::Dscal { .. } => "dscal",
            BlasRequest::Daxpy { .. } => "daxpy",
            BlasRequest::Ddot { .. } => "ddot",
            BlasRequest::Dnrm2 { .. } => "dnrm2",
            BlasRequest::Dasum { .. } => "dasum",
            BlasRequest::Drot { .. } => "drot",
            BlasRequest::Drotm { .. } => "drotm",
            BlasRequest::Idamax { .. } => "idamax",
            BlasRequest::Dgemv { .. } => "dgemv",
            BlasRequest::Dtrsv { .. } => "dtrsv",
            BlasRequest::Dger { .. } => "dger",
            BlasRequest::Dsymv { .. } => "dsymv",
            BlasRequest::Dtrmv { .. } => "dtrmv",
            BlasRequest::Dgemm { .. } => "dgemm",
            BlasRequest::Dsymm { .. } => "dsymm",
            BlasRequest::Dtrmm { .. } => "dtrmm",
            BlasRequest::Dtrsm { .. } => "dtrsm",
            BlasRequest::Dsyrk { .. } => "dsyrk",
        }
    }

    /// BLAS level of the routine family.
    pub fn level(&self) -> Level {
        match self {
            BlasRequest::Dscal { .. }
            | BlasRequest::Daxpy { .. }
            | BlasRequest::Ddot { .. }
            | BlasRequest::Dnrm2 { .. }
            | BlasRequest::Dasum { .. }
            | BlasRequest::Drot { .. }
            | BlasRequest::Drotm { .. }
            | BlasRequest::Idamax { .. } => Level::L1,
            BlasRequest::Dgemv { .. }
            | BlasRequest::Dtrsv { .. }
            | BlasRequest::Dger { .. }
            | BlasRequest::Dsymv { .. }
            | BlasRequest::Dtrmv { .. } => Level::L2,
            _ => Level::L3,
        }
    }

    /// Principal problem size (vector length / matrix dimension) — the
    /// batching and artifact-matching key.
    pub fn dim(&self) -> usize {
        match self {
            BlasRequest::Dscal { x, .. }
            | BlasRequest::Dnrm2 { x }
            | BlasRequest::Dasum { x }
            | BlasRequest::Ddot { x, .. }
            | BlasRequest::Daxpy { x, .. }
            | BlasRequest::Drot { x, .. }
            | BlasRequest::Drotm { x, .. }
            | BlasRequest::Idamax { x } => x.len(),
            BlasRequest::Dger { a, .. } => a.rows,
            BlasRequest::Dgemv { a, .. }
            | BlasRequest::Dgemm { a, .. }
            | BlasRequest::Dsymm { a, .. }
            | BlasRequest::Dtrmm { a, .. }
            | BlasRequest::Dtrsm { a, .. }
            | BlasRequest::Dsyrk { a, .. }
            | BlasRequest::Dtrsv { a, .. }
            | BlasRequest::Dsymv { a, .. }
            | BlasRequest::Dtrmv { a, .. } => a.rows,
        }
    }

    /// Floating-point operation count (for GFLOPS reporting).
    pub fn flops(&self) -> f64 {
        let n = self.dim() as f64;
        match self {
            BlasRequest::Dscal { .. } => n,
            BlasRequest::Daxpy { .. } => 2.0 * n,
            BlasRequest::Ddot { .. } => 2.0 * n,
            BlasRequest::Dnrm2 { .. } => 2.0 * n,
            BlasRequest::Dasum { .. } => n,
            BlasRequest::Drot { .. } => 6.0 * n,
            BlasRequest::Drotm { .. } => 6.0 * n,
            BlasRequest::Idamax { .. } => n,
            BlasRequest::Dgemv { a, .. } => 2.0 * (a.rows * a.cols) as f64,
            BlasRequest::Dtrsv { .. } => n * n,
            BlasRequest::Dger { a, .. } => 2.0 * (a.rows * a.cols) as f64,
            BlasRequest::Dsymv { a, .. } => 2.0 * (a.rows * a.cols) as f64,
            BlasRequest::Dtrmv { .. } => n * n,
            BlasRequest::Dgemm { a, b, .. } => {
                2.0 * (a.rows * a.cols * b.cols) as f64
            }
            BlasRequest::Dsymm { a, b, .. } => {
                2.0 * (a.rows * a.cols * b.cols) as f64
            }
            BlasRequest::Dtrmm { a, b, .. } => (a.rows * a.cols * b.cols) as f64,
            BlasRequest::Dtrsm { a, b } => (a.rows * a.rows * b.cols) as f64,
            BlasRequest::Dsyrk { a, .. } => (a.rows * a.rows * a.cols) as f64,
        }
    }

    /// Shape-level batching key: same routine + same shape can share a
    /// batch window. The server batches *planned* jobs by resolved
    /// kernel id instead (strictly coarser: shapes with the same plan
    /// merge); this key remains the fallback for unplanned (PJRT) jobs,
    /// whose shape-specialized artifacts want exact-shape groups.
    pub fn batch_key(&self) -> (&'static str, usize) {
        (self.routine(), self.dim())
    }
}

/// Response payload: scalar or tensor result(s).
#[derive(Clone, Debug)]
pub enum BlasResult {
    /// A scalar result (dot, norms, amax index as f64).
    Scalar(f64),
    /// A vector result.
    Vector(Vec<f64>),
    /// A matrix result.
    Matrix(Matrix),
}

impl BlasResult {
    /// The scalar payload, if this is one.
    pub fn as_scalar(&self) -> Option<f64> {
        match self {
            BlasResult::Scalar(v) => Some(*v),
            _ => None,
        }
    }

    /// The vector payload, if this is one.
    pub fn as_vector(&self) -> Option<&[f64]> {
        match self {
            BlasResult::Vector(v) => Some(v),
            _ => None,
        }
    }

    /// The matrix payload, if this is one.
    pub fn as_matrix(&self) -> Option<&Matrix> {
        match self {
            BlasResult::Matrix(m) => Some(m),
            _ => None,
        }
    }
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct BlasResponse {
    /// The computed payload.
    pub result: BlasResult,
    /// Detection/correction counters from the protection scheme.
    pub ft: FtReport,
    /// Backend that executed the request.
    pub backend: Backend,
    /// Registry name of the kernel that executed the request
    /// (e.g. `"dgemm/abft-fused-mt"`; `"pjrt"` on the artifact path).
    pub kernel: &'static str,
    /// Kernel-only execution seconds (excludes queueing).
    pub exec_seconds: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn levels_and_routines() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(4, 4, &mut rng);
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: a.clone(),
            b: a.clone(),
            beta: 0.0,
            c: Matrix::zeros(4, 4),
        };
        assert_eq!(req.routine(), "dgemm");
        assert_eq!(req.level(), Level::L3);
        assert_eq!(req.dim(), 4);
        assert_eq!(req.flops(), 128.0);
        assert_eq!(req.batch_key(), ("dgemm", 4));

        let req = BlasRequest::Dscal { alpha: 2.0, x: vec![0.0; 10] };
        assert_eq!(req.level(), Level::L1);
        assert_eq!(req.flops(), 10.0);
    }

    #[test]
    fn backend_names() {
        for b in Backend::ALL {
            assert_eq!(Backend::by_name(b.name()), Some(b));
            assert_eq!(b.is_native(), b.variant().is_some());
        }
        for v in crate::blas::Impl::ALL {
            assert_eq!(Backend::for_variant(v).variant(), Some(v));
        }
    }
}
