//! Serving metrics: a per-kernel completion ledger.
//!
//! Every completion is recorded against the **executed kernel's registry
//! name** (from [`crate::coordinator::request::BlasResponse::kernel`]),
//! carrying kernel-exec, end-to-end, and queue-wait latencies plus FT
//! counters and the kernel's latency-SLO target (a completion whose
//! end-to-end latency exceeds the target counts one **burn**).
//! Scheduling counters — plan-cache hits/misses, thread-budget
//! deferrals, admission sheds, the configured budget and the queue /
//! in-flight high-watermarks — live beside them, so one snapshot answers
//! both "what ran" and "how the admission/scheduling pipeline behaved".
//! Runtime-substrate counters complete the picture: the compute pool's
//! occupancy/stealing ledger ([`crate::runtime::pool::PoolStats`],
//! stamped once by the cluster that owns the shared pool) and the
//! packing-arena totals of the server worker threads
//! ([`Metrics::record_arena`]).
//!
//! Snapshots retain the raw latency samples, which is what lets a
//! cluster merge its per-shard ledgers **exactly**:
//! [`MetricsSnapshot::merge`] sums counters and recomputes every summary
//! (per-kernel, per-routine, overall) from the concatenated samples —
//! never from per-shard means.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::runtime::pool::PoolStats;
use crate::util::arena;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Schema tag stamped on every serialized ledger snapshot. The gateway
/// serves this document verbatim on `GET /metrics`, so the identifier
/// is part of the wire contract (`docs/PROTOCOL.md`).
pub const LEDGER_SCHEMA: &str = "ftblas.ledger.v1";

/// JSON view of a latency [`Summary`] (seconds; a shared shape so the
/// ledger artifact's schema stays uniform across fields).
fn summary_json(s: &Summary) -> Json {
    Json::obj()
        .field("n", Json::Int(s.n as u64))
        .field("mean_s", Json::Num(s.mean))
        .field("min_s", Json::Num(s.min))
        .field("max_s", Json::Num(s.max))
        .field("p50_s", Json::Num(s.p50))
        .field("p99_s", Json::Num(s.p99))
}

/// Shared, thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Raw per-kernel ledger: retained samples + counters.
#[derive(Default)]
struct KernelLedger {
    routine: &'static str,
    completed: u64,
    errors_injected: u64,
    errors_detected: u64,
    errors_corrected: u64,
    /// Injected faults the scheme failed to detect (computed per
    /// completion as `injected − detected`, clamped at zero).
    errors_escaped: u64,
    /// SLO target (seconds, end-to-end; 0 = untracked, or mixed —
    /// completions recorded under differing targets).
    slo_target: f64,
    /// Completions whose end-to-end latency exceeded the target.
    slo_burns: u64,
    /// Largest number of items a single fused batch executed through
    /// this kernel (0 = never batch-fused).
    max_items_per_batch: u64,
    /// kernel-exec latencies (seconds)
    exec: Vec<f64>,
    /// end-to-end latencies (queue + exec, seconds)
    e2e: Vec<f64>,
    /// queue-wait latencies (admission → execution start, seconds)
    queue: Vec<f64>,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    shed: u64,
    errors_injected: u64,
    errors_detected: u64,
    errors_corrected: u64,
    errors_escaped: u64,
    deferrals: u64,
    starvation_reserves: u64,
    /// Drained batches the worker executed as ONE batched-kernel call
    /// instead of per-item plans.
    batches_fused: u64,
    /// Items those fused batches carried (so `items_fused /
    /// batches_fused` is the realized mean batch size).
    items_fused: u64,
    thread_budget: u64,
    max_in_flight_threads: u64,
    max_queue_depth: u64,
    /// Latest `(capacity, grows, leases)` of each recording thread's
    /// packing arena ([`crate::util::arena::thread_stats`]); keyed by
    /// thread id because the stats are cumulative per thread — each
    /// refresh overwrites, so a snapshot sums every thread exactly once.
    arenas: HashMap<std::thread::ThreadId, (usize, u64, u64)>,
    /// ledgers keyed by executed kernel registry name
    kernels: HashMap<&'static str, KernelLedger>,
}

/// Per-kernel summary in a snapshot. Carries both the computed
/// summaries and the raw samples they were computed from — the samples
/// are what make cross-shard merges exact.
#[derive(Clone, Debug, Default)]
pub struct KernelStats {
    /// Routine the kernel serves (rollup key for the per-routine views).
    pub routine: String,
    /// Completions recorded against this kernel.
    pub completed: u64,
    /// Faults the injector armed on requests this kernel executed.
    pub errors_injected: u64,
    /// Faults the kernel's protection scheme detected.
    pub errors_detected: u64,
    /// Detected faults the scheme corrected in place.
    pub errors_corrected: u64,
    /// Injected faults the scheme failed to detect — a nonzero value
    /// here means a silently wrong result left this kernel, which is
    /// exactly what the soak gate refuses to ship.
    pub errors_escaped: u64,
    /// End-to-end latency SLO target (seconds; 0 = untracked, or mixed
    /// — completions under differing targets share this ledger entry).
    pub slo_target: f64,
    /// Completions that missed the target.
    pub slo_burns: u64,
    /// High-watermark of items per fused batch executed through this
    /// kernel (0 = never batch-fused; merges take the max).
    pub max_items_per_batch: u64,
    /// Kernel-exec latency summary (seconds).
    pub exec: Summary,
    /// End-to-end latency summary (queue + exec, seconds).
    pub e2e: Summary,
    /// Queue-wait latency summary (admission → execution start).
    pub queue: Summary,
    /// Raw retained samples behind the summaries above.
    pub exec_samples: Vec<f64>,
    /// Raw end-to-end samples.
    pub e2e_samples: Vec<f64>,
    /// Raw queue-wait samples.
    pub queue_samples: Vec<f64>,
}

impl KernelStats {
    /// Recompute the summaries from the retained samples (after a merge
    /// extended them).
    fn resummarize(&mut self) {
        self.exec = Summary::from_samples(&self.exec_samples);
        self.e2e = Summary::from_samples(&self.e2e_samples);
        self.queue = Summary::from_samples(&self.queue_samples);
    }
}

/// A snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Requests that completed successfully.
    pub completed: u64,
    /// Requests whose execution returned an error.
    pub failed: u64,
    /// Submissions rejected at the admission watermark (`Overloaded`).
    pub shed: u64,
    /// Faults the injector armed across the run.
    pub errors_injected: u64,
    /// Faults detected by the protection schemes.
    pub errors_detected: u64,
    /// Detected faults corrected in place.
    pub errors_corrected: u64,
    /// Injected faults no scheme detected (summed per completion as
    /// `injected − detected`, clamped at zero). The soak gate requires
    /// this to be exactly zero.
    pub errors_escaped: u64,
    /// How faults were armed for this ledger's completions:
    /// `"campaign"` (a rate-based [`crate::ft::injector::InjectionCampaign`]),
    /// `"per-call"` (a planned [`crate::ft::injector::Injector`]), or
    /// `""` (no injection). Merges keep the first non-empty label.
    pub injection_mode: &'static str,
    /// CPU feature set the one-time SIMD probe detected on the host
    /// that produced this snapshot (e.g. `"x86_64+avx2+fma"`,
    /// `"scalar"`), so committed ledgers and bench rows are comparable
    /// across machines. Merges keep the first non-empty label.
    pub cpu_features: &'static str,
    /// Admission-time plan-cache counters (filled by the server, or by
    /// the cluster for its shared cache).
    pub plan_cache_hits: u64,
    /// Plan-cache misses (one per distinct shape × policy × backend).
    pub plan_cache_misses: u64,
    /// Times a drained batch bypassed an older group whose thread grant
    /// did not fit the remaining budget (counted per bypassed group on
    /// successful drains only, so idle re-polling does not inflate it).
    pub deferrals: u64,
    /// Times the scheduler's anti-starvation aging kicked in: a
    /// budget-deferred group at the FIFO head was bypassed
    /// `starvation_limit` times, so the shard reserved its thread
    /// budget for that group until it fit.
    pub starvation_reserves: u64,
    /// Drained batches the worker fused into ONE batched-kernel call
    /// (every item same planned kernel, every dim under the batched
    /// sibling's ceiling) instead of executing per-item plans.
    pub batches_fused: u64,
    /// Items carried by those fused batches; `items_fused /
    /// batches_fused` is the realized mean fused-batch size.
    pub items_fused: u64,
    /// Shards the elastic tier added (cluster-level; zero in per-shard
    /// snapshots, summed by merge).
    pub scale_ups: u64,
    /// Shards the elastic tier drained and retired (cluster-level).
    pub scale_downs: u64,
    /// Kernel-id routing keys whose owning shard changed across all
    /// scale events (the migration cost of elasticity; cluster-level).
    pub keys_migrated: u64,
    /// Configured thread budget (0 when no server is involved; summed
    /// across shards in a merged snapshot — total cluster capacity).
    pub thread_budget: u64,
    /// High-watermark of in-flight thread grants (max across shards in
    /// a merged snapshot — the watermarks are not simultaneous, so a
    /// sum would overstate).
    pub max_in_flight_threads: u64,
    /// High-watermark of the pending-queue depth (max across shards).
    pub max_queue_depth: u64,
    /// Total packing-arena capacity (`f64` elements) across the server
    /// worker threads that recorded into this ledger (summed by merge —
    /// shards own disjoint workers). Pool-worker arenas are reported
    /// separately under [`MetricsSnapshot::pool`].
    pub arena_capacity: u64,
    /// Total arena slab reallocations across those threads — flat in
    /// steady state, when the packing hot path allocates nothing.
    pub arena_grows: u64,
    /// Total arena leases served across those threads.
    pub arena_leases: u64,
    /// Compute-pool counters. Per-shard snapshots carry zeros — shards
    /// share ONE cluster pool, so the cluster stamps the pool's stats
    /// once on the merged view and cross-shard sums stay exact.
    pub pool: PoolStats,
    /// Per-kernel ledger, keyed by executed kernel registry name.
    pub kernels: HashMap<String, KernelStats>,
    /// Per-routine rollups (exact: aggregated from the retained
    /// per-kernel samples) for callers that don't care which kernel ran.
    pub exec_by_routine: HashMap<String, Summary>,
    /// Per-routine end-to-end rollups (exact, like `exec_by_routine`).
    pub e2e_by_routine: HashMap<String, Summary>,
    /// Exact all-kernel end-to-end summary (computed from every retained
    /// sample at snapshot time, not from per-group means).
    pub e2e_overall: Summary,
}

impl Metrics {
    /// An empty ledger.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completion against the kernel that executed it.
    /// `slo_target` is the kernel's end-to-end latency target in
    /// seconds (0 = untracked); a completion over target burns it.
    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(&self, kernel: &'static str,
                             routine: &'static str, exec_s: f64, e2e_s: f64,
                             queue_s: f64, detected: u64, corrected: u64,
                             injected: u64, slo_target: f64) {
        // an escape is judged per completion: a fault was armed for
        // this execution and the scheme reported fewer detections than
        // injections — the silent-corruption case the campaign gate
        // exists to catch
        let escaped = injected.saturating_sub(detected);
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.errors_detected += detected;
        m.errors_corrected += corrected;
        m.errors_injected += injected;
        m.errors_escaped += escaped;
        let k = m.kernels.entry(kernel).or_default();
        k.routine = routine;
        k.completed += 1;
        k.errors_detected += detected;
        k.errors_corrected += corrected;
        k.errors_injected += injected;
        k.errors_escaped += escaped;
        // burns are judged per completion against that completion's
        // target; the ledger's *displayed* target stays stable only
        // while every completion shares one target and degrades to 0
        // ("mixed/untracked") otherwise — e.g. the single "pjrt" ledger
        // entry spans BLAS levels with different level-derived targets
        if k.completed == 1 {
            k.slo_target = slo_target;
        } else if k.slo_target != slo_target {
            k.slo_target = 0.0;
        }
        if slo_target > 0.0 && e2e_s > slo_target {
            k.slo_burns += 1;
        }
        k.exec.push(exec_s);
        k.e2e.push(e2e_s);
        k.queue.push(queue_s);
    }

    /// Count a request whose execution returned an error.
    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Count a submission shed at the admission watermark.
    pub fn record_shed(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// Count groups a drained batch bypassed on budget grounds.
    pub fn record_deferrals(&self, n: u64) {
        if n > 0 {
            self.inner.lock().unwrap().deferrals += n;
        }
    }

    /// Count an anti-starvation reservation: the FIFO-head group
    /// crossed the bypass limit and the scheduler fenced the budget for
    /// it.
    pub fn record_starvation_reserve(&self) {
        self.inner.lock().unwrap().starvation_reserves += 1;
    }

    /// Record one fused batch: `items` jobs executed as a single call on
    /// the batched `kernel`. The per-item completions are recorded
    /// separately (by [`Metrics::record_completion`], under the same
    /// kernel name); this tracks how often fusion fired and how large
    /// the fused batches ran.
    pub fn record_batch_fusion(&self, kernel: &'static str, items: u64) {
        let mut m = self.inner.lock().unwrap();
        m.batches_fused += 1;
        m.items_fused += items;
        let k = m.kernels.entry(kernel).or_default();
        k.max_items_per_batch = k.max_items_per_batch.max(items);
    }

    /// Cheap cumulative counters for the autoscaler's sampling loop:
    /// `(completed, shed, slo_burns)` without cloning any latency
    /// samples (a full [`Metrics::snapshot`] clones every retained
    /// sample vector, which is too heavy to take every few
    /// milliseconds).
    pub fn pressure(&self) -> (u64, u64, u64) {
        let m = self.inner.lock().unwrap();
        let burns = m.kernels.values().map(|k| k.slo_burns).sum();
        (m.completed, m.shed, burns)
    }

    /// Record the ledger level after an admission (keeps the
    /// high-watermark the oversubscription test asserts on).
    pub fn record_in_flight(&self, in_flight_threads: u64) {
        let mut m = self.inner.lock().unwrap();
        m.max_in_flight_threads = m.max_in_flight_threads.max(in_flight_threads);
    }

    /// Record the pending-queue depth after an enqueue (keeps the
    /// high-watermark the admission-control test asserts on).
    pub fn record_queue_depth(&self, depth: u64) {
        let mut m = self.inner.lock().unwrap();
        m.max_queue_depth = m.max_queue_depth.max(depth);
    }

    /// Record the configured thread budget (reported, never derived).
    pub fn set_thread_budget(&self, budget: u64) {
        self.inner.lock().unwrap().thread_budget = budget;
    }

    /// Refresh the calling thread's packing-arena statistics
    /// ([`crate::util::arena::thread_stats`]) into the ledger. The stats
    /// are cumulative per thread and keyed by thread id, so workers can
    /// call this after every drained batch and a snapshot still counts
    /// each thread exactly once (latest value wins).
    pub fn record_arena(&self) {
        let stats = arena::thread_stats();
        let mut m = self.inner.lock().unwrap();
        m.arenas.insert(std::thread::current().id(), stats);
    }

    /// A point-in-time copy of the ledger, with all summaries computed
    /// from the retained samples.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut snap = MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            shed: m.shed,
            errors_injected: m.errors_injected,
            errors_detected: m.errors_detected,
            errors_corrected: m.errors_corrected,
            errors_escaped: m.errors_escaped,
            cpu_features: crate::blas::simd::CpuFeatures::summary(),
            plan_cache_hits: 0,
            plan_cache_misses: 0,
            deferrals: m.deferrals,
            starvation_reserves: m.starvation_reserves,
            batches_fused: m.batches_fused,
            items_fused: m.items_fused,
            thread_budget: m.thread_budget,
            max_in_flight_threads: m.max_in_flight_threads,
            max_queue_depth: m.max_queue_depth,
            ..Default::default()
        };
        for &(capacity, grows, leases) in m.arenas.values() {
            snap.arena_capacity += capacity as u64;
            snap.arena_grows += grows;
            snap.arena_leases += leases;
        }
        for (name, k) in &m.kernels {
            snap.kernels.insert(name.to_string(), KernelStats {
                routine: k.routine.to_string(),
                completed: k.completed,
                errors_injected: k.errors_injected,
                errors_detected: k.errors_detected,
                errors_corrected: k.errors_corrected,
                errors_escaped: k.errors_escaped,
                slo_target: k.slo_target,
                slo_burns: k.slo_burns,
                max_items_per_batch: k.max_items_per_batch,
                exec: Summary::from_samples(&k.exec),
                e2e: Summary::from_samples(&k.e2e),
                queue: Summary::from_samples(&k.queue),
                exec_samples: k.exec.clone(),
                e2e_samples: k.e2e.clone(),
                queue_samples: k.queue.clone(),
            });
        }
        snap.recompute_rollups();
        snap
    }
}

impl MetricsSnapshot {
    /// All-kernel end-to-end latency summary — exact (computed from
    /// every retained sample, not from per-group means).
    pub fn overall_e2e(&self) -> Summary {
        self.e2e_overall.clone()
    }

    /// Total SLO burns across the per-kernel ledger.
    pub fn slo_burns(&self) -> u64 {
        self.kernels.values().map(|k| k.slo_burns).sum()
    }

    /// Rebuild the per-routine and overall views from the per-kernel
    /// retained samples.
    fn recompute_rollups(&mut self) {
        let mut exec_by_routine: HashMap<String, Vec<f64>> = HashMap::new();
        let mut e2e_by_routine: HashMap<String, Vec<f64>> = HashMap::new();
        let mut e2e_all = Vec::new();
        for k in self.kernels.values() {
            exec_by_routine
                .entry(k.routine.clone())
                .or_default()
                .extend_from_slice(&k.exec_samples);
            e2e_by_routine
                .entry(k.routine.clone())
                .or_default()
                .extend_from_slice(&k.e2e_samples);
            e2e_all.extend_from_slice(&k.e2e_samples);
        }
        self.exec_by_routine = exec_by_routine
            .into_iter()
            .map(|(k, v)| (k, Summary::from_samples(&v)))
            .collect();
        self.e2e_by_routine = e2e_by_routine
            .into_iter()
            .map(|(k, v)| (k, Summary::from_samples(&v)))
            .collect();
        self.e2e_overall = Summary::from_samples(&e2e_all);
    }

    /// Serialize the ledger as a stable JSON document
    /// (`ftblas.ledger.v1`): counters, error outcomes, scheduling and
    /// scaling state, the overall end-to-end summary, and the
    /// per-kernel ledgers sorted by kernel name. This is the
    /// machine-readable artifact CI uploads per run, so the schema is
    /// append-only: new fields may be added, existing keys never change
    /// meaning.
    pub fn to_json(&self) -> Json {
        let mut kernels: Vec<(&String, &KernelStats)> =
            self.kernels.iter().collect();
        kernels.sort_by(|a, b| a.0.cmp(b.0));
        let kernel_rows = kernels
            .into_iter()
            .map(|(name, k)| {
                Json::obj()
                    .field("kernel", Json::Str(name.clone()))
                    .field("routine", Json::Str(k.routine.clone()))
                    .field("completed", Json::Int(k.completed))
                    .field("errors", Json::obj()
                        .field("injected", Json::Int(k.errors_injected))
                        .field("detected", Json::Int(k.errors_detected))
                        .field("corrected", Json::Int(k.errors_corrected))
                        .field("escaped", Json::Int(k.errors_escaped)))
                    .field("slo", Json::obj()
                        .field("target_s", Json::Num(k.slo_target))
                        .field("burns", Json::Int(k.slo_burns)))
                    .field("max_items_per_batch",
                           Json::Int(k.max_items_per_batch))
                    .field("exec", summary_json(&k.exec))
                    .field("e2e", summary_json(&k.e2e))
                    .field("queue", summary_json(&k.queue))
            })
            .collect();
        Json::obj()
            .field("schema", Json::Str(LEDGER_SCHEMA.into()))
            .field("completed", Json::Int(self.completed))
            .field("failed", Json::Int(self.failed))
            .field("shed", Json::Int(self.shed))
            .field("injection_mode", Json::Str(self.injection_mode.into()))
            .field("cpu_features", Json::Str(self.cpu_features.into()))
            .field("errors", Json::obj()
                .field("injected", Json::Int(self.errors_injected))
                .field("detected", Json::Int(self.errors_detected))
                .field("corrected", Json::Int(self.errors_corrected))
                .field("escaped", Json::Int(self.errors_escaped)))
            .field("plan_cache", Json::obj()
                .field("hits", Json::Int(self.plan_cache_hits))
                .field("misses", Json::Int(self.plan_cache_misses)))
            .field("scheduling", Json::obj()
                .field("deferrals", Json::Int(self.deferrals))
                .field("starvation_reserves",
                       Json::Int(self.starvation_reserves))
                .field("batches_fused", Json::Int(self.batches_fused))
                .field("items_fused", Json::Int(self.items_fused))
                .field("thread_budget", Json::Int(self.thread_budget))
                .field("max_in_flight_threads",
                       Json::Int(self.max_in_flight_threads))
                .field("max_queue_depth", Json::Int(self.max_queue_depth)))
            .field("scaling", Json::obj()
                .field("ups", Json::Int(self.scale_ups))
                .field("downs", Json::Int(self.scale_downs))
                .field("keys_migrated", Json::Int(self.keys_migrated)))
            .field("arena", Json::obj()
                .field("capacity_f64", Json::Int(self.arena_capacity))
                .field("grows", Json::Int(self.arena_grows))
                .field("leases", Json::Int(self.arena_leases)))
            .field("pool", self.pool_json())
            .field("slo_burns", Json::Int(self.slo_burns()))
            .field("e2e_overall", summary_json(&self.e2e_overall))
            .field("kernels", Json::Arr(kernel_rows))
    }

    /// JSON view of the compute-pool counters: occupancy and stealing
    /// totals, the pool workers' arena triple, and the per-kernel-frame
    /// queue-to-start wait summaries (sorted by frame label).
    fn pool_json(&self) -> Json {
        let p = &self.pool;
        let waits = p
            .queue_summaries()
            .into_iter()
            .map(|(label, s)| {
                Json::obj()
                    .field("frame", Json::Str(label.into()))
                    .field("wait", summary_json(&s))
            })
            .collect();
        Json::obj()
            .field("workers", Json::Int(p.workers))
            .field("tasks_submitted", Json::Int(p.tasks_submitted))
            .field("tasks_executed", Json::Int(p.tasks_executed))
            .field("steals", Json::Int(p.steals))
            .field("park_wakeups", Json::Int(p.park_wakeups))
            .field("arena", Json::obj()
                .field("capacity_f64", Json::Int(p.arena_capacity))
                .field("grows", Json::Int(p.arena_grows))
                .field("leases", Json::Int(p.arena_leases)))
            .field("queue_waits", Json::Arr(waits))
    }

    /// Aggregate per-shard snapshots **exactly**: counters sum, kernel
    /// ledgers concatenate their retained samples, and every latency
    /// summary (per-kernel, per-routine, overall) is recomputed from
    /// the merged samples — a merged mean/percentile is what a single
    /// ledger over all completions would have reported, never a
    /// mean-of-means. Capacity fields follow their semantics: thread
    /// budgets sum (total cluster capacity) while the in-flight and
    /// queue-depth watermarks take the max (per-shard peaks are not
    /// simultaneous).
    pub fn merge(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
        let mut out = MetricsSnapshot::default();
        for p in parts {
            out.completed += p.completed;
            out.failed += p.failed;
            out.shed += p.shed;
            out.errors_injected += p.errors_injected;
            out.errors_detected += p.errors_detected;
            out.errors_corrected += p.errors_corrected;
            out.errors_escaped += p.errors_escaped;
            if out.injection_mode.is_empty() {
                out.injection_mode = p.injection_mode;
            }
            if out.cpu_features.is_empty() {
                out.cpu_features = p.cpu_features;
            }
            out.plan_cache_hits += p.plan_cache_hits;
            out.plan_cache_misses += p.plan_cache_misses;
            out.deferrals += p.deferrals;
            out.starvation_reserves += p.starvation_reserves;
            out.batches_fused += p.batches_fused;
            out.items_fused += p.items_fused;
            out.scale_ups += p.scale_ups;
            out.scale_downs += p.scale_downs;
            out.keys_migrated += p.keys_migrated;
            out.thread_budget += p.thread_budget;
            out.max_in_flight_threads =
                out.max_in_flight_threads.max(p.max_in_flight_threads);
            out.max_queue_depth = out.max_queue_depth.max(p.max_queue_depth);
            out.arena_capacity += p.arena_capacity;
            out.arena_grows += p.arena_grows;
            out.arena_leases += p.arena_leases;
            out.pool.absorb(&p.pool);
            for (name, k) in &p.kernels {
                let dst = out.kernels.entry(name.clone()).or_default();
                let first_part = dst.completed == 0;
                dst.routine = k.routine.clone();
                dst.completed += k.completed;
                dst.errors_injected += k.errors_injected;
                dst.errors_detected += k.errors_detected;
                dst.errors_corrected += k.errors_corrected;
                dst.errors_escaped += k.errors_escaped;
                // same mixed-target rule as recording: shards that
                // disagree on a kernel's target merge to 0 (untracked)
                if first_part {
                    dst.slo_target = k.slo_target;
                } else if dst.slo_target != k.slo_target {
                    dst.slo_target = 0.0;
                }
                dst.slo_burns += k.slo_burns;
                dst.max_items_per_batch =
                    dst.max_items_per_batch.max(k.max_items_per_batch);
                dst.exec_samples.extend_from_slice(&k.exec_samples);
                dst.e2e_samples.extend_from_slice(&k.e2e_samples);
                dst.queue_samples.extend_from_slice(&k.queue_samples);
            }
        }
        for k in out.kernels.values_mut() {
            k.resummarize();
        }
        out.recompute_rollups();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_kernel() {
        let m = Metrics::new();
        m.record_completion("dgemm/abft-fused", "dgemm", 0.1, 0.2, 0.05, 1, 1,
                            1, 0.0);
        m.record_completion("dgemm/tuned", "dgemm", 0.3, 0.4, 0.0, 0, 0, 0,
                            0.0);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.errors_detected, 1);
        assert_eq!(s.errors_corrected, 1);
        // per-kernel ledger entries
        let k = &s.kernels["dgemm/abft-fused"];
        assert_eq!(k.routine, "dgemm");
        assert_eq!(k.completed, 1);
        assert_eq!(k.errors_detected, 1);
        assert!((k.queue.mean - 0.05).abs() < 1e-12);
        // routine rollup merges both kernels
        let g = &s.exec_by_routine["dgemm"];
        assert_eq!(g.n, 2);
        assert!((g.mean - 0.2).abs() < 1e-12);
    }

    #[test]
    fn overall_e2e_is_an_exact_weighted_rollup() {
        let m = Metrics::new();
        // 3 fast dscal completions vs 1 slow dgemm: a mean-of-means
        // would report (0.1 + 0.9) / 2 = 0.5; the exact mean is 0.3.
        for _ in 0..3 {
            m.record_completion("dscal/tuned", "dscal", 0.1, 0.1, 0.0, 0, 0,
                                0, 0.0);
        }
        m.record_completion("dgemm/tuned", "dgemm", 0.9, 0.9, 0.0, 0, 0, 0,
                            0.0);
        let s = m.snapshot().overall_e2e();
        assert_eq!(s.n, 4);
        assert!((s.mean - 0.3).abs() < 1e-12, "mean {} not exact", s.mean);
        assert_eq!(s.max, 0.9);
        assert_eq!(s.min, 0.1);
    }

    #[test]
    fn scheduling_counters_track_budget_pressure() {
        let m = Metrics::new();
        m.set_thread_budget(8);
        m.record_in_flight(5);
        m.record_in_flight(3);
        m.record_deferrals(2);
        m.record_deferrals(0);
        m.record_queue_depth(4);
        m.record_queue_depth(2);
        m.record_shed();
        m.record_starvation_reserve();
        let s = m.snapshot();
        assert_eq!(s.thread_budget, 8);
        assert_eq!(s.max_in_flight_threads, 5);
        assert_eq!(s.deferrals, 2);
        assert_eq!(s.max_queue_depth, 4);
        assert_eq!(s.shed, 1);
        assert_eq!(s.starvation_reserves, 1);
    }

    #[test]
    fn pressure_matches_the_snapshot_counters() {
        let m = Metrics::new();
        m.record_completion("ddot/dmr", "ddot", 0.3, 0.3, 0.0, 0, 0, 0, 0.2);
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 0, 0, 0, 0.2);
        m.record_shed();
        m.record_shed();
        let (completed, shed, burns) = m.pressure();
        let s = m.snapshot();
        assert_eq!(completed, s.completed);
        assert_eq!(shed, s.shed);
        assert_eq!(burns, s.slo_burns());
        assert_eq!((completed, shed, burns), (2, 2, 1));
    }

    /// Escapes are judged per completion (`injected − detected`,
    /// clamped), accumulate per kernel and overall, and merge by sum;
    /// the injection-mode label survives a merge with unlabeled parts.
    #[test]
    fn escapes_accumulate_and_merge() {
        let m = Metrics::new();
        // detected: no escape
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 1, 1, 1, 0.0);
        // injected but undetected: one escape
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 0, 0, 1, 0.0);
        // spurious extra detection never counts negative
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 2, 2, 1, 0.0);
        let mut a = m.snapshot();
        assert_eq!(a.errors_escaped, 1);
        assert_eq!(a.kernels["ddot/dmr"].errors_escaped, 1);
        a.injection_mode = "campaign";
        let b = Metrics::new().snapshot();
        let merged = MetricsSnapshot::merge(&[b, a]);
        assert_eq!(merged.errors_escaped, 1);
        assert_eq!(merged.kernels["ddot/dmr"].errors_escaped, 1);
        assert_eq!(merged.injection_mode, "campaign",
                   "the label survives unlabeled parts");
    }

    /// The JSON artifact is stable: fixed schema tag, exact integer
    /// counters, kernels sorted by name.
    #[test]
    fn ledger_json_is_stable_and_sorted() {
        let m = Metrics::new();
        m.record_completion("dscal/tuned", "dscal", 0.2, 0.2, 0.0, 0, 0, 0,
                            0.0);
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 1, 1, 1, 0.0);
        let mut snap = m.snapshot();
        snap.injection_mode = "per-call";
        let text = snap.to_json().render();
        assert!(text.starts_with(r#"{"schema":"ftblas.ledger.v1""#));
        assert!(text.contains(r#""injection_mode":"per-call""#));
        assert!(text.contains(r#""injected":1"#));
        let ddot = text.find(r#""kernel":"ddot/dmr""#).unwrap();
        let dscal = text.find(r#""kernel":"dscal/tuned""#).unwrap();
        assert!(ddot < dscal, "kernels must serialize sorted by name");
        // rendering is deterministic
        assert_eq!(text, snap.to_json().render());
    }

    /// The cluster-level scale counters ride through merges by
    /// summation (per-shard snapshots carry zeros; the cluster fills
    /// them on the merged view).
    #[test]
    fn scale_counters_merge_by_sum() {
        let mut a = Metrics::new().snapshot();
        a.scale_ups = 2;
        a.scale_downs = 1;
        a.keys_migrated = 40;
        a.starvation_reserves = 3;
        let b = Metrics::new().snapshot();
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.scale_ups, 2);
        assert_eq!(merged.scale_downs, 1);
        assert_eq!(merged.keys_migrated, 40);
        assert_eq!(merged.starvation_reserves, 3);
    }

    /// Batch-fusion counters: totals sum, the per-kernel items-per-batch
    /// high-watermark rides snapshots and merges by max, and the JSON
    /// artifact carries all three (append-only schema).
    #[test]
    fn batch_fusion_counters_accumulate_and_merge() {
        let m = Metrics::new();
        m.record_batch_fusion("dgemm/batched-simd", 6);
        m.record_batch_fusion("dgemm/batched-simd", 3);
        for _ in 0..9 {
            m.record_completion("dgemm/batched-simd", "dgemm", 0.1, 0.1, 0.0,
                                0, 0, 0, 0.0);
        }
        let a = m.snapshot();
        assert_eq!(a.batches_fused, 2);
        assert_eq!(a.items_fused, 9);
        assert_eq!(a.kernels["dgemm/batched-simd"].max_items_per_batch, 6);
        let n = Metrics::new();
        n.record_batch_fusion("dgemm/batched-simd", 8);
        n.record_completion("dgemm/batched-simd", "dgemm", 0.1, 0.1, 0.0, 0,
                            0, 0, 0.0);
        let merged = MetricsSnapshot::merge(&[a, n.snapshot()]);
        assert_eq!(merged.batches_fused, 3, "fusion totals sum");
        assert_eq!(merged.items_fused, 17);
        assert_eq!(merged.kernels["dgemm/batched-simd"].max_items_per_batch,
                   8, "the high-watermark merges by max, not sum");
        let text = merged.to_json().render();
        assert!(text.contains(r#""batches_fused":3"#));
        assert!(text.contains(r#""items_fused":17"#));
        assert!(text.contains(r#""max_items_per_batch":8"#));
    }

    #[test]
    fn slo_burns_count_completions_over_target() {
        let m = Metrics::new();
        // target 0.2s: one on-target, two over, one untracked (0 target)
        m.record_completion("ddot/dmr", "ddot", 0.1, 0.1, 0.0, 0, 0, 0, 0.2);
        m.record_completion("ddot/dmr", "ddot", 0.3, 0.3, 0.0, 0, 0, 0, 0.2);
        m.record_completion("ddot/dmr", "ddot", 0.5, 0.5, 0.2, 0, 0, 0, 0.2);
        m.record_completion("dgemm/tuned", "dgemm", 9.0, 9.0, 0.0, 0, 0, 0,
                            0.0);
        let s = m.snapshot();
        let k = &s.kernels["ddot/dmr"];
        assert_eq!(k.slo_target, 0.2);
        assert_eq!(k.slo_burns, 2);
        assert_eq!(s.kernels["dgemm/tuned"].slo_burns, 0);
        assert_eq!(s.slo_burns(), 2);
        // one ledger entry recorded under differing targets (the PJRT
        // path spans BLAS levels): burns stay per-completion-correct,
        // the displayed target degrades to 0 rather than lying
        m.record_completion("pjrt", "dscal", 0.1, 0.1, 0.0, 0, 0, 0, 0.05);
        m.record_completion("pjrt", "dgemm", 0.1, 0.1, 0.0, 0, 0, 0, 0.5);
        let s = m.snapshot();
        assert_eq!(s.kernels["pjrt"].slo_target, 0.0, "mixed targets");
        assert_eq!(s.kernels["pjrt"].slo_burns, 1, "0.1 burns only 0.05");
    }

    /// Server-worker arena stats: recorded per thread (latest wins, so
    /// repeated refreshes never double-count), summed into the
    /// snapshot, summed again across shards by merge, and emitted in
    /// the ledger JSON.
    #[test]
    fn arena_stats_record_sum_and_merge() {
        // a dedicated thread so the arena counters start from zero
        let a = std::thread::spawn(|| {
            let m = Metrics::new();
            crate::util::arena::with([32, 16], |_| ());
            m.record_arena();
            crate::util::arena::with([8], |_| ());
            m.record_arena(); // refresh: overwrites, never double-counts
            m.snapshot()
        })
        .join()
        .unwrap();
        assert_eq!(a.arena_capacity, 48);
        assert_eq!(a.arena_grows, 1);
        assert_eq!(a.arena_leases, 2);
        let mut b = Metrics::new().snapshot();
        b.arena_capacity = 100;
        b.arena_grows = 2;
        b.arena_leases = 7;
        let merged = MetricsSnapshot::merge(&[a, b]);
        assert_eq!(merged.arena_capacity, 148, "shard arenas sum");
        assert_eq!(merged.arena_grows, 3);
        assert_eq!(merged.arena_leases, 9);
        let text = merged.to_json().render();
        assert!(text.contains(
            r#""arena":{"capacity_f64":148,"grows":3,"leases":9}"#));
    }

    /// Compute-pool counters ride the merge via [`PoolStats::absorb`]
    /// (per-shard snapshots carry zeros; the cluster stamps the shared
    /// pool once) and serialize with sorted per-frame wait summaries.
    #[test]
    fn pool_counters_merge_and_serialize() {
        let mut a = Metrics::new().snapshot();
        a.pool.workers = 4;
        a.pool.tasks_submitted = 12;
        a.pool.tasks_executed = 12;
        a.pool.steals = 3;
        a.pool.park_wakeups = 5;
        a.pool.arena_leases = 12;
        a.pool.queue_waits.insert("dgemm/mt", vec![1e-6, 3e-6]);
        a.pool.queue_waits.insert("dgemm/batched", vec![2e-6]);
        let b = Metrics::new().snapshot();
        let merged = MetricsSnapshot::merge(&[b, a]);
        assert_eq!(merged.pool.workers, 4);
        assert_eq!(merged.pool.tasks_submitted, 12);
        assert_eq!(merged.pool.tasks_executed, 12,
                   "no-leak invariant survives the merge");
        assert_eq!(merged.pool.park_wakeups, 5);
        let text = merged.to_json().render();
        assert!(text.contains(r#""pool":{"workers":4"#));
        assert!(text.contains(r#""tasks_submitted":12"#));
        assert!(text.contains(r#""steals":3"#));
        assert!(text.contains(r#""park_wakeups":5"#));
        // frames serialize sorted: dgemm/batched before dgemm/mt
        let batched = text.find(r#""frame":"dgemm/batched""#).unwrap();
        let mt = text.find(r#""frame":"dgemm/mt""#).unwrap();
        assert!(batched < mt, "queue_waits must be sorted by frame");
    }

    /// The cluster-merge invariant: merging two shard snapshots is
    /// indistinguishable from one ledger having recorded everything.
    #[test]
    fn merge_is_exact_not_mean_of_means() {
        let shard0 = Metrics::new();
        for _ in 0..3 {
            shard0.record_completion("dscal/tuned", "dscal", 0.1, 0.1, 0.0,
                                     0, 0, 0, 0.05);
        }
        shard0.record_shed();
        shard0.set_thread_budget(4);
        let shard1 = Metrics::new();
        shard1.record_completion("dgemm/tuned", "dgemm", 0.9, 0.9, 0.0, 1, 1,
                                 1, 0.05);
        shard1.record_completion("dscal/tuned", "dscal", 0.2, 0.2, 0.0, 0, 0,
                                 0, 0.05);
        shard1.set_thread_budget(4);
        let one = Metrics::new();
        for _ in 0..3 {
            one.record_completion("dscal/tuned", "dscal", 0.1, 0.1, 0.0, 0, 0,
                                  0, 0.05);
        }
        one.record_completion("dgemm/tuned", "dgemm", 0.9, 0.9, 0.0, 1, 1, 1,
                              0.05);
        one.record_completion("dscal/tuned", "dscal", 0.2, 0.2, 0.0, 0, 0, 0,
                              0.05);
        let merged =
            MetricsSnapshot::merge(&[shard0.snapshot(), shard1.snapshot()]);
        let want = one.snapshot();
        assert_eq!(merged.completed, want.completed);
        assert_eq!(merged.shed, 1);
        assert_eq!(merged.errors_detected, want.errors_detected);
        assert_eq!(merged.thread_budget, 8, "budgets sum to cluster capacity");
        // per-kernel ledgers merged sample-exactly
        for name in ["dscal/tuned", "dgemm/tuned"] {
            let (a, b) = (&merged.kernels[name], &want.kernels[name]);
            assert_eq!(a.completed, b.completed, "{name}");
            assert_eq!(a.slo_burns, b.slo_burns, "{name}");
            assert!((a.e2e.mean - b.e2e.mean).abs() < 1e-12, "{name}");
            assert_eq!(a.e2e.n, b.e2e.n, "{name}");
        }
        // the overall summary is sample-exact (0.28), not the
        // mean-of-shard-means ((0.1 + 0.55) / 2 = 0.325)
        assert_eq!(merged.e2e_overall.n, 5);
        assert!((merged.e2e_overall.mean - want.e2e_overall.mean).abs()
                < 1e-12);
        assert!((merged.e2e_overall.mean - 0.28).abs() < 1e-12);
        assert_eq!(merged.e2e_overall.max, 0.9);
        // per-routine rollups survive the merge exactly
        assert_eq!(merged.e2e_by_routine["dscal"].n, 4);
        assert!((merged.e2e_by_routine["dscal"].mean - 0.125).abs() < 1e-12);
    }
}
