//! Serving metrics: a per-kernel completion ledger.
//!
//! Every completion is recorded against the **executed kernel's registry
//! name** (from [`crate::coordinator::request::BlasResponse::kernel`]),
//! carrying kernel-exec, end-to-end, and queue-wait latencies plus FT
//! counters. Scheduling counters — plan-cache hits/misses, thread-budget
//! deferrals, the configured budget and its in-flight high-watermark —
//! live beside them, so one snapshot answers both "what ran" and "how
//! the admission/scheduling pipeline behaved".
//!
//! [`MetricsSnapshot`] still exposes the per-routine views
//! (`exec_by_routine`, `e2e_by_routine`) existing callers consume; they
//! are exact rollups of the per-kernel ledgers sharing a routine.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::stats::Summary;

/// Shared, thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Raw per-kernel ledger: retained samples + counters.
#[derive(Default)]
struct KernelLedger {
    routine: &'static str,
    completed: u64,
    errors_injected: u64,
    errors_detected: u64,
    errors_corrected: u64,
    /// kernel-exec latencies (seconds)
    exec: Vec<f64>,
    /// end-to-end latencies (queue + exec, seconds)
    e2e: Vec<f64>,
    /// queue-wait latencies (admission → execution start, seconds)
    queue: Vec<f64>,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    errors_injected: u64,
    errors_detected: u64,
    errors_corrected: u64,
    deferrals: u64,
    thread_budget: u64,
    max_in_flight_threads: u64,
    /// ledgers keyed by executed kernel registry name
    kernels: HashMap<&'static str, KernelLedger>,
}

/// Per-kernel summary in a snapshot.
#[derive(Clone, Debug, Default)]
pub struct KernelStats {
    /// Routine the kernel serves (rollup key for the per-routine views).
    pub routine: String,
    pub completed: u64,
    pub errors_injected: u64,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub exec: Summary,
    pub e2e: Summary,
    pub queue: Summary,
}

/// A snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub errors_injected: u64,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    /// Admission-time plan-cache counters (filled by the server).
    pub plan_cache_hits: u64,
    pub plan_cache_misses: u64,
    /// Times a drained batch bypassed an older group whose thread grant
    /// did not fit the remaining budget (counted per bypassed group on
    /// successful drains only, so idle re-polling does not inflate it).
    pub deferrals: u64,
    /// Configured thread budget (0 when no server is involved).
    pub thread_budget: u64,
    /// High-watermark of in-flight thread grants.
    pub max_in_flight_threads: u64,
    /// Per-kernel ledger, keyed by executed kernel registry name.
    pub kernels: HashMap<String, KernelStats>,
    /// Per-routine rollups (exact: aggregated from the retained
    /// per-kernel samples) for callers that don't care which kernel ran.
    pub exec_by_routine: HashMap<String, Summary>,
    pub e2e_by_routine: HashMap<String, Summary>,
    /// Exact all-kernel end-to-end summary (computed from every retained
    /// sample at snapshot time, not from per-group means).
    pub e2e_overall: Summary,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one completion against the kernel that executed it.
    #[allow(clippy::too_many_arguments)]
    pub fn record_completion(&self, kernel: &'static str,
                             routine: &'static str, exec_s: f64, e2e_s: f64,
                             queue_s: f64, detected: u64, corrected: u64,
                             injected: u64) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.errors_detected += detected;
        m.errors_corrected += corrected;
        m.errors_injected += injected;
        let k = m.kernels.entry(kernel).or_default();
        k.routine = routine;
        k.completed += 1;
        k.errors_detected += detected;
        k.errors_corrected += corrected;
        k.errors_injected += injected;
        k.exec.push(exec_s);
        k.e2e.push(e2e_s);
        k.queue.push(queue_s);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Count groups a drained batch bypassed on budget grounds.
    pub fn record_deferrals(&self, n: u64) {
        if n > 0 {
            self.inner.lock().unwrap().deferrals += n;
        }
    }

    /// Record the ledger level after an admission (keeps the
    /// high-watermark the oversubscription test asserts on).
    pub fn record_in_flight(&self, in_flight_threads: u64) {
        let mut m = self.inner.lock().unwrap();
        m.max_in_flight_threads = m.max_in_flight_threads.max(in_flight_threads);
    }

    pub fn set_thread_budget(&self, budget: u64) {
        self.inner.lock().unwrap().thread_budget = budget;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let mut kernels = HashMap::new();
        let mut exec_by_routine: HashMap<String, Vec<f64>> = HashMap::new();
        let mut e2e_by_routine: HashMap<String, Vec<f64>> = HashMap::new();
        let mut e2e_all = Vec::new();
        for (name, k) in &m.kernels {
            kernels.insert(name.to_string(), KernelStats {
                routine: k.routine.to_string(),
                completed: k.completed,
                errors_injected: k.errors_injected,
                errors_detected: k.errors_detected,
                errors_corrected: k.errors_corrected,
                exec: Summary::from_samples(&k.exec),
                e2e: Summary::from_samples(&k.e2e),
                queue: Summary::from_samples(&k.queue),
            });
            exec_by_routine
                .entry(k.routine.to_string())
                .or_default()
                .extend_from_slice(&k.exec);
            e2e_by_routine
                .entry(k.routine.to_string())
                .or_default()
                .extend_from_slice(&k.e2e);
            e2e_all.extend_from_slice(&k.e2e);
        }
        MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            errors_injected: m.errors_injected,
            errors_detected: m.errors_detected,
            errors_corrected: m.errors_corrected,
            plan_cache_hits: 0,
            plan_cache_misses: 0,
            deferrals: m.deferrals,
            thread_budget: m.thread_budget,
            max_in_flight_threads: m.max_in_flight_threads,
            kernels,
            exec_by_routine: exec_by_routine
                .into_iter()
                .map(|(k, v)| (k, Summary::from_samples(&v)))
                .collect(),
            e2e_by_routine: e2e_by_routine
                .into_iter()
                .map(|(k, v)| (k, Summary::from_samples(&v)))
                .collect(),
            e2e_overall: Summary::from_samples(&e2e_all),
        }
    }
}

impl MetricsSnapshot {
    /// All-kernel end-to-end latency summary — exact (computed from
    /// every retained sample at snapshot time; the old implementation
    /// averaged per-routine means, biasing the mean toward sparse
    /// routines and fabricating percentiles).
    pub fn overall_e2e(&self) -> Summary {
        self.e2e_overall.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_kernel() {
        let m = Metrics::new();
        m.record_completion("dgemm/abft-fused", "dgemm", 0.1, 0.2, 0.05, 1, 1, 1);
        m.record_completion("dgemm/tuned", "dgemm", 0.3, 0.4, 0.0, 0, 0, 0);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.errors_detected, 1);
        assert_eq!(s.errors_corrected, 1);
        // per-kernel ledger entries
        let k = &s.kernels["dgemm/abft-fused"];
        assert_eq!(k.routine, "dgemm");
        assert_eq!(k.completed, 1);
        assert_eq!(k.errors_detected, 1);
        assert!((k.queue.mean - 0.05).abs() < 1e-12);
        // routine rollup merges both kernels
        let g = &s.exec_by_routine["dgemm"];
        assert_eq!(g.n, 2);
        assert!((g.mean - 0.2).abs() < 1e-12);
    }

    #[test]
    fn overall_e2e_is_an_exact_weighted_rollup() {
        let m = Metrics::new();
        // 3 fast dscal completions vs 1 slow dgemm: a mean-of-means
        // would report (0.1 + 0.9) / 2 = 0.5; the exact mean is 0.3.
        for _ in 0..3 {
            m.record_completion("dscal/tuned", "dscal", 0.1, 0.1, 0.0, 0, 0, 0);
        }
        m.record_completion("dgemm/tuned", "dgemm", 0.9, 0.9, 0.0, 0, 0, 0);
        let s = m.snapshot().overall_e2e();
        assert_eq!(s.n, 4);
        assert!((s.mean - 0.3).abs() < 1e-12, "mean {} not exact", s.mean);
        assert_eq!(s.max, 0.9);
        assert_eq!(s.min, 0.1);
    }

    #[test]
    fn scheduling_counters_track_budget_pressure() {
        let m = Metrics::new();
        m.set_thread_budget(8);
        m.record_in_flight(5);
        m.record_in_flight(3);
        m.record_deferrals(2);
        m.record_deferrals(0);
        let s = m.snapshot();
        assert_eq!(s.thread_budget, 8);
        assert_eq!(s.max_in_flight_threads, 5);
        assert_eq!(s.deferrals, 2);
    }
}
