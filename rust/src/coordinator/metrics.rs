//! Serving metrics: request/latency counters, per-routine breakdowns,
//! FT counters (errors injected / detected / corrected).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::stats::Summary;

/// Shared, thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    completed: u64,
    failed: u64,
    errors_injected: u64,
    errors_detected: u64,
    errors_corrected: u64,
    /// per-routine kernel-exec latencies (seconds)
    exec: HashMap<String, Vec<f64>>,
    /// per-routine end-to-end latencies (queue + exec, seconds)
    e2e: HashMap<String, Vec<f64>>,
}

/// A snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub failed: u64,
    pub errors_injected: u64,
    pub errors_detected: u64,
    pub errors_corrected: u64,
    pub exec_by_routine: HashMap<String, Summary>,
    pub e2e_by_routine: HashMap<String, Summary>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_completion(&self, routine: &str, exec_s: f64, e2e_s: f64,
                             detected: u64, corrected: u64, injected: u64) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.errors_detected += detected;
        m.errors_corrected += corrected;
        m.errors_injected += injected;
        m.exec.entry(routine.to_string()).or_default().push(exec_s);
        m.e2e.entry(routine.to_string()).or_default().push(e2e_s);
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        MetricsSnapshot {
            completed: m.completed,
            failed: m.failed,
            errors_injected: m.errors_injected,
            errors_detected: m.errors_detected,
            errors_corrected: m.errors_corrected,
            exec_by_routine: m
                .exec
                .iter()
                .map(|(k, v)| (k.clone(), Summary::from_samples(v)))
                .collect(),
            e2e_by_routine: m
                .e2e
                .iter()
                .map(|(k, v)| (k.clone(), Summary::from_samples(v)))
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    /// All-routine end-to-end latency summary.
    pub fn overall_e2e(&self) -> Summary {
        let mut all = Vec::new();
        for s in self.e2e_by_routine.values() {
            // approximate: reconstruct from means isn't possible; keep the
            // per-routine path as the primary interface. This method is
            // only used when a single routine is in play.
            all.push(s.mean);
        }
        Summary::from_samples(&all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_completion("dgemm", 0.1, 0.2, 1, 1, 1);
        m.record_completion("dgemm", 0.3, 0.4, 0, 0, 0);
        m.record_failure();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert_eq!(s.errors_detected, 1);
        assert_eq!(s.errors_corrected, 1);
        let g = &s.exec_by_routine["dgemm"];
        assert_eq!(g.n, 2);
        assert!((g.mean - 0.2).abs() < 1e-12);
    }
}
