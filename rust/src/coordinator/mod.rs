//! Layer-3 coordinator: typed BLAS requests routed to native or PJRT
//! backends under an FT policy, with batching, a threaded server,
//! metrics, and workload traces.
//!
//! Topology (the paper's contribution is the kernels; the coordinator is
//! the serving shell around them — DESIGN.md §3):
//!
//! ```text
//!   clients ──> server queue ──> batcher ──> router
//!                                   │            ├─> native worker pool
//!                                   │            └─> PJRT executor thread
//!                                   └─< responses (+ FtReport, metrics)
//! ```
//!
//! The PJRT engine is not `Send`, so exactly one executor thread owns it
//! and serves artifact calls over channels ([`executor`]).

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod pjrt_backend;
pub mod request;
pub mod router;
pub mod server;
pub mod trace;

pub use request::{BlasRequest, BlasResponse, Backend};
