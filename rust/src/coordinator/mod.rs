//! Layer-3 coordinator: typed BLAS requests routed to native or PJRT
//! backends under an FT policy, with batching, a threaded server,
//! metrics, and workload traces.
//!
//! Topology (the paper's contribution is the kernels; the coordinator is
//! the serving shell around them — DESIGN.md §3). Dispatch is data, not
//! control flow: every native kernel registers a descriptor in the
//! [`registry`] and the [`plan::Planner`] resolves each request into an
//! execution plan (kernel, thread grant, protection scheme) that the
//! router, batcher, and server all consume:
//!
//! ```text
//!   clients ──> server queue ──> batcher ──> router ──┬─> PJRT executor thread
//!                   │      (groups by routine×shape)  │
//!                   │                                 └─> planner ──> kernel registry
//!                   │                                        │    (descriptor table:
//!                   │                                        │     serial / MT / DMR /
//!                   │                                        │     ABFT kernels per
//!                   │                                        │     routine × policy)
//!                   │                                        └─> ExecutionPlan
//!                   │                                            (kernel, threads,
//!                   │                                             protection scheme)
//!                   └─< responses (+ FtReport, executed-kernel name, metrics)
//! ```
//!
//! The PJRT engine is not `Send`, so exactly one executor thread owns it
//! and serves artifact calls over channels ([`executor`]).

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod pjrt_backend;
pub mod plan;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod trace;

pub use plan::{ExecutionPlan, Planner};
pub use registry::{KernelDescriptor, KernelRegistry};
pub use request::{BlasRequest, BlasResponse, Backend};
