//! Layer-3 coordinator: typed BLAS requests routed to native or PJRT
//! backends under an FT policy, with batching, a threaded server,
//! metrics, and workload traces.
//!
//! Topology (the paper's contribution is the kernels; the coordinator is
//! the serving shell around them — DESIGN.md §3). Dispatch is data, not
//! control flow: every native kernel registers a descriptor (with a
//! stable [`registry::KernelId`]) in the [`registry`], and the request
//! path is organized as an **admission → schedule → execute** pipeline
//! around the resolved [`plan::ExecutionPlan`]:
//!
//! ```text
//!   clients ──> submit = ADMISSION ───> batcher = SCHEDULE ──> workers = EXECUTE
//!               │  plan cache             │  sub-queues keyed      │
//!               │  (routine×dim×          │  by planned kernel     ├─> execute_planned
//!               │   policy×backend        │  id; thread-budget     │   (pre-resolved
//!               │   → ExecutionPlan,      │  ledger defers MT      │    native kernel,
//!               │   memoized, planner     │  batches that would    │    no lookup)
//!               │   runs once per key)    │  oversubscribe,        └─> PJRT executor
//!               │                         │  serial flows past         (unplanned jobs)
//!               └─< responses (+ FtReport, executed-kernel name,
//!                   per-kernel metrics ledger: exec/e2e/queue-wait,
//!                   plan-cache hits/misses, deferrals, FT counters)
//! ```
//!
//! - **Admission** ([`server::ServerHandle::submit`]): the request is
//!   resolved once through the [`plan::PlanCache`]; its batch key is the
//!   planned kernel's id, so shapes that run the same registered kernel
//!   share a batch window.
//! - **Schedule** ([`batcher`]): per-key sub-queues with groups ordered
//!   by oldest member — a drain is O(batch), and the cost-aware drain
//!   lets the server's thread-budget ledger defer an MT batch (its
//!   whole thread grant is debited while in flight) without blocking
//!   serial traffic behind it.
//! - **Execute** ([`router::Router::execute_planned`]): workers run the
//!   pre-resolved plan; the per-request planner lookup survives only in
//!   the [`router::Router::execute`] compatibility shim used by the
//!   CLI, benches, and examples.
//!
//! The PJRT engine is not `Send`, so exactly one executor thread owns it
//! and serves artifact calls over channels ([`executor`]); PJRT jobs are
//! admitted unplanned (the executor plans per-artifact) and batch by
//! `(routine, dim)`.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod pjrt_backend;
pub mod plan;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod trace;

pub use metrics::{KernelStats, MetricsSnapshot};
pub use plan::{ExecutionPlan, PlanCache, Planner};
pub use registry::{KernelDescriptor, KernelId, KernelRegistry};
pub use request::{BlasRequest, BlasResponse, Backend};
