//! Layer-3 coordinator: typed BLAS requests routed to native or PJRT
//! backends under an FT policy, with batching, a threaded server,
//! metrics, and workload traces.
//!
//! Topology (the paper's contribution is the kernels; the coordinator is
//! the serving shell around them — DESIGN.md §3). Dispatch is data, not
//! control flow: every native kernel registers a descriptor (with a
//! stable [`registry::KernelId`]) in the [`registry`], and the request
//! path is organized as an **admission → route → schedule → execute**
//! pipeline around the resolved [`plan::ExecutionPlan`]:
//!
//! ```text
//!   clients ──> submit = ADMISSION ──> ROUTE ────> batcher = SCHEDULE ──> workers = EXECUTE
//!               │  plan cache            │  rendezvous   │  sub-queues keyed   │
//!               │  (routine×dim×         │  hash on      │  by planned kernel  ├─> execute_planned
//!               │   policy×selection     │  kernel id;   │  id; thread-budget  │   (pre-resolved
//!               │   → ExecutionPlan,     │  queue-depth  │  ledger defers MT   │    kernel: native,
//!               │   memoized); depth     │  tiebreak     │  batches that would │    GPU-sim, or the
//!               │   watermark sheds      │  over the     │  oversubscribe,     │    PJRT peer —
//!               │   `Overloaded`;        │  shards       │  serial flows past  │    no lookup)
//!               │   `NoCandidate` =
//!               │   exhaustive planner diagnostics
//!               └─< responses (+ FtReport, executed-kernel name, per-kernel
//!                   metrics ledger: exec/e2e/queue-wait, SLO burns, plan-cache
//!                   hits/misses, deferrals, sheds, FT counters — per shard,
//!                   merged exactly by MetricsSnapshot::merge)
//! ```
//!
//! - **Admission** ([`cluster::ClusterHandle::submit`], or
//!   [`server::ServerHandle::submit`] for a standalone shard): the
//!   request is resolved once through the [`plan::PlanCache`] under a
//!   [`plan::SelectionPolicy`] — ordered backend preferences plus
//!   allow/deny lists and capability requirements, with any per-request
//!   `routing` overlay merged in; its batch key is the planned kernel's
//!   id, so shapes that run the same registered kernel share a batch
//!   window. A selection no descriptor satisfies is rejected at the
//!   door as [`server::Error::NoCandidate`], carrying every considered
//!   descriptor and the capability each missed; a shard at its
//!   `admission_depth` watermark sheds the submission with a typed
//!   [`server::Error::Overloaded`] instead of queueing unboundedly.
//! - **Route** ([`cluster`]): deterministic rendezvous hashing on the
//!   planned kernel id pins each kernel's traffic to one shard (keeping
//!   kernel-keyed batching effective there); score ties fall to the
//!   shard with the shallower live queue.
//! - **Schedule** ([`batcher`]): per-key sub-queues with groups ordered
//!   by oldest member — a drain is O(batch), and the cost-aware drain
//!   lets the shard's thread-budget ledger defer an MT batch (its
//!   whole thread grant is debited while in flight) without blocking
//!   serial traffic behind it.
//! - **Execute** ([`router::Router::execute_planned`]): workers run the
//!   pre-resolved plan — native kernels and GPU-sim descriptors execute
//!   in-process, while a plan carrying the PJRT peer backend is handed
//!   to the attached [`pjrt_backend::PjrtBackend`]. There is no
//!   unplanned dispatch path: the planned API *is* the whole API.
//!
//! The tier is **elastic**: an [`autoscale::ScalingController`] samples
//! queue depth, shed rate, and SLO burn rate over a sliding window and
//! grows/shrinks the shard set between the profile's
//! `min_shards`/`max_shards` bounds. Growth spawns a fresh engine on
//! the shared router with a fresh-generation rendezvous salt (only the
//! minimal kernel-id slice migrates); shrink unroutes the newest shard,
//! drains it to completion, and retires its ledger into the merged
//! snapshot — in-flight requests are never dropped. Clients wrap
//! [`cluster::ClusterHandle::submit_with_retry`] around bursty traffic
//! to ride out transient `Overloaded` sheds with jittered backoff.
//! `docs/ARCHITECTURE.md` narrates the whole pipeline, including the
//! scaling state machine.
//!
//! The PJRT engine is a registry-resident **peer backend**: its
//! descriptors sit in the same registry as the native kernels, so PJRT
//! jobs are planned, batched, and routed by kernel id like everything
//! else. The engine itself is not `Send`, so exactly one executor
//! thread owns it and serves artifact calls over channels
//! ([`executor`]).
//!
//! Above the whole pipeline sits the **network serving plane**: the
//! dependency-free HTTP/1.1 parser in [`http`] and the [`gateway`] that
//! binds a `TcpListener` in front of a cluster, decodes the
//! `ftblas.request.v1`/`v2` envelopes (v2 adds the optional `routing`
//! selection overlay), submits through
//! [`cluster::ClusterHandle::submit_with_retry_routed`], and maps the
//! typed admission errors onto wire status codes (`429` + `Retry-After`
//! for `Overloaded`, `400` for plan failures and `NoCandidate`
//! selections, `504` past the deadline) — the transport/execution seam
//! `docs/PROTOCOL.md` specifies.

pub mod autoscale;
pub mod batcher;
pub mod cluster;
pub mod executor;
pub mod gateway;
pub mod http;
pub mod metrics;
pub mod pjrt_backend;
pub mod plan;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod trace;

pub use autoscale::{ScaleDecision, ScalingConfig, ScalingController,
                    TierSample};
pub use cluster::{Cluster, ClusterConfig, ClusterHandle, RetryPolicy,
                  ShardSlot, TopologySnapshot};
pub use gateway::{Envelope, Gateway, GatewayConfig, GatewayStats};
pub use metrics::{KernelStats, MetricsSnapshot};
pub use plan::{CapRequirement, ExecutionPlan, NoCandidate, PlanCache,
               Planner, SelectionPolicy};
pub use registry::{KernelDescriptor, KernelId, KernelRegistry};
pub use request::{BlasRequest, BlasResponse, Backend};
pub use server::{Error, Server, ServerHandle};
