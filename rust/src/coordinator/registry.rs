//! Kernel registry: dispatch as data, not control flow.
//!
//! Every native kernel registers a [`KernelDescriptor`] — routine, BLAS
//! level, [`Impl`] variant, the [`FtPolicy`] capabilities it can serve,
//! the backend it reports as, whether it runs on the profile's thread
//! pool, and its MR-aligned minimum-size floor — plus a uniform
//! [`KernelFn`] entry point. The [`crate::coordinator::plan::Planner`]
//! resolves a request + policy + profile into one of these entries; the
//! router, server, and bench harnesses all enumerate the same table
//! instead of hand-maintaining per-routine × per-variant match arms.

use crate::blas::level3::GemmParams;
use crate::blas::{batched, blocked, gpu_sim, level1, level2, level3, naive,
                  parallel, simd, Impl};
use crate::config::Profile;
use crate::coordinator::request::{
    Backend, BlasRequest, BlasResult, Level,
};
use crate::ft::abft_fused::Strike;
use crate::ft::injector::Fault;
use crate::ft::policy::FtPolicy;
use crate::ft::{abft, abft_fused, abft_weighted, dmr, FtReport};
use crate::util::matrix::Matrix;

/// Everything a registered kernel sees at execution time.
pub struct ExecCtx<'a> {
    /// The request being executed.
    pub req: &'a BlasRequest,
    /// Machine profile (block parameters, panel sizes).
    pub profile: &'a Profile,
    /// Protection policy the plan selected.
    pub policy: FtPolicy,
    /// Planned faults to inject (empty on clean runs). Serial DMR/ABFT
    /// schemes consume the first; the banded MT kernels route each
    /// strike to the thread band owning its row.
    pub faults: &'a [Fault],
    /// Thread count granted by the plan (1 for serial kernels).
    pub threads: usize,
}

impl ExecCtx<'_> {
    fn fault(&self) -> Option<Fault> {
        self.faults.first().copied()
    }

    fn inj_elem(&self) -> Option<(usize, f64)> {
        self.faults.first().map(|f| (f.i, f.delta))
    }
}

/// Uniform kernel entry point.
pub type KernelFn = fn(&ExecCtx) -> (BlasResult, FtReport);

type KernelOut = (BlasResult, FtReport);

/// Protection scheme a registered kernel implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Unprotected.
    None,
    /// Duplicate-and-verify (paper §4, memory-bound L1/L2).
    Dmr,
    /// Fused online ABFT (paper §5.2).
    AbftFused,
    /// ABFT around a third-party GEMM (paper §5.1).
    AbftUnfused,
    /// Weighted double-checksum ABFT (paper §2.1 citation).
    AbftWeighted,
    /// FT-TRSM: panel ABFT + checksum-verified diagonal solves.
    FtTrsm,
}

impl Scheme {
    /// Report/constraint name of the scheme (the `--require scheme=…`
    /// and `/backends` vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::None => "none",
            Scheme::Dmr => "dmr",
            Scheme::AbftFused => "abft-fused",
            Scheme::AbftUnfused => "abft-unfused",
            Scheme::AbftWeighted => "abft-weighted",
            Scheme::FtTrsm => "ft-trsm",
        }
    }

    /// Parse a scheme name (the inverse of [`Scheme::name`]).
    pub fn by_name(s: &str) -> Option<Scheme> {
        match s {
            "none" => Some(Scheme::None),
            "dmr" => Some(Scheme::Dmr),
            "abft-fused" => Some(Scheme::AbftFused),
            "abft-unfused" => Some(Scheme::AbftUnfused),
            "abft-weighted" => Some(Scheme::AbftWeighted),
            "ft-trsm" => Some(Scheme::FtTrsm),
            _ => None,
        }
    }
}

/// Stable identity of a registered kernel: its index in the global
/// registry table. Registration order is append-only (new kernels go at
/// the end of their routine's block or the table's end), so an id is
/// stable for the life of a process and cheap to hash — the batcher
/// keys its sub-queues by it and the plan cache stores it in every
/// [`crate::coordinator::plan::ExecutionPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(pub u16);

/// A registered kernel.
pub struct KernelDescriptor {
    /// Registry name, `"<routine>/<flavor>"` (e.g. `"dgemm/abft-fused-mt"`).
    pub name: &'static str,
    /// Routine the kernel serves.
    pub routine: &'static str,
    /// BLAS level of the routine.
    pub level: Level,
    /// Variant family the kernel belongs to (protected kernels are
    /// built on the tuned substrate and register as [`Impl::Tuned`]).
    pub variant: Impl,
    /// Backend the kernel reports as.
    pub backend: Backend,
    /// Protection scheme the kernel implements.
    pub scheme: Scheme,
    /// FT policies this kernel can serve.
    pub policies: &'static [FtPolicy],
    /// Runs on the profile's kernel thread pool when granted threads.
    pub threaded: bool,
    /// Minimum principal dimension in units of `GemmParams.mr` (banded
    /// kernels need at least two MR-aligned bands; 0 = no floor).
    pub min_mr_multiple: usize,
    /// Largest principal dimension this kernel serves (0 = unbounded).
    /// The GPU-sim small-tile tier caps itself here so selection falls
    /// through to the unbounded tier above the cap.
    pub max_dim: usize,
    /// Largest principal dimension an item may have to ride this
    /// kernel's batch-fused execution (0 = not batch-capable). Only the
    /// `dgemm/batched*` entries set this: batch fusion pays off exactly
    /// where per-call threading does not — many small items.
    pub batch_dim_ceiling: usize,
    /// One-line human description (bench row notes).
    pub summary: &'static str,
    /// The kernel entry point.
    pub execute: KernelFn,
}

impl KernelDescriptor {
    /// Whether this kernel can serve `policy`.
    pub fn supports(&self, policy: FtPolicy) -> bool {
        self.policies.contains(&policy)
    }

    /// Does a request of principal dimension `dim` clear this kernel's
    /// MR-aligned floor?
    pub fn admits_dim(&self, dim: usize, mr: usize) -> bool {
        dim >= self.min_mr_multiple * mr
    }

    /// How many pool threads a batch of this kernel occupies when
    /// granted `grant` threads — the server's thread-budget ledger
    /// debits this amount per in-flight batch. Serial kernels cost the
    /// worker thread itself; threaded kernels cost their whole grant.
    pub fn thread_cost(&self, grant: usize) -> usize {
        if self.threaded { grant.max(1) } else { 1 }
    }

    /// Can an item of principal dimension `dim` ride this kernel's
    /// batch-fused execution? Always false for non-batched kernels.
    pub fn admits_batch(&self, dim: usize) -> bool {
        self.batch_dim_ceiling > 0 && dim > 0 && dim <= self.batch_dim_ceiling
    }

    /// Is `dim` within this kernel's dimension cap (`max_dim`, 0 =
    /// unbounded)?
    pub fn serves_dim(&self, dim: usize) -> bool {
        self.max_dim == 0 || dim <= self.max_dim
    }

    /// The typed capability record the selection layer, the `/backends`
    /// serializer, and the no-candidate diagnostics all consume. The
    /// descriptor *is* the capability set; this view materializes it
    /// with the derived fields (precision, CPU-feature requirements)
    /// spelled out.
    pub fn capabilities(&self) -> Capabilities {
        Capabilities {
            backend: self.backend,
            precision: "f64",
            max_dim: self.max_dim,
            batch_dim_ceiling: self.batch_dim_ceiling,
            policies: self.policies,
            scheme: self.scheme,
            threaded: self.threaded,
            min_mr_multiple: self.min_mr_multiple,
            cpu_features: match self.variant {
                Impl::Simd => &["avx2", "fma"],
                _ => &[],
            },
        }
    }
}

/// The capability set of one registered kernel — what the
/// [`crate::coordinator::plan::SelectionPolicy`] constraint vocabulary
/// matches against and what `/backends` serializes.
#[derive(Clone, Copy, Debug)]
pub struct Capabilities {
    /// Backend identity.
    pub backend: Backend,
    /// Element precision (every registered kernel is f64 today).
    pub precision: &'static str,
    /// Largest principal dimension served (0 = unbounded).
    pub max_dim: usize,
    /// Batch-fusion dimension ceiling (0 = not batch-capable).
    pub batch_dim_ceiling: usize,
    /// FT policies served.
    pub policies: &'static [FtPolicy],
    /// Protection scheme implemented.
    pub scheme: Scheme,
    /// Whether the kernel rides the profile's thread pool.
    pub threaded: bool,
    /// MR-aligned minimum-dimension floor (units of `GemmParams.mr`).
    pub min_mr_multiple: usize,
    /// CPU features the kernel's fast path requires (it still runs —
    /// via runtime-probed fallback — without them).
    pub cpu_features: &'static [&'static str],
}

/// The registry: a static table of every native kernel.
pub struct KernelRegistry {
    entries: &'static [KernelDescriptor],
}

static REGISTRY: KernelRegistry = KernelRegistry { entries: ENTRIES };

impl KernelRegistry {
    /// The process-wide registry table.
    pub fn global() -> &'static KernelRegistry {
        &REGISTRY
    }

    /// Every descriptor, in registration (= [`KernelId`]) order.
    pub fn entries(&self) -> &'static [KernelDescriptor] {
        self.entries
    }

    /// All entries for one routine, in registration order.
    pub fn for_routine(&self, routine: &str) -> Vec<&'static KernelDescriptor> {
        self.entries.iter().filter(|e| e.routine == routine).collect()
    }

    /// Look up an entry by registry name.
    pub fn find(&self, name: &str) -> Option<&'static KernelDescriptor> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The stable id of a descriptor from this table (its index in
    /// registration order). Returns `None` for a descriptor that does
    /// not live in the table.
    pub fn id_of(&self, k: &'static KernelDescriptor) -> Option<KernelId> {
        self.entries
            .iter()
            .position(|e| std::ptr::eq(e, k))
            .map(|i| KernelId(i as u16))
    }

    /// Resolve a stable id back to its descriptor.
    pub fn by_id(&self, id: KernelId) -> Option<&'static KernelDescriptor> {
        self.entries.get(id.0 as usize)
    }

    /// The serial unprotected *native* variant ladder for one routine
    /// (naive → blocked → tuned → simd where a SIMD rung is
    /// registered), as the bench figures enumerate it. Peer-backend
    /// descriptors (PJRT, GPU-sim) are not rungs of this ladder.
    pub fn serial_variants(&self, routine: &str)
                           -> Vec<&'static KernelDescriptor> {
        self.entries
            .iter()
            .filter(|e| {
                e.routine == routine
                    && !e.threaded
                    && e.scheme == Scheme::None
                    && e.backend.is_native()
            })
            .collect()
    }

    /// The batch-fused counterpart of a per-call kernel, if one is
    /// registered: same routine, variant family, and protection scheme.
    /// The server's worker fuses a drained batch through this mapping
    /// when every item's plan resolved to `k` and every item's dim
    /// clears [`KernelDescriptor::admits_batch`]. Both serial and MT
    /// per-call kernels map — a batch of MT-planned small GEMMs is
    /// exactly the per-item fork/join waste fusion removes (the fused
    /// batch reuses the plan's grant for one pooled frame instead).
    pub fn batched_sibling(&self, k: &KernelDescriptor)
                           -> Option<&'static KernelDescriptor> {
        if k.batch_dim_ceiling > 0 {
            return None; // already batched
        }
        self.entries.iter().find(|e| {
            e.batch_dim_ceiling > 0
                && e.routine == k.routine
                && e.variant == k.variant
                && e.scheme == k.scheme
                && e.backend == k.backend
        })
    }

    /// Unique routine names, in registration order.
    pub fn routines(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for e in self.entries {
            if !out.contains(&e.routine) {
                out.push(e.routine);
            }
        }
        out
    }
}

// --------------------------------------------------- selection ledger

fn selection_counters() -> &'static [std::sync::atomic::AtomicU64] {
    use std::sync::atomic::AtomicU64;
    use std::sync::OnceLock;
    static COUNTS: OnceLock<Vec<AtomicU64>> = OnceLock::new();
    COUNTS.get_or_init(|| {
        (0..ENTRIES.len()).map(|_| AtomicU64::new(0)).collect()
    })
}

/// Record one planner selection of `id` — the per-kernel half of the
/// per-backend selection counts `/backends` reports.
pub fn note_selected(id: KernelId) {
    if let Some(c) = selection_counters().get(id.0 as usize) {
        c.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
}

/// How many times the planner has selected `id` in this process.
pub fn selection_count(id: KernelId) -> u64 {
    selection_counters()
        .get(id.0 as usize)
        .map_or(0, |c| c.load(std::sync::atomic::Ordering::Relaxed))
}

/// The `/backends` document (`ftblas.backends.v1`): every backend with
/// its health, aggregate selection count, and per-kernel capability
/// records. Shared verbatim by the gateway admin route and the
/// `ftblas backends` subcommand. `pjrt_health` is the PJRT backend's
/// probe result when a handle is resident (`None` = not loaded).
pub fn backends_json(pjrt_health: Option<String>) -> crate::util::json::Json {
    use crate::util::json::Json;
    let reg = KernelRegistry::global();
    let mut backends = Vec::new();
    for be in Backend::ALL {
        let mut kernels = Vec::new();
        let mut selected = 0u64;
        for (i, e) in reg.entries().iter().enumerate() {
            if e.backend != be {
                continue;
            }
            let caps = e.capabilities();
            let count = selection_count(KernelId(i as u16));
            selected += count;
            kernels.push(
                Json::obj()
                    .field("name", Json::Str(e.name.to_string()))
                    .field("routine", Json::Str(e.routine.to_string()))
                    .field("scheme", Json::Str(caps.scheme.name().into()))
                    .field("precision", Json::Str(caps.precision.into()))
                    .field("threaded", Json::Bool(caps.threaded))
                    .field("max_dim", Json::Int(caps.max_dim as u64))
                    .field("batch_dim_ceiling",
                           Json::Int(caps.batch_dim_ceiling as u64))
                    .field("min_mr_multiple",
                           Json::Int(caps.min_mr_multiple as u64))
                    .field(
                        "policies",
                        Json::Arr(caps.policies.iter()
                            .map(|p| Json::Str(p.name().to_string()))
                            .collect()),
                    )
                    .field(
                        "cpu_features",
                        Json::Arr(caps.cpu_features.iter()
                            .map(|f| Json::Str((*f).to_string()))
                            .collect()),
                    )
                    .field("selected", Json::Int(count)),
            );
        }
        let health = match be {
            Backend::Pjrt => pjrt_health.clone()
                .unwrap_or_else(|| "unavailable: no handle loaded".into()),
            Backend::GpuSim => "healthy: simulated executor".into(),
            _ => "healthy: compiled in".into(),
        };
        backends.push(
            Json::obj()
                .field("backend", Json::Str(be.name().to_string()))
                .field("health", Json::Str(health))
                .field("selected", Json::Int(selected))
                .field("kernels", Json::Arr(kernels)),
        );
    }
    Json::obj()
        .field("schema", Json::Str("ftblas.backends.v1".into()))
        .field("backends", Json::Arr(backends))
}

// ---------------------------------------------------------------- policies

const UNPROTECTED: &[FtPolicy] = &[FtPolicy::None];
/// Protected policies a DMR kernel serves (every non-None policy falls
/// back to DMR on L1/L2 — the hybrid strategy's memory-bound half).
const PROTECTED_ALL: &[FtPolicy] =
    &[FtPolicy::Hybrid, FtPolicy::AbftUnfused, FtPolicy::AbftWeighted];
const HYBRID_ONLY: &[FtPolicy] = &[FtPolicy::Hybrid];
/// Fused-ABFT kernels also serve the weighted policy for routines the
/// weighted frame does not cover (DSYMM/DTRMM).
const HYBRID_OR_WEIGHTED: &[FtPolicy] =
    &[FtPolicy::Hybrid, FtPolicy::AbftWeighted];
const UNFUSED_ONLY: &[FtPolicy] = &[FtPolicy::AbftUnfused];
const WEIGHTED_ONLY: &[FtPolicy] = &[FtPolicy::AbftWeighted];
/// DSYRK has no FT path (the paper does not protect it): its plain
/// kernels serve every policy with a clean report.
const ANY_POLICY: &[FtPolicy] = &[
    FtPolicy::None,
    FtPolicy::Hybrid,
    FtPolicy::AbftUnfused,
    FtPolicy::AbftWeighted,
];

// ------------------------------------------------------------ constructors

const fn serial_with(name: &'static str, routine: &'static str, level: Level,
                     variant: Impl, policies: &'static [FtPolicy],
                     summary: &'static str, execute: KernelFn)
                     -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level,
        variant,
        backend: Backend::for_variant(variant),
        scheme: Scheme::None,
        policies,
        threaded: false,
        min_mr_multiple: 0,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

const fn serial(name: &'static str, routine: &'static str, level: Level,
                variant: Impl, summary: &'static str, execute: KernelFn)
                -> KernelDescriptor {
    serial_with(name, routine, level, variant, UNPROTECTED, summary, execute)
}

const fn protected(name: &'static str, routine: &'static str, level: Level,
                   scheme: Scheme, policies: &'static [FtPolicy],
                   summary: &'static str, execute: KernelFn)
                   -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level,
        variant: Impl::Tuned,
        backend: Backend::NativeTuned,
        scheme,
        policies,
        threaded: false,
        min_mr_multiple: 0,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

const fn threaded(name: &'static str, routine: &'static str, scheme: Scheme,
                  policies: &'static [FtPolicy], summary: &'static str,
                  execute: KernelFn) -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level: Level::L3,
        variant: Impl::Tuned,
        backend: Backend::NativeTuned,
        scheme,
        policies,
        threaded: true,
        // at least two MR-aligned row bands, else the MT frame falls
        // through to the serial kernel anyway
        min_mr_multiple: 2,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

/// Protected kernel built on the SIMD substrate: same shape as
/// [`protected`] but registering as [`Impl::Simd`] so planner variant
/// selection and `--variant simd` route to it.
const fn protected_simd(name: &'static str, routine: &'static str,
                        scheme: Scheme, policies: &'static [FtPolicy],
                        summary: &'static str, execute: KernelFn)
                        -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level: Level::L3,
        variant: Impl::Simd,
        backend: Backend::NativeSimd,
        scheme,
        policies,
        threaded: false,
        min_mr_multiple: 0,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

/// Threaded kernel on the SIMD substrate — [`threaded`]'s counterpart
/// for [`Impl::Simd`]. The SIMD MT frames band on the 8-row SIMD
/// micro-tile and fall through to the serial SIMD kernel below the
/// floor, so the same two-band minimum applies.
const fn threaded_simd(name: &'static str, routine: &'static str,
                       scheme: Scheme, policies: &'static [FtPolicy],
                       summary: &'static str, execute: KernelFn)
                       -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level: Level::L3,
        variant: Impl::Simd,
        backend: Backend::NativeSimd,
        scheme,
        policies,
        threaded: true,
        min_mr_multiple: 2,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

/// Every `dgemm/batched*` entry fuses items up to this principal
/// dimension. Above it a request is better served per-call (the MT
/// kernels band it across the pool); at or below it per-call threading
/// is idle overhead and batch fusion wins.
pub const BATCH_DIM_CEILING: usize = 64;

/// Batch-fused kernel: executes a whole same-plan batch of small GEMMs
/// under one threading frame (see [`crate::blas::batched`]). Registered
/// `threaded` — a fused batch occupies one pool grant, debited once per
/// batch, not per item — with the standard two-band MR floor so the
/// planner's per-request selection never prefers it over the earlier MT
/// entries: batched kernels are entered through the server's fusion
/// step ([`KernelRegistry::batched_sibling`]), or as a batch of one via
/// the uniform [`KernelFn`] entry point.
const fn batched_kernel(name: &'static str, variant: Impl, scheme: Scheme,
                        policies: &'static [FtPolicy],
                        summary: &'static str, execute: KernelFn)
                        -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine: "dgemm",
        level: Level::L3,
        variant,
        backend: Backend::for_variant(variant),
        scheme,
        policies,
        threaded: true,
        min_mr_multiple: 2,
        max_dim: 0,
        batch_dim_ceiling: BATCH_DIM_CEILING,
        summary,
        execute,
    }
}

/// Registry-resident descriptor for a PJRT-served routine. PJRT is a
/// peer backend: its descriptors compete in capability selection like
/// any native entry, but execution is dispatched by
/// [`crate::coordinator::router::Router::execute_planned`] to the
/// resident [`crate::coordinator::pjrt_backend::PjrtBackend`] handle
/// (artifact dispatch needs the process-wide executor, which a static
/// table cannot hold) — the uniform entry point below is unreachable
/// by construction.
const fn pjrt_peer(name: &'static str, routine: &'static str, level: Level,
                   summary: &'static str) -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine,
        level,
        variant: Impl::Tuned,
        backend: Backend::Pjrt,
        scheme: Scheme::None,
        policies: ANY_POLICY,
        threaded: false,
        min_mr_multiple: 0,
        max_dim: 0,
        batch_dim_ceiling: 0,
        summary,
        execute: pjrt_dispatches_via_router,
    }
}

/// See [`pjrt_peer`]: planned PJRT jobs are intercepted by the router
/// before the registry entry point is reached.
fn pjrt_dispatches_via_router(c: &ExecCtx) -> KernelOut {
    unreachable!(
        "{}: PJRT descriptors execute through Router::execute_planned",
        c.req.routine()
    )
}

/// Simulated-GPU executor descriptor (see [`crate::blas::gpu_sim`]):
/// a warp-tiled tier with an optional dimension cap, so the small-tile
/// tier yields to the unbounded tier above `max_dim`.
const fn gpu_sim_kernel(name: &'static str, scheme: Scheme,
                        policies: &'static [FtPolicy], max_dim: usize,
                        summary: &'static str, execute: KernelFn)
                        -> KernelDescriptor {
    KernelDescriptor {
        name,
        routine: "dgemm",
        level: Level::L3,
        variant: Impl::Tuned,
        backend: Backend::GpuSim,
        scheme,
        policies,
        threaded: false,
        min_mr_multiple: 0,
        max_dim,
        batch_dim_ceiling: 0,
        summary,
        execute,
    }
}

// ------------------------------------------------------- Level 1 kernels

fn dscal_with(c: &ExecCtx, k: fn(f64, &mut [f64])) -> KernelOut {
    let BlasRequest::Dscal { alpha, x } = c.req else {
        unreachable!("dscal kernel planned for {}", c.req.routine())
    };
    let mut x = x.clone();
    k(*alpha, &mut x);
    (BlasResult::Vector(x), FtReport::none())
}

fn dscal_naive(c: &ExecCtx) -> KernelOut {
    dscal_with(c, naive::dscal)
}

fn dscal_blocked(c: &ExecCtx) -> KernelOut {
    dscal_with(c, blocked::dscal)
}

fn dscal_tuned(c: &ExecCtx) -> KernelOut {
    dscal_with(c, level1::dscal)
}

fn dscal_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dscal { alpha, x } = c.req else {
        unreachable!("dscal kernel planned for {}", c.req.routine())
    };
    let mut x = x.clone();
    let ft = dmr::dscal_ft(*alpha, &mut x, c.inj_elem());
    (BlasResult::Vector(x), ft)
}

fn dscal_simd(c: &ExecCtx) -> KernelOut {
    dscal_with(c, simd::dscal)
}

fn daxpy_with(c: &ExecCtx, k: fn(f64, &[f64], &mut [f64])) -> KernelOut {
    let BlasRequest::Daxpy { alpha, x, y } = c.req else {
        unreachable!("daxpy kernel planned for {}", c.req.routine())
    };
    let mut y = y.clone();
    k(*alpha, x, &mut y);
    (BlasResult::Vector(y), FtReport::none())
}

fn daxpy_naive(c: &ExecCtx) -> KernelOut {
    daxpy_with(c, naive::daxpy)
}

fn daxpy_blocked(c: &ExecCtx) -> KernelOut {
    daxpy_with(c, blocked::daxpy)
}

fn daxpy_tuned(c: &ExecCtx) -> KernelOut {
    daxpy_with(c, level1::daxpy)
}

fn daxpy_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Daxpy { alpha, x, y } = c.req else {
        unreachable!("daxpy kernel planned for {}", c.req.routine())
    };
    let mut y = y.clone();
    let ft = dmr::daxpy_ft(*alpha, x, &mut y, c.inj_elem());
    (BlasResult::Vector(y), ft)
}

fn daxpy_simd(c: &ExecCtx) -> KernelOut {
    daxpy_with(c, simd::daxpy)
}

/// Reduction DMR injects per chunk: clamp the strike to the chunk range.
fn chunk_inj(c: &ExecCtx, len: usize) -> Option<(usize, f64)> {
    c.inj_elem().map(|(i, d)| (i % (len / 8).max(1), d))
}

fn ddot_with(c: &ExecCtx, k: fn(&[f64], &[f64]) -> f64) -> KernelOut {
    let BlasRequest::Ddot { x, y } = c.req else {
        unreachable!("ddot kernel planned for {}", c.req.routine())
    };
    (BlasResult::Scalar(k(x, y)), FtReport::none())
}

fn ddot_naive(c: &ExecCtx) -> KernelOut {
    ddot_with(c, naive::ddot)
}

fn ddot_blocked(c: &ExecCtx) -> KernelOut {
    ddot_with(c, blocked::ddot)
}

fn ddot_tuned(c: &ExecCtx) -> KernelOut {
    ddot_with(c, level1::ddot)
}

fn ddot_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Ddot { x, y } = c.req else {
        unreachable!("ddot kernel planned for {}", c.req.routine())
    };
    let (d, ft) = dmr::ddot_ft(x, y, chunk_inj(c, x.len()));
    (BlasResult::Scalar(d), ft)
}

fn ddot_simd(c: &ExecCtx) -> KernelOut {
    ddot_with(c, simd::ddot)
}

fn dnrm2_with(c: &ExecCtx, k: fn(&[f64]) -> f64) -> KernelOut {
    let BlasRequest::Dnrm2 { x } = c.req else {
        unreachable!("dnrm2 kernel planned for {}", c.req.routine())
    };
    (BlasResult::Scalar(k(x)), FtReport::none())
}

fn dnrm2_naive(c: &ExecCtx) -> KernelOut {
    dnrm2_with(c, naive::dnrm2)
}

fn dnrm2_blocked(c: &ExecCtx) -> KernelOut {
    dnrm2_with(c, blocked::dnrm2)
}

fn dnrm2_tuned(c: &ExecCtx) -> KernelOut {
    dnrm2_with(c, level1::dnrm2)
}

fn dnrm2_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dnrm2 { x } = c.req else {
        unreachable!("dnrm2 kernel planned for {}", c.req.routine())
    };
    let (d, ft) = dmr::dnrm2_ft(x, chunk_inj(c, x.len()));
    (BlasResult::Scalar(d), ft)
}

fn dnrm2_simd(c: &ExecCtx) -> KernelOut {
    dnrm2_with(c, simd::dnrm2)
}

fn dasum_with(c: &ExecCtx, k: fn(&[f64]) -> f64) -> KernelOut {
    let BlasRequest::Dasum { x } = c.req else {
        unreachable!("dasum kernel planned for {}", c.req.routine())
    };
    (BlasResult::Scalar(k(x)), FtReport::none())
}

fn dasum_naive(c: &ExecCtx) -> KernelOut {
    dasum_with(c, naive::dasum)
}

fn dasum_tuned(c: &ExecCtx) -> KernelOut {
    dasum_with(c, level1::dasum)
}

fn dasum_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dasum { x } = c.req else {
        unreachable!("dasum kernel planned for {}", c.req.routine())
    };
    let (d, ft) = dmr::dasum_ft(x, chunk_inj(c, x.len()));
    (BlasResult::Scalar(d), ft)
}

fn drot_with(c: &ExecCtx,
             k: fn(&mut [f64], &mut [f64], f64, f64)) -> KernelOut {
    let BlasRequest::Drot { x, y, c: co, s } = c.req else {
        unreachable!("drot kernel planned for {}", c.req.routine())
    };
    let (mut x, mut y) = (x.clone(), y.clone());
    k(&mut x, &mut y, *co, *s);
    let mut out = x;
    out.extend_from_slice(&y);
    (BlasResult::Vector(out), FtReport::none())
}

fn drot_naive(c: &ExecCtx) -> KernelOut {
    drot_with(c, naive::drot)
}

fn drot_tuned(c: &ExecCtx) -> KernelOut {
    drot_with(c, level1::drot)
}

fn drot_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Drot { x, y, c: co, s } = c.req else {
        unreachable!("drot kernel planned for {}", c.req.routine())
    };
    let (mut x, mut y) = (x.clone(), y.clone());
    let ft = dmr::drot_ft(&mut x, &mut y, *co, *s, c.inj_elem());
    let mut out = x;
    out.extend_from_slice(&y);
    (BlasResult::Vector(out), ft)
}

fn drotm_with(c: &ExecCtx,
              k: fn(&mut [f64], &mut [f64], &[f64; 5])) -> KernelOut {
    let BlasRequest::Drotm { x, y, param } = c.req else {
        unreachable!("drotm kernel planned for {}", c.req.routine())
    };
    let (mut x, mut y) = (x.clone(), y.clone());
    k(&mut x, &mut y, param);
    let mut out = x;
    out.extend_from_slice(&y);
    (BlasResult::Vector(out), FtReport::none())
}

fn drotm_naive(c: &ExecCtx) -> KernelOut {
    drotm_with(c, naive::drotm)
}

fn drotm_tuned(c: &ExecCtx) -> KernelOut {
    drotm_with(c, level1::drotm)
}

fn drotm_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Drotm { x, y, param } = c.req else {
        unreachable!("drotm kernel planned for {}", c.req.routine())
    };
    let (mut x, mut y) = (x.clone(), y.clone());
    let ft = dmr::drotm_ft(&mut x, &mut y, param, c.inj_elem());
    let mut out = x;
    out.extend_from_slice(&y);
    (BlasResult::Vector(out), ft)
}

fn idamax_with(c: &ExecCtx, k: fn(&[f64]) -> usize) -> KernelOut {
    let BlasRequest::Idamax { x } = c.req else {
        unreachable!("idamax kernel planned for {}", c.req.routine())
    };
    (BlasResult::Scalar(k(x) as f64), FtReport::none())
}

fn idamax_naive(c: &ExecCtx) -> KernelOut {
    idamax_with(c, naive::idamax)
}

fn idamax_tuned(c: &ExecCtx) -> KernelOut {
    idamax_with(c, level1::idamax)
}

fn idamax_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Idamax { x } = c.req else {
        unreachable!("idamax kernel planned for {}", c.req.routine())
    };
    let (i, ft) = dmr::idamax_ft(x, c.inj_elem());
    (BlasResult::Scalar(i as f64), ft)
}

// ------------------------------------------------------- Level 2 kernels

fn dgemv_with(c: &ExecCtx,
              k: fn(usize, usize, f64, &[f64], &[f64], f64, &mut [f64]))
              -> KernelOut {
    let BlasRequest::Dgemv { alpha, a, x, beta, y } = c.req else {
        unreachable!("dgemv kernel planned for {}", c.req.routine())
    };
    let mut y = y.clone();
    k(a.rows, a.cols, *alpha, &a.data, x, *beta, &mut y);
    (BlasResult::Vector(y), FtReport::none())
}

fn dgemv_naive(c: &ExecCtx) -> KernelOut {
    dgemv_with(c, naive::dgemv)
}

fn dgemv_blocked(c: &ExecCtx) -> KernelOut {
    dgemv_with(c, blocked::dgemv)
}

fn dgemv_tuned(c: &ExecCtx) -> KernelOut {
    dgemv_with(c, level2::dgemv)
}

fn dgemv_simd(c: &ExecCtx) -> KernelOut {
    dgemv_with(c, simd::dgemv)
}

fn dgemv_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemv { alpha, a, x, beta, y } = c.req else {
        unreachable!("dgemv kernel planned for {}", c.req.routine())
    };
    let mut y = y.clone();
    let ft = dmr::dgemv_ft(a.rows, a.cols, *alpha, &a.data, x, *beta, &mut y,
                           c.inj_elem());
    (BlasResult::Vector(y), ft)
}

fn dtrsv_with(c: &ExecCtx, k: fn(usize, &[f64], &mut [f64])) -> KernelOut {
    let BlasRequest::Dtrsv { a, b } = c.req else {
        unreachable!("dtrsv kernel planned for {}", c.req.routine())
    };
    let mut x = b.clone();
    k(a.rows, &a.data, &mut x);
    (BlasResult::Vector(x), FtReport::none())
}

fn dtrsv_naive(c: &ExecCtx) -> KernelOut {
    dtrsv_with(c, naive::dtrsv_lower)
}

fn dtrsv_blocked(c: &ExecCtx) -> KernelOut {
    dtrsv_with(c, blocked::dtrsv_lower)
}

fn dtrsv_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrsv { a, b } = c.req else {
        unreachable!("dtrsv kernel planned for {}", c.req.routine())
    };
    let mut x = b.clone();
    level2::dtrsv_lower(a.rows, &a.data, &mut x, c.profile.trsv_panel);
    (BlasResult::Vector(x), FtReport::none())
}

fn dtrsv_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrsv { a, b } = c.req else {
        unreachable!("dtrsv kernel planned for {}", c.req.routine())
    };
    let mut x = b.clone();
    let n = a.rows;
    // panel step 0 has no gemv update: clamp strikes to >= 1
    let nsteps = n.div_ceil(c.profile.trsv_panel);
    let inj = c.fault().map(|f| {
        let s = if nsteps > 1 { 1 + f.step % (nsteps - 1) } else { 0 };
        (s, f.delta)
    });
    let ft = dmr::dtrsv_ft(n, &a.data, &mut x, c.profile.trsv_panel, inj);
    (BlasResult::Vector(x), ft)
}

fn dger_with(c: &ExecCtx,
             k: fn(usize, usize, f64, &[f64], &[f64], &mut [f64]))
             -> KernelOut {
    let BlasRequest::Dger { alpha, x, y, a } = c.req else {
        unreachable!("dger kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, a.cols);
    let mut ad = a.data.clone();
    k(m, n, *alpha, x, y, &mut ad);
    (BlasResult::Matrix(Matrix::from_vec(m, n, ad)), FtReport::none())
}

fn dger_naive(c: &ExecCtx) -> KernelOut {
    dger_with(c, naive::dger)
}

fn dger_tuned(c: &ExecCtx) -> KernelOut {
    dger_with(c, level2::dger)
}

fn dger_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dger { alpha, x, y, a } = c.req else {
        unreachable!("dger kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, a.cols);
    let mut ad = a.data.clone();
    let inj = c.inj_elem().map(|(i, d)| (i % (m * n), d));
    let ft = dmr::dger_ft(m, n, *alpha, x, y, &mut ad, inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, ad)), ft)
}

fn dsymv_with(c: &ExecCtx,
              k: fn(usize, f64, &[f64], &[f64], f64, &mut [f64]))
              -> KernelOut {
    let BlasRequest::Dsymv { alpha, a, x, beta, y } = c.req else {
        unreachable!("dsymv kernel planned for {}", c.req.routine())
    };
    let mut y = y.clone();
    k(a.rows, *alpha, &a.data, x, *beta, &mut y);
    (BlasResult::Vector(y), FtReport::none())
}

fn dsymv_naive(c: &ExecCtx) -> KernelOut {
    dsymv_with(c, naive::dsymv_lower)
}

fn dsymv_tuned(c: &ExecCtx) -> KernelOut {
    dsymv_with(c, level2::dsymv_lower)
}

fn dsymv_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsymv { alpha, a, x, beta, y } = c.req else {
        unreachable!("dsymv kernel planned for {}", c.req.routine())
    };
    let n = a.rows;
    let mut y = y.clone();
    let inj = c.inj_elem().map(|(i, d)| (i % n, d));
    let ft = dmr::dsymv_ft(n, *alpha, &a.data, x, *beta, &mut y, inj);
    (BlasResult::Vector(y), ft)
}

fn dtrmv_with(c: &ExecCtx, k: fn(usize, &[f64], &mut [f64])) -> KernelOut {
    let BlasRequest::Dtrmv { a, x } = c.req else {
        unreachable!("dtrmv kernel planned for {}", c.req.routine())
    };
    let mut x = x.clone();
    k(a.rows, &a.data, &mut x);
    (BlasResult::Vector(x), FtReport::none())
}

fn dtrmv_naive(c: &ExecCtx) -> KernelOut {
    dtrmv_with(c, naive::dtrmv_lower)
}

fn dtrmv_tuned(c: &ExecCtx) -> KernelOut {
    dtrmv_with(c, level2::dtrmv_lower)
}

fn dtrmv_dmr(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrmv { a, x } = c.req else {
        unreachable!("dtrmv kernel planned for {}", c.req.routine())
    };
    let n = a.rows;
    let mut x = x.clone();
    let inj = c.inj_elem().map(|(i, d)| (i % n, d));
    let ft = dmr::dtrmv_ft(n, &a.data, &mut x, inj);
    (BlasResult::Vector(x), ft)
}

// ------------------------------------------------------- Level 3 kernels

/// Translate planned faults into rank-K_C strikes for an m×n ABFT frame.
/// Shared with the router's batch-fusion path, which arms one fault per
/// batch item through the same mapping.
pub(crate) fn strikes(faults: &[Fault], nsteps: usize, m: usize, n: usize)
                      -> Vec<Strike> {
    let nsteps = nsteps.max(1);
    faults
        .iter()
        .map(|f| (f.step % nsteps, f.i % m, f.j % n, f.delta))
        .collect()
}

fn dgemm_with(c: &ExecCtx,
              k: fn(usize, usize, usize, f64, &[f64], &[f64], f64, &mut [f64]))
              -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    k(m, n, kk, *alpha, &a.data, &b.data, *beta, &mut cd);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dgemm_naive(c: &ExecCtx) -> KernelOut {
    dgemm_with(c, naive::dgemm)
}

fn dgemm_blocked(c: &ExecCtx) -> KernelOut {
    dgemm_with(c, blocked::dgemm)
}

fn dgemm_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    level3::dgemm(m, n, kk, *alpha, &a.data, &b.data, *beta, &mut cd,
                  &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dgemm_tuned_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    parallel::dgemm_mt(m, n, kk, *alpha, &a.data, &b.data, *beta, &mut cd,
                       &c.profile.gemm, c.threads);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dgemm_fused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let ft = abft_fused::dgemm_abft_fused(m, n, kk, *alpha, &a.data, &b.data,
                                          *beta, &mut cd, params, &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dgemm_fused_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let ft = parallel::dgemm_abft_fused_mt(m, n, kk, *alpha, &a.data, &b.data,
                                           *beta, &mut cd, params, c.threads,
                                           &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dgemm_simd(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    simd::dgemm(m, n, kk, *alpha, &a.data, &b.data, *beta, &mut cd,
                &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dgemm_simd_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    parallel::dgemm_simd_mt(m, n, kk, *alpha, &a.data, &b.data, *beta,
                            &mut cd, &c.profile.gemm, c.threads);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dgemm_fused_simd(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let ft = simd::dgemm_abft_fused(m, n, kk, *alpha, &a.data, &b.data,
                                    *beta, &mut cd, params, &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dgemm_fused_simd_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let ft = parallel::dgemm_abft_fused_simd_mt(m, n, kk, *alpha, &a.data,
                                                &b.data, *beta, &mut cd,
                                                params, c.threads, &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

/// Run one dgemm request through a batched driver as a batch of one —
/// the uniform [`KernelFn`] face of the `dgemm/batched*` entries. The
/// server's fusion path calls the drivers directly with the whole
/// drained batch; this entry keeps the registry contract (CLI `run`,
/// bench harness, campaign arming) uniform.
fn dgemm_batched_with(
    c: &ExecCtx,
    driver: fn(&mut [batched::GemmItem<'_>], &GemmParams, usize)
               -> Vec<crate::ft::FtReport>,
) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let mut items = vec![batched::GemmItem {
        m,
        n,
        k: kk,
        alpha: *alpha,
        beta: *beta,
        a: &a.data[..],
        b: &b.data[..],
        c: &mut cd[..],
        inject: inj,
    }];
    let reps = driver(&mut items, params, c.threads);
    drop(items);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), reps[0])
}

fn dgemm_batched_one(c: &ExecCtx) -> KernelOut {
    dgemm_batched_with(c, |items, params, threads| {
        batched::dgemm_batched(items, params, threads);
        vec![FtReport::none(); items.len().max(1)]
    })
}

fn dgemm_batched_simd_one(c: &ExecCtx) -> KernelOut {
    dgemm_batched_with(c, |items, params, threads| {
        batched::dgemm_batched_simd(items, params, threads);
        vec![FtReport::none(); items.len().max(1)]
    })
}

fn dgemm_batched_fused_one(c: &ExecCtx) -> KernelOut {
    dgemm_batched_with(c, batched::dgemm_batched_abft_fused_simd)
}

fn dgemm_unfused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    // the §5.1 baseline scales α into A and β into C up front, then
    // wraps the unprotected tuned GEMM in separate checksum passes
    let ascaled: Vec<f64> = a.data.iter().map(|v| alpha * v).collect();
    let mut cd = c0.data.clone();
    for v in cd.iter_mut() {
        *v *= beta;
    }
    let ft = abft::dgemm_abft_unfused(
        m, n, kk, params.kc, &ascaled, &b.data, &mut cd,
        |ap, bp, cc, mm, kp| {
            level3::dgemm(mm, n, kp, 1.0, ap, bp, 1.0, cc, params)
        },
        inj.first().copied(),
    );
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dgemm_weighted(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, kk.div_ceil(params.kc), m, n);
    // the weighted frame is specialized to C := A·B: fold α into A and
    // apply the β accumulation after the checksummed multiply
    let ascaled: Vec<f64> = a.data.iter().map(|v| alpha * v).collect();
    let mut t = vec![0.0; m * n];
    let ft = abft_weighted::dgemm_abft_weighted(m, n, kk, &ascaled, &b.data,
                                                &mut t, params, &inj);
    let mut cd = c0.data.clone();
    for (cv, tv) in cd.iter_mut().zip(&t) {
        *cv = beta * *cv + tv;
    }
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

/// Thread-block tile edges of the simulated GPU tiers (the WMMA
/// fragment multiples of arXiv 2305.01024's kernel hierarchy).
const GPUSIM_TILE_SMALL: usize = 16;
const GPUSIM_TILE_LARGE: usize = 32;

fn dgemm_gpusim_with(c: &ExecCtx, tile: usize, protected: bool) -> KernelOut {
    let BlasRequest::Dgemm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dgemm kernel planned for {}", c.req.routine())
    };
    let (m, n, kk) = (a.rows, b.cols, a.cols);
    let mut cd = c0.data.clone();
    if protected {
        let inj = strikes(c.faults, kk.div_ceil(tile), m, n);
        let ft = gpu_sim::dgemm_gpusim_abft(m, n, kk, *alpha, &a.data,
                                            &b.data, *beta, &mut cd, tile,
                                            &inj);
        (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
    } else {
        gpu_sim::dgemm_gpusim(m, n, kk, *alpha, &a.data, &b.data, *beta,
                              &mut cd, tile);
        (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
    }
}

fn dgemm_gpusim_ori(c: &ExecCtx) -> KernelOut {
    dgemm_gpusim_with(c, GPUSIM_TILE_LARGE, false)
}

fn dgemm_gpusim_wmma16(c: &ExecCtx) -> KernelOut {
    dgemm_gpusim_with(c, GPUSIM_TILE_SMALL, true)
}

fn dgemm_gpusim_wmma32(c: &ExecCtx) -> KernelOut {
    dgemm_gpusim_with(c, GPUSIM_TILE_LARGE, true)
}

fn dsymm_with(c: &ExecCtx,
              k: fn(usize, usize, f64, &[f64], &[f64], f64, &mut [f64]))
              -> KernelOut {
    let BlasRequest::Dsymm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dsymm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut cd = c0.data.clone();
    k(m, n, *alpha, &a.data, &b.data, *beta, &mut cd);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dsymm_naive(c: &ExecCtx) -> KernelOut {
    dsymm_with(c, naive::dsymm_lower)
}

fn dsymm_blocked(c: &ExecCtx) -> KernelOut {
    dsymm_with(c, blocked::dsymm_lower)
}

fn dsymm_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsymm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dsymm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut cd = c0.data.clone();
    level3::dsymm_lower(m, n, *alpha, &a.data, &b.data, *beta, &mut cd,
                        &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dsymm_tuned_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsymm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dsymm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut cd = c0.data.clone();
    parallel::dsymm_lower_mt(m, n, *alpha, &a.data, &b.data, *beta, &mut cd,
                             &c.profile.gemm, c.threads);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), FtReport::none())
}

fn dsymm_fused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsymm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dsymm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, m.div_ceil(params.kc), m, n);
    let mut cd = c0.data.clone();
    let ft = abft_fused::dsymm_abft_fused(m, n, *alpha, &a.data, &b.data,
                                          *beta, &mut cd, params, &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dsymm_unfused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsymm { alpha, a, b, beta, c: c0 } = c.req else {
        unreachable!("dsymm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, m.div_ceil(params.kc), m, n);
    // symmetrize (packing analog) then unfused-ABFT GEMM
    let mut full = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            let v = alpha * a.data[i * m + j];
            full[i * m + j] = v;
            full[j * m + i] = v;
        }
    }
    let mut cd = c0.data.clone();
    for v in cd.iter_mut() {
        *v *= beta;
    }
    let ft = abft::dgemm_abft_unfused(
        m, n, m, params.kc, &full, &b.data, &mut cd,
        |ap, bp, cc, mm, kp| {
            level3::dgemm(mm, n, kp, 1.0, ap, bp, 1.0, cc, params)
        },
        inj.first().copied(),
    );
    (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
}

fn dtrmm_with(c: &ExecCtx,
              k: fn(usize, usize, f64, &[f64], &mut [f64])) -> KernelOut {
    let BlasRequest::Dtrmm { alpha, a, b } = c.req else {
        unreachable!("dtrmm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    k(m, n, *alpha, &a.data, &mut bd);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrmm_naive(c: &ExecCtx) -> KernelOut {
    dtrmm_with(c, naive::dtrmm_lower)
}

fn dtrmm_blocked(c: &ExecCtx) -> KernelOut {
    dtrmm_with(c, blocked::dtrmm_lower)
}

fn dtrmm_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrmm { alpha, a, b } = c.req else {
        unreachable!("dtrmm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    level3::dtrmm_lower(m, n, *alpha, &a.data, &mut bd, &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrmm_tuned_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrmm { alpha, a, b } = c.req else {
        unreachable!("dtrmm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    parallel::dtrmm_lower_mt(m, n, *alpha, &a.data, &mut bd, &c.profile.gemm,
                             c.threads);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrmm_fused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrmm { alpha, a, b } = c.req else {
        unreachable!("dtrmm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, m.div_ceil(params.kc), m, n);
    let mut bd = b.data.clone();
    let ft = abft_fused::dtrmm_abft_fused(m, n, *alpha, &a.data, &mut bd,
                                          params, &inj);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), ft)
}

fn dtrmm_unfused(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrmm { alpha, a, b } = c.req else {
        unreachable!("dtrmm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let params = &c.profile.gemm;
    let inj = strikes(c.faults, m.div_ceil(params.kc), m, n);
    let mut low = vec![0.0; m * m];
    for i in 0..m {
        for j in 0..=i {
            low[i * m + j] = alpha * a.data[i * m + j];
        }
    }
    let mut bd = b.data.clone();
    let b0 = bd.clone();
    for v in bd.iter_mut() {
        *v = 0.0;
    }
    let ft = abft::dgemm_abft_unfused(
        m, n, m, params.kc, &low, &b0, &mut bd,
        |ap, bp, cc, mm, kp| {
            level3::dgemm(mm, n, kp, 1.0, ap, bp, 1.0, cc, params)
        },
        inj.first().copied(),
    );
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), ft)
}

fn dtrsm_with(c: &ExecCtx, k: fn(usize, usize, &[f64], &mut [f64]))
              -> KernelOut {
    let BlasRequest::Dtrsm { a, b } = c.req else {
        unreachable!("dtrsm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    k(m, n, &a.data, &mut bd);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrsm_naive(c: &ExecCtx) -> KernelOut {
    dtrsm_with(c, naive::dtrsm_llnn)
}

fn dtrsm_blocked(c: &ExecCtx) -> KernelOut {
    dtrsm_with(c, blocked::dtrsm_llnn)
}

fn dtrsm_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrsm { a, b } = c.req else {
        unreachable!("dtrsm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    level3::dtrsm_llnn(m, n, &a.data, &mut bd, c.profile.trsm_panel,
                       &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrsm_tuned_mt(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrsm { a, b } = c.req else {
        unreachable!("dtrsm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    parallel::dtrsm_llnn_mt(m, n, &a.data, &mut bd, c.profile.trsm_panel,
                            &c.profile.gemm, c.threads);
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), FtReport::none())
}

fn dtrsm_ft(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dtrsm { a, b } = c.req else {
        unreachable!("dtrsm kernel planned for {}", c.req.routine())
    };
    let (m, n) = (a.rows, b.cols);
    let mut bd = b.data.clone();
    let ft = dtrsm_ft_native(m, n, &a.data, &mut bd, c.profile.trsm_panel,
                             &c.profile.gemm, c.fault());
    (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), ft)
}

fn dsyrk_with(c: &ExecCtx,
              k: fn(usize, usize, f64, &[f64], f64, &mut [f64])) -> KernelOut {
    let BlasRequest::Dsyrk { alpha, a, beta, c: c0 } = c.req else {
        unreachable!("dsyrk kernel planned for {}", c.req.routine())
    };
    let (n, kk) = (a.rows, a.cols);
    let mut cd = c0.data.clone();
    k(n, kk, *alpha, &a.data, *beta, &mut cd);
    (BlasResult::Matrix(Matrix::from_vec(n, n, cd)), FtReport::none())
}

fn dsyrk_naive(c: &ExecCtx) -> KernelOut {
    dsyrk_with(c, naive::dsyrk_lower)
}

fn dsyrk_tuned(c: &ExecCtx) -> KernelOut {
    let BlasRequest::Dsyrk { alpha, a, beta, c: c0 } = c.req else {
        unreachable!("dsyrk kernel planned for {}", c.req.routine())
    };
    let (n, kk) = (a.rows, a.cols);
    let mut cd = c0.data.clone();
    level3::dsyrk_lower(n, kk, *alpha, &a.data, *beta, &mut cd,
                        &c.profile.gemm);
    (BlasResult::Matrix(Matrix::from_vec(n, n, cd)), FtReport::none())
}

/// Native FT-TRSM: each panel's GEMM update is checksum-verified and
/// corrected online; diagonal solves are checksum-verified with a DMR
/// re-solve on the cold path (paper's FT-TRSM hybrid).
fn dtrsm_ft_native(m: usize, n: usize, a: &[f64], b: &mut [f64], panel: usize,
                   params: &GemmParams, fault: Option<Fault>) -> FtReport {
    let mut report = FtReport::none();
    let nsteps = m.div_ceil(panel);
    // step 0 has no off-diagonal panel; clamp planned strikes to [1, nsteps)
    let fault = fault.map(|mut f| {
        if nsteps > 1 {
            f.step = 1 + f.step % (nsteps - 1);
        } else {
            f.step = 0;
        }
        f.i %= panel; // panel-local strike position
        f.j %= n;
        f
    });
    let mut i = 0;
    let mut step = 0;
    while i < m {
        let pb = panel.min(m - i);
        if i > 0 {
            let mut apanel = vec![0.0; pb * i];
            for r in 0..pb {
                apanel[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * m..(i + r) * m + i]);
            }
            let (xdone, btail) = b.split_at_mut(i * n);
            let bblk = &mut btail[..pb * n];
            // B_block -= A_panel · X_done, in place through the fused-ABFT
            // GEMM frame (paper §5.2): the checksum traffic shares the
            // packing loads and the β=1 accumulation seeds the checksums
            // from B_block itself — no staging buffer, no extra subtract
            // pass over memory.
            let usteps = i.div_ceil(params.kc);
            let inj: Vec<_> = fault
                .filter(|f| f.step == step)
                // clamp the strike into this step's pb×n update (the last
                // panel can be narrower than the configured width)
                .map(|f| (f.step % usteps, f.i % pb, f.j % n, f.delta))
                .into_iter()
                .collect();
            report.merge(abft_fused::dgemm_abft_fused(
                pb, n, i, -1.0, &apanel, &xdone[..i * n], 1.0, bblk, params,
                &inj));
        }
        // Checksum-protected diagonal solve (the ABFT identity for a
        // triangular solve T·X = B: with w = Tᵀ·e, any computed X must
        // satisfy wᵀ·X = eᵀ·B column-wise). Verification costs one
        // O(pb·n) pass instead of duplicating the O(pb²·n/2) solve — the
        // L3 analog of the paper's "cast the cost into checksums, not
        // duplication" argument. A flagged column is re-solved twice on
        // the cold path (third computation + consensus).
        let binit: Vec<f64> = b[i * n..(i + pb) * n].to_vec();
        // column sums of the incoming rhs (eᵀ·B) — fused with the copy
        let mut sb = vec![0.0; n];
        for r in 0..pb {
            let row = &binit[r * n..(r + 1) * n];
            for (s, v) in sb.iter_mut().zip(row) {
                *s += v;
            }
        }
        // w = Tᵀ·e: column sums of the pb×pb lower-triangular block
        let mut w = vec![0.0; pb];
        let mut max_t = 0.0f64;
        for r in 0..pb {
            let gi = i + r;
            for (p, wv) in w.iter_mut().enumerate().take(r + 1) {
                let t = a[gi * m + i + p];
                *wv += t;
                max_t = max_t.max(t.abs());
            }
        }
        // the (single) vectorized forward solve
        {
            let (done, cur) = b.split_at_mut(i * n);
            let _ = done;
            let blk = &mut cur[..pb * n];
            for r in 0..pb {
                let gi = i + r;
                let (solved, rest) = blk.split_at_mut(r * n);
                let row = &mut rest[..n];
                for p in 0..r {
                    let aip = a[gi * m + i + p];
                    let prow = &solved[p * n..(p + 1) * n];
                    for (o, s) in row.iter_mut().zip(prow) {
                        *o -= aip * s;
                    }
                }
                let rd = 1.0 / a[gi * m + gi];
                for o in row.iter_mut() {
                    *o *= rd;
                }
            }
        }
        // single-panel matrices have no GEMM update to strike — the
        // planned fault lands on the diagonal solve output instead
        // (before verification reads it), exercising the checksum path
        if let Some(f) = fault {
            if f.step == step && i == 0 && m <= panel {
                b[(f.i % pb) * n + f.j % n] += f.delta;
            }
        }
        // verify wᵀ·X against eᵀ·B per column
        let x = &b[i * n..(i + pb) * n];
        let mut sx = vec![0.0; n];
        let mut max_x = 0.0f64;
        for r in 0..pb {
            let wr = w[r];
            let row = &x[r * n..(r + 1) * n];
            for (s, v) in sx.iter_mut().zip(row) {
                *s += wr * v;
            }
        }
        for v in x {
            max_x = max_x.max(v.abs());
        }
        let tol = crate::ft::abft::round_off_threshold(
            max_t.max(1.0) * max_x.max(1.0), pb, n);
        let bad: Vec<usize> = (0..n)
            .filter(|&cx| (sx[cx] - sb[cx]).abs() > tol)
            .collect();
        if !bad.is_empty() {
            // cold path: re-solve the flagged columns twice + consensus
            for &cx in &bad {
                let resolve = || -> Vec<f64> {
                    let mut col = vec![0.0; pb];
                    for r in 0..pb {
                        let gi = i + r;
                        let mut acc =
                            std::hint::black_box(binit[r * n + cx]);
                        for p in 0..r {
                            acc -= a[gi * m + i + p] * col[p];
                        }
                        col[r] = acc / a[gi * m + gi];
                    }
                    col
                };
                let c1 = resolve();
                let c2 = resolve();
                if c1 != c2 {
                    panic!("FT-BLAS DTRSM: diagonal re-solve disagrees — \
                            unrecoverable");
                }
                for r in 0..pb {
                    b[(i + r) * n + cx] = c1[r];
                }
            }
            report.errors_detected += 1;
            report.errors_corrected += 1;
        }
        i += pb;
        step += 1;
    }
    report
}

// ---------------------------------------------------------------- table

/// The full native kernel table. Registration order matters twice:
/// `serial_variants` reports the naive → blocked → tuned → simd ladder
/// in this order, and the planner's any-variant fallback takes the
/// first supporting entry.
static ENTRIES: &[KernelDescriptor] = &[
    // -------------------------------------------------------- Level 1
    serial("dscal/naive", "dscal", Level::L1, Impl::Naive,
           "textbook loop (LAPACK-sim)", dscal_naive),
    serial("dscal/blocked", "dscal", Level::L1, Impl::Blocked,
           "SIMD-width, unroll, NO prefetch (OpenBLAS-sim)", dscal_blocked),
    serial("dscal/tuned", "dscal", Level::L1, Impl::Tuned,
           "+prefetch (FT-BLAS Ori)", dscal_tuned),
    protected("dscal/dmr", "dscal", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "duplicated SIMD streams", dscal_dmr),
    serial("dscal/simd", "dscal", Level::L1, Impl::Simd,
           "AVX2 4-lane ×4 unroll, runtime-probed", dscal_simd),
    serial("daxpy/naive", "daxpy", Level::L1, Impl::Naive,
           "scalar loop", daxpy_naive),
    serial("daxpy/blocked", "daxpy", Level::L1, Impl::Blocked,
           "blocked loop (OpenBLAS-sim)", daxpy_blocked),
    serial("daxpy/tuned", "daxpy", Level::L1, Impl::Tuned,
           "SIMD-width, unroll, prefetch", daxpy_tuned),
    protected("daxpy/dmr", "daxpy", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "duplicated SIMD streams", daxpy_dmr),
    serial("daxpy/simd", "daxpy", Level::L1, Impl::Simd,
           "AVX2+FMA 4-lane ×4 unroll, runtime-probed", daxpy_simd),
    serial("ddot/naive", "ddot", Level::L1, Impl::Naive,
           "single accumulator", ddot_naive),
    serial("ddot/blocked", "ddot", Level::L1, Impl::Blocked,
           "single accumulator, blocked", ddot_blocked),
    serial("ddot/tuned", "ddot", Level::L1, Impl::Tuned,
           "4 accumulator chains, prefetch", ddot_tuned),
    protected("ddot/dmr", "ddot", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "per-chunk duplicated reduction", ddot_dmr),
    serial("ddot/simd", "ddot", Level::L1, Impl::Simd,
           "4 AVX2 FMA chains, runtime-probed", ddot_simd),
    serial("dnrm2/naive", "dnrm2", Level::L1, Impl::Naive,
           "scaled loop", dnrm2_naive),
    serial("dnrm2/blocked", "dnrm2", Level::L1, Impl::Blocked,
           "SSE2-width (2 lanes)", dnrm2_blocked),
    serial("dnrm2/tuned", "dnrm2", Level::L1, Impl::Tuned,
           "AVX512-width (8 lanes), prefetch", dnrm2_tuned),
    protected("dnrm2/dmr", "dnrm2", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "per-chunk duplicated reduction", dnrm2_dmr),
    serial("dnrm2/simd", "dnrm2", Level::L1, Impl::Simd,
           "4 AVX2 FMA chains + overflow fallback, runtime-probed",
           dnrm2_simd),
    serial("dasum/naive", "dasum", Level::L1, Impl::Naive,
           "textbook loop", dasum_naive),
    serial("dasum/blocked", "dasum", Level::L1, Impl::Blocked,
           "shares the tuned kernel", dasum_tuned),
    serial("dasum/tuned", "dasum", Level::L1, Impl::Tuned,
           "chunked + unrolled", dasum_tuned),
    protected("dasum/dmr", "dasum", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "per-chunk duplicated reduction", dasum_dmr),
    serial("drot/naive", "drot", Level::L1, Impl::Naive,
           "textbook loop", drot_naive),
    serial("drot/blocked", "drot", Level::L1, Impl::Blocked,
           "shares the tuned kernel", drot_tuned),
    serial("drot/tuned", "drot", Level::L1, Impl::Tuned,
           "chunked + unrolled", drot_tuned),
    protected("drot/dmr", "drot", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "duplicated rotation streams", drot_dmr),
    serial("drotm/naive", "drotm", Level::L1, Impl::Naive,
           "textbook loop", drotm_naive),
    serial("drotm/blocked", "drotm", Level::L1, Impl::Blocked,
           "shares the tuned kernel", drotm_tuned),
    serial("drotm/tuned", "drotm", Level::L1, Impl::Tuned,
           "flag-specialized, unrolled", drotm_tuned),
    protected("drotm/dmr", "drotm", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "duplicated rotation streams", drotm_dmr),
    serial("idamax/naive", "idamax", Level::L1, Impl::Naive,
           "textbook scan", idamax_naive),
    serial("idamax/blocked", "idamax", Level::L1, Impl::Blocked,
           "shares the tuned kernel", idamax_tuned),
    serial("idamax/tuned", "idamax", Level::L1, Impl::Tuned,
           "chunked scan", idamax_tuned),
    protected("idamax/dmr", "idamax", Level::L1, Scheme::Dmr, PROTECTED_ALL,
              "duplicated scan", idamax_dmr),
    // -------------------------------------------------------- Level 2
    serial("dgemv/naive", "dgemv", Level::L2, Impl::Naive,
           "textbook loops", dgemv_naive),
    serial("dgemv/blocked", "dgemv", Level::L2, Impl::Blocked,
           "cache-blocked A (OpenBLAS-sim)", dgemv_blocked),
    serial("dgemv/tuned", "dgemv", Level::L2, Impl::Tuned,
           "Ri=4 register reuse, streaming A", dgemv_tuned),
    protected("dgemv/dmr", "dgemv", Level::L2, Scheme::Dmr, PROTECTED_ALL,
              "duplicated row streams", dgemv_dmr),
    serial("dgemv/simd", "dgemv", Level::L2, Impl::Simd,
           "row-dot with 4 AVX2 FMA chains, runtime-probed", dgemv_simd),
    serial("dtrsv/naive", "dtrsv", Level::L2, Impl::Naive,
           "textbook forward solve", dtrsv_naive),
    serial("dtrsv/blocked", "dtrsv", Level::L2, Impl::Blocked,
           "B=64 panels (OpenBLAS default)", dtrsv_blocked),
    serial("dtrsv/tuned", "dtrsv", Level::L2, Impl::Tuned,
           "B=4 panels (paper's choice)", dtrsv_tuned),
    protected("dtrsv/dmr", "dtrsv", Level::L2, Scheme::Dmr, PROTECTED_ALL,
              "DMR panel solves + gemv updates", dtrsv_dmr),
    serial("dger/naive", "dger", Level::L2, Impl::Naive,
           "textbook loops", dger_naive),
    serial("dger/blocked", "dger", Level::L2, Impl::Blocked,
           "shares the tuned kernel", dger_tuned),
    serial("dger/tuned", "dger", Level::L2, Impl::Tuned,
           "unrolled rank-1 update", dger_tuned),
    protected("dger/dmr", "dger", Level::L2, Scheme::Dmr, PROTECTED_ALL,
              "duplicated update streams", dger_dmr),
    serial("dsymv/naive", "dsymv", Level::L2, Impl::Naive,
           "textbook loops", dsymv_naive),
    serial("dsymv/blocked", "dsymv", Level::L2, Impl::Blocked,
           "shares the tuned kernel", dsymv_tuned),
    serial("dsymv/tuned", "dsymv", Level::L2, Impl::Tuned,
           "symmetric register reuse", dsymv_tuned),
    protected("dsymv/dmr", "dsymv", Level::L2, Scheme::Dmr, PROTECTED_ALL,
              "duplicated row streams", dsymv_dmr),
    serial("dtrmv/naive", "dtrmv", Level::L2, Impl::Naive,
           "textbook loops", dtrmv_naive),
    serial("dtrmv/blocked", "dtrmv", Level::L2, Impl::Blocked,
           "shares the tuned kernel", dtrmv_tuned),
    serial("dtrmv/tuned", "dtrmv", Level::L2, Impl::Tuned,
           "triangular register reuse", dtrmv_tuned),
    protected("dtrmv/dmr", "dtrmv", Level::L2, Scheme::Dmr, PROTECTED_ALL,
              "duplicated row streams", dtrmv_dmr),
    // -------------------------------------------------------- Level 3
    serial("dgemm/naive", "dgemm", Level::L3, Impl::Naive,
           "textbook triple loop", dgemm_naive),
    serial("dgemm/blocked", "dgemm", Level::L3, Impl::Blocked,
           "default-parameter blocking (OpenBLAS-sim)", dgemm_blocked),
    serial("dgemm/tuned", "dgemm", Level::L3, Impl::Tuned,
           "packed mc/nc/kc blocking, unrolled micro kernel", dgemm_tuned),
    threaded("dgemm/tuned-mt", "dgemm", Scheme::None, UNPROTECTED,
             "row-band parallel tuned GEMM", dgemm_tuned_mt),
    protected("dgemm/abft-fused", "dgemm", Level::L3, Scheme::AbftFused,
              HYBRID_ONLY, "checksums fused into packing + write-back (§5.2)",
              dgemm_fused),
    threaded("dgemm/abft-fused-mt", "dgemm", Scheme::AbftFused, HYBRID_ONLY,
             "band-local fused ABFT across threads", dgemm_fused_mt),
    protected("dgemm/abft-unfused", "dgemm", Level::L3, Scheme::AbftUnfused,
              UNFUSED_ONLY, "ABFT around a third-party GEMM (§5.1)",
              dgemm_unfused),
    protected("dgemm/abft-weighted", "dgemm", Level::L3, Scheme::AbftWeighted,
              WEIGHTED_ONLY, "weighted double-checksum encoding (§2.1)",
              dgemm_weighted),
    serial("dgemm/simd", "dgemm", Level::L3, Impl::Simd,
           "8×4 AVX2+FMA GEBP micro kernel, runtime-probed", dgemm_simd),
    threaded_simd("dgemm/simd-mt", "dgemm", Scheme::None, UNPROTECTED,
                  "row-band parallel SIMD GEBP", dgemm_simd_mt),
    protected_simd("dgemm/abft-fused-simd", "dgemm", Scheme::AbftFused,
                   HYBRID_ONLY,
                   "checksum stream fused into the 8×4 micro kernel",
                   dgemm_fused_simd),
    threaded_simd("dgemm/abft-fused-simd-mt", "dgemm", Scheme::AbftFused,
                  HYBRID_ONLY, "band-local fused ABFT on the SIMD substrate",
                  dgemm_fused_simd_mt),
    serial("dsymm/naive", "dsymm", Level::L3, Impl::Naive,
           "textbook triple loop", dsymm_naive),
    serial("dsymm/blocked", "dsymm", Level::L3, Impl::Blocked,
           "default-parameter blocking", dsymm_blocked),
    serial("dsymm/tuned", "dsymm", Level::L3, Impl::Tuned,
           "packed symmetric frame", dsymm_tuned),
    threaded("dsymm/tuned-mt", "dsymm", Scheme::None, UNPROTECTED,
             "row-band parallel symmetric frame", dsymm_tuned_mt),
    protected("dsymm/abft-fused", "dsymm", Level::L3, Scheme::AbftFused,
              HYBRID_OR_WEIGHTED, "fused checksums in the symmetric frame",
              dsymm_fused),
    protected("dsymm/abft-unfused", "dsymm", Level::L3, Scheme::AbftUnfused,
              UNFUSED_ONLY, "symmetrize + third-party ABFT", dsymm_unfused),
    serial("dtrmm/naive", "dtrmm", Level::L3, Impl::Naive,
           "textbook triple loop", dtrmm_naive),
    serial("dtrmm/blocked", "dtrmm", Level::L3, Impl::Blocked,
           "default-parameter blocking", dtrmm_blocked),
    serial("dtrmm/tuned", "dtrmm", Level::L3, Impl::Tuned,
           "packed triangular frame", dtrmm_tuned),
    threaded("dtrmm/tuned-mt", "dtrmm", Scheme::None, UNPROTECTED,
             "row-band parallel triangular frame", dtrmm_tuned_mt),
    protected("dtrmm/abft-fused", "dtrmm", Level::L3, Scheme::AbftFused,
              HYBRID_OR_WEIGHTED, "fused checksums in the triangular frame",
              dtrmm_fused),
    protected("dtrmm/abft-unfused", "dtrmm", Level::L3, Scheme::AbftUnfused,
              UNFUSED_ONLY, "lower-fill + third-party ABFT", dtrmm_unfused),
    serial("dtrsm/naive", "dtrsm", Level::L3, Impl::Naive,
           "textbook forward solve", dtrsm_naive),
    serial("dtrsm/blocked", "dtrsm", Level::L3, Impl::Blocked,
           "scalar diagonal solver (the under-optimized prototype)",
           dtrsm_blocked),
    serial("dtrsm/tuned", "dtrsm", Level::L3, Impl::Tuned,
           "reciprocal-diagonal macro kernel", dtrsm_tuned),
    threaded("dtrsm/tuned-mt", "dtrsm", Scheme::None, UNPROTECTED,
             "column-stripe parallel solve", dtrsm_tuned_mt),
    protected("dtrsm/ft", "dtrsm", Level::L3, Scheme::FtTrsm, PROTECTED_ALL,
              "panel ABFT + checksum-verified diagonal solves", dtrsm_ft),
    serial_with("dsyrk/naive", "dsyrk", Level::L3, Impl::Naive, ANY_POLICY,
                "textbook triple loop (no FT path)", dsyrk_naive),
    serial_with("dsyrk/blocked", "dsyrk", Level::L3, Impl::Blocked, ANY_POLICY,
                "shares the tuned kernel (no FT path)", dsyrk_tuned),
    serial_with("dsyrk/tuned", "dsyrk", Level::L3, Impl::Tuned, ANY_POLICY,
                "packed rank-k frame (no FT path)", dsyrk_tuned),
    // ---------------------------------------------- batch-fused kernels
    batched_kernel("dgemm/batched", Impl::Tuned, Scheme::None, UNPROTECTED,
                   "batch of small tuned GEMMs, one pooled row-band queue",
                   dgemm_batched_one),
    batched_kernel("dgemm/batched-simd", Impl::Simd, Scheme::None,
                   UNPROTECTED,
                   "batch of small SIMD GEMMs under one threading frame",
                   dgemm_batched_simd_one),
    batched_kernel("dgemm/batched-abft-fused-simd", Impl::Simd,
                   Scheme::AbftFused, HYBRID_ONLY,
                   "batch-fused ABFT: per-item checksum state and reports",
                   dgemm_batched_fused_one),
    // ------------------------------------------ peer-backend executors
    // PJRT: one capability descriptor per AOT-compiled routine; the
    // router dispatches planned jobs to the resident executor handle.
    pjrt_peer("dscal/pjrt", "dscal", Level::L1, "AOT Pallas scal artifact"),
    pjrt_peer("daxpy/pjrt", "daxpy", Level::L1, "AOT Pallas axpy artifact"),
    pjrt_peer("ddot/pjrt", "ddot", Level::L1, "AOT Pallas dot artifact"),
    pjrt_peer("dnrm2/pjrt", "dnrm2", Level::L1, "AOT Pallas nrm2 artifact"),
    pjrt_peer("dasum/pjrt", "dasum", Level::L1, "AOT Pallas asum artifact"),
    pjrt_peer("dgemv/pjrt", "dgemv", Level::L2, "AOT Pallas gemv artifact"),
    pjrt_peer("dtrsv/pjrt", "dtrsv", Level::L2, "AOT Pallas trsv artifact"),
    pjrt_peer("dgemm/pjrt", "dgemm", Level::L3, "AOT Pallas gemm artifact"),
    pjrt_peer("dsymm/pjrt", "dsymm", Level::L3, "AOT Pallas symm artifact"),
    pjrt_peer("dtrmm/pjrt", "dtrmm", Level::L3, "AOT Pallas trmm artifact"),
    pjrt_peer("dtrsm/pjrt", "dtrsm", Level::L3, "AOT Pallas trsm artifact"),
    pjrt_peer("dsyrk/pjrt", "dsyrk", Level::L3, "AOT Pallas syrk artifact"),
    // Simulated GPU tiers (arXiv 2305.01024): the small-tile fused-ABFT
    // tier caps itself at the batch ceiling; selection falls through to
    // the unbounded 32-wide tier above it.
    gpu_sim_kernel("dgemm/gpusim-wmma16", Scheme::AbftFused, PROTECTED_ALL,
                   BATCH_DIM_CEILING,
                   "16-wide warp-tiled fused-ABFT tier (small dims)",
                   dgemm_gpusim_wmma16),
    gpu_sim_kernel("dgemm/gpusim-wmma32", Scheme::AbftFused, PROTECTED_ALL, 0,
                   "32-wide warp-tiled fused-ABFT tier",
                   dgemm_gpusim_wmma32),
    gpu_sim_kernel("dgemm/gpusim-ori", Scheme::None, UNPROTECTED, 0,
                   "32-wide warp-tiled unprotected tier",
                   dgemm_gpusim_ori),
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Totality: every routine serves every policy through at least one
    /// registered kernel — the planner can never come up empty.
    #[test]
    fn every_routine_serves_every_policy() {
        let reg = KernelRegistry::global();
        let routines = reg.routines();
        assert_eq!(routines.len(), 18, "routine count drifted");
        for r in routines {
            for p in FtPolicy::ALL {
                assert!(
                    reg.for_routine(r).iter().any(|e| e.supports(p)),
                    "{r} has no kernel for {}", p.name()
                );
            }
        }
    }

    /// Every routine exposes the serial naive → tuned ladder the oracle
    /// comparisons and bench figures rely on.
    #[test]
    fn every_routine_has_naive_and_tuned_serial() {
        let reg = KernelRegistry::global();
        for r in reg.routines() {
            let ladder = reg.serial_variants(r);
            assert!(ladder.iter().any(|e| e.variant == Impl::Naive),
                    "{r}: no naive serial kernel");
            assert!(ladder.iter().any(|e| e.variant == Impl::Tuned),
                    "{r}: no tuned serial kernel");
        }
    }

    /// The committed bench trajectory and the Fig. 5/6 oracles both
    /// read `serial_variants` positionally, so the ladder order is
    /// load-bearing: naive → blocked → tuned (→ simd for the routines
    /// with an AVX2 rung), deterministically, per registration order.
    #[test]
    fn serial_ladder_order_is_deterministic() {
        let reg = KernelRegistry::global();
        for r in ["dscal", "daxpy", "ddot", "dnrm2", "dgemv", "dgemm"] {
            let names: Vec<&str> =
                reg.serial_variants(r).iter().map(|e| e.name).collect();
            let want: Vec<String> = ["naive", "blocked", "tuned", "simd"]
                .iter()
                .map(|f| format!("{r}/{f}"))
                .collect();
            assert_eq!(names, want, "{r}: serial ladder drifted");
        }
        // routines without a SIMD rung keep the three-rung prefix order
        for r in reg.routines() {
            let ladder = reg.serial_variants(r);
            let mut last = None;
            for e in &ladder {
                let pos = Impl::ALL.iter().position(|v| *v == e.variant);
                assert!(pos > last, "{r}: ladder out of Impl::ALL order");
                last = pos;
            }
        }
    }

    /// Registry names are unique and follow `routine/flavor`.
    #[test]
    fn names_unique_and_well_formed() {
        let reg = KernelRegistry::global();
        let mut seen = std::collections::HashSet::new();
        for e in reg.entries() {
            assert!(seen.insert(e.name), "duplicate kernel name {}", e.name);
            assert!(e.name.starts_with(e.routine),
                    "{}: name not prefixed by routine {}", e.name, e.routine);
            assert_eq!(reg.find(e.name).unwrap().name, e.name);
        }
    }

    /// Stable ids round-trip through the table and thread costs match
    /// the descriptor's threading class.
    #[test]
    fn ids_round_trip_and_costs_follow_threading() {
        let reg = KernelRegistry::global();
        for (i, e) in reg.entries().iter().enumerate() {
            let id = reg.id_of(e).expect("table entry must have an id");
            assert_eq!(id, KernelId(i as u16));
            assert!(std::ptr::eq(reg.by_id(id).unwrap(), e));
            if e.threaded {
                assert_eq!(e.thread_cost(4), 4, "{}", e.name);
            } else {
                assert_eq!(e.thread_cost(4), 1, "{}", e.name);
            }
            assert_eq!(e.thread_cost(0), 1, "{}: zero grant clamps", e.name);
        }
        assert!(reg.by_id(KernelId(reg.entries().len() as u16)).is_none());
    }

    /// The fusion mapping: each batchable serial dgemm kernel resolves
    /// to exactly the batched entry sharing its variant and scheme, and
    /// everything else resolves to nothing.
    #[test]
    fn batched_siblings_map_variant_and_scheme_exactly() {
        let reg = KernelRegistry::global();
        for (serial, want) in [
            ("dgemm/tuned", "dgemm/batched"),
            ("dgemm/tuned-mt", "dgemm/batched"),
            ("dgemm/simd", "dgemm/batched-simd"),
            ("dgemm/simd-mt", "dgemm/batched-simd"),
            ("dgemm/abft-fused-simd", "dgemm/batched-abft-fused-simd"),
            ("dgemm/abft-fused-simd-mt", "dgemm/batched-abft-fused-simd"),
        ] {
            let k = reg.find(serial).unwrap();
            let b = reg.batched_sibling(k).unwrap();
            assert_eq!(b.name, want, "{serial}: wrong batched sibling");
            assert_eq!(b.scheme, k.scheme);
            assert_eq!(b.policies, k.policies,
                       "{serial}: fusion must not widen policy support");
            assert!(b.threaded, "{want}: a batch debits one pool grant");
            assert!(b.admits_batch(BATCH_DIM_CEILING));
            assert!(!b.admits_batch(BATCH_DIM_CEILING + 1),
                    "{want}: must refuse items above the ceiling");
            assert!(!b.admits_batch(0));
        }
        // scalar-fused (no scalar batched-fused entry), unfused,
        // weighted, naive/blocked, other routines, and the batched
        // entries themselves never fuse
        for name in ["dgemm/naive", "dgemm/blocked", "dgemm/abft-fused",
                     "dgemm/abft-fused-mt", "dgemm/abft-unfused",
                     "dgemm/abft-weighted", "dgemm/batched-simd",
                     "dsymm/tuned", "dsymm/tuned-mt", "ddot/tuned"] {
            let k = reg.find(name).unwrap();
            assert!(reg.batched_sibling(k).is_none(),
                    "{name}: unexpected batched sibling");
        }
        // only batched entries admit batch items at all
        assert!(!reg.find("dgemm/simd").unwrap().admits_batch(8));
    }

    /// The batched entries' uniform KernelFn face runs a batch of one:
    /// a strike through the fused entry is detected, corrected, and
    /// reported exactly like the serial fused kernel would.
    #[test]
    fn batched_entry_executes_a_batch_of_one() {
        use crate::util::matrix::allclose;
        use crate::util::rng::Rng;
        let reg = KernelRegistry::global();
        let profile = Profile::skylake_sim();
        let mut rng = Rng::new(0xB1);
        let n = 24;
        let a = Matrix::random(n, n, &mut rng);
        let b = Matrix::random(n, n, &mut rng);
        let mut want = vec![0.0; n * n];
        naive::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0, &mut want);
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a,
            b,
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        let fault = Fault { step: 0, i: 5, j: 7, delta: 4e4 };
        for (name, policy, faults, hits) in [
            ("dgemm/batched", FtPolicy::None, &[][..], 0u64),
            ("dgemm/batched-simd", FtPolicy::None, &[][..], 0),
            ("dgemm/batched-abft-fused-simd", FtPolicy::Hybrid,
             &[fault][..], 1),
        ] {
            let k = reg.find(name).unwrap();
            let ctx = ExecCtx {
                req: &req,
                profile: &profile,
                policy,
                faults,
                threads: 1,
            };
            let (res, ft) = (k.execute)(&ctx);
            assert_eq!(ft.errors_detected, hits, "{name}: detection count");
            assert_eq!(ft.errors_corrected, hits, "{name}: correction count");
            let BlasResult::Matrix(got) = res else {
                panic!("{name}: dgemm must return a matrix");
            };
            assert!(allclose(&got.data, &want, 1e-8, 1e-8),
                    "{name}: batch-of-one result wrong");
        }
    }

    /// PJRT and GPU-sim are registry-resident peers: their descriptors
    /// compete in selection but never leak into the native serial
    /// ladder or the native batch-fusion mapping.
    #[test]
    fn peer_backends_are_registry_resident() {
        let reg = KernelRegistry::global();
        let pjrt: Vec<_> = reg.entries().iter()
            .filter(|e| e.backend == Backend::Pjrt)
            .collect();
        assert_eq!(pjrt.len(), 12, "PJRT descriptor count drifted");
        for e in &pjrt {
            assert!(e.name.ends_with("/pjrt"), "{}", e.name);
            assert!(!e.threaded, "{}", e.name);
        }
        let small = reg.find("dgemm/gpusim-wmma16").unwrap();
        assert_eq!(small.max_dim, BATCH_DIM_CEILING);
        assert!(small.serves_dim(BATCH_DIM_CEILING));
        assert!(!small.serves_dim(BATCH_DIM_CEILING + 1));
        let large = reg.find("dgemm/gpusim-wmma32").unwrap();
        assert_eq!(large.max_dim, 0, "large tier must be unbounded");
        assert!(large.serves_dim(usize::MAX));
        for e in reg.entries().iter().filter(|e| !e.backend.is_native()) {
            assert!(
                !reg.serial_variants(e.routine)
                    .iter()
                    .any(|s| s.name == e.name),
                "{}: peer entry leaked into the native ladder", e.name
            );
            assert!(reg.batched_sibling(e).is_none(),
                    "{}: peer entry must not batch-fuse natively", e.name);
        }
    }

    /// The capability view is a faithful projection of the descriptor,
    /// and scheme names round-trip for the constraint vocabulary.
    #[test]
    fn capabilities_view_mirrors_descriptor() {
        let reg = KernelRegistry::global();
        for e in reg.entries() {
            let caps = e.capabilities();
            assert_eq!(caps.backend, e.backend, "{}", e.name);
            assert_eq!(caps.precision, "f64");
            assert_eq!(caps.scheme, e.scheme);
            assert_eq!(caps.threaded, e.threaded);
            assert_eq!(caps.max_dim, e.max_dim);
            assert_eq!(caps.batch_dim_ceiling, e.batch_dim_ceiling);
            assert_eq!(caps.min_mr_multiple, e.min_mr_multiple);
            assert_eq!(caps.cpu_features.is_empty(),
                       e.variant != Impl::Simd, "{}", e.name);
            assert_eq!(Scheme::by_name(e.scheme.name()), Some(e.scheme));
        }
        assert!(Scheme::by_name("warp").is_none());
    }

    /// The selection ledger counts per kernel and the shared
    /// `/backends` serializer covers every backend and every entry.
    #[test]
    fn selection_ledger_counts_and_serializes() {
        let reg = KernelRegistry::global();
        let id = reg.id_of(reg.find("dgemm/tuned").unwrap()).unwrap();
        let before = selection_count(id);
        note_selected(id);
        assert_eq!(selection_count(id), before + 1);
        // out-of-table ids are ignored, not a panic
        note_selected(KernelId(u16::MAX));
        assert_eq!(selection_count(KernelId(u16::MAX)), 0);

        let doc = backends_json(None);
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(),
                   "ftblas.backends.v1");
        let arr = doc.get("backends").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), Backend::ALL.len());
        let total: usize = arr.iter()
            .map(|b| b.get("kernels").unwrap().as_arr().unwrap().len())
            .sum();
        assert_eq!(total, reg.entries().len(),
                   "every kernel appears under exactly one backend");
        for b in arr {
            assert!(b.get("health").unwrap().as_str().is_some());
            assert!(b.get("backend").unwrap().as_str().is_some());
        }
    }

    /// Threaded kernels are L3-only, carry an MR floor, and have a
    /// serial sibling serving the same policies (the fall-back path).
    #[test]
    fn threaded_kernels_have_serial_siblings() {
        let reg = KernelRegistry::global();
        for e in reg.entries().iter().filter(|e| e.threaded) {
            assert_eq!(e.level, Level::L3, "{}: threaded non-L3", e.name);
            assert!(e.min_mr_multiple > 0, "{}: no MR floor", e.name);
            for p in e.policies {
                assert!(
                    reg.for_routine(e.routine)
                        .iter()
                        .any(|s| !s.threaded && s.supports(*p)),
                    "{}: no serial sibling for {}", e.name, p.name()
                );
            }
        }
    }
}
