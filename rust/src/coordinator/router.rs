//! Router: executes pre-resolved plans on whichever backend the planner
//! selected.
//!
//! The router's public execution surface is exactly three entries, all
//! plan-first: [`Router::execute_planned`] (one planned request),
//! [`Router::execute_batch`] (a drained same-kernel batch), and the
//! free-function [`execute_plan`] (the kernel invocation both share).
//! Planning itself lives in [`Planner`]: the router contributes its
//! server-side [`SelectionPolicy`] plus per-request backend health —
//! PJRT artifacts are shape-specialized, so an unservable request gets
//! the PJRT backend folded into the deny list before selection —
//! and the planner picks across native, PJRT, and GPU-sim descriptors
//! uniformly. Requests never fail for shape reasons under the default
//! selection: the registry-order fallback rung keeps a native kernel
//! eligible.
//!
//! Native and GPU-sim plans run in the caller's thread (the server
//! gives them a worker pool); a plan that selected a PJRT registry
//! descriptor is intercepted here and forwarded to the executor thread.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::blas::{batched, Impl};
use crate::config::Profile;
use crate::coordinator::pjrt_backend::PjrtBackend;
use crate::coordinator::plan::{ExecutionPlan, Planner, SelectionPolicy};
use crate::coordinator::registry::{
    self, ExecCtx, KernelDescriptor, Scheme,
};
use crate::coordinator::request::{
    Backend, BlasRequest, BlasResponse, BlasResult,
};
use crate::ft::injector::{CampaignConfig, Fault, InjectionCampaign};
use crate::ft::policy::FtPolicy;
use crate::ft::FtReport;
use crate::runtime::pool::{self, ComputePool};
use crate::util::matrix::Matrix;

/// The router. `pjrt` is optional so the native path works without
/// artifacts on disk (e.g. unit tests).
pub struct Router {
    /// Machine profile shared by every kernel execution.
    pub profile: Profile,
    /// The artifact backend, when available.
    pub pjrt: Option<PjrtBackend>,
    /// Server-side selection policy every request is planned under
    /// (request-scoped routing overlays merge onto it).
    pub selection: SelectionPolicy,
    /// The live cluster-wide fault-injection campaign, when one is
    /// running. It lives here — on the one object every shard already
    /// shares as `Arc<Router>` — so a shard spawned by the autoscaler
    /// mid-run inherits the campaign (and its slice of the schedule)
    /// with no extra hand-off: the workers simply ask the router.
    pub campaign: Option<Arc<InjectionCampaign>>,
    /// The cluster's persistent work-stealing compute pool, when one is
    /// attached. Like the campaign it lives on the one object every
    /// shard shares as `Arc<Router>`, so shards the autoscaler spawns
    /// mid-run submit to the same long-lived workers. The router
    /// installs it thread-locally around kernel execution
    /// ([`crate::runtime::pool::enter`]); `None` (unit tests, plain
    /// servers, `--no-pool`) leaves the frames on their scoped
    /// fork/join fallback.
    pub pool: Option<Arc<ComputePool>>,
}

impl Router {
    /// A router with no PJRT backend, preferring `prefer`'s kernels.
    pub fn native_only(profile: Profile, prefer: Backend) -> Router {
        Router {
            profile,
            pjrt: None,
            selection: SelectionPolicy::for_backend(prefer),
            campaign: None,
            pool: None,
        }
    }

    /// A router that may resolve requests to the PJRT artifact path.
    pub fn with_pjrt(profile: Profile, pjrt: PjrtBackend, prefer: Backend) -> Router {
        Router {
            profile,
            pjrt: Some(pjrt),
            selection: SelectionPolicy::for_backend(prefer),
            campaign: None,
            pool: None,
        }
    }

    /// Same router under an explicit selection policy (the CLI's
    /// `--require`/`--deny` flags land here).
    pub fn with_selection(mut self, selection: SelectionPolicy) -> Router {
        self.selection = selection;
        self
    }

    /// Same router with a live injection campaign started from `cfg`
    /// (the campaign clock starts here). Server workers arm campaign
    /// strikes on every planned execution through the `campaign()`
    /// accessor.
    pub fn with_campaign(mut self, cfg: CampaignConfig) -> Router {
        self.campaign = Some(Arc::new(InjectionCampaign::new(cfg)));
        self
    }

    /// The live campaign, if one is running.
    pub fn campaign(&self) -> Option<&InjectionCampaign> {
        self.campaign.as_deref()
    }

    /// Same router with a persistent compute pool attached. The cluster
    /// builds one pool (sized by
    /// [`crate::config::Profile::pool_worker_count`]) and attaches it
    /// here before wrapping the router in `Arc`, so every shard — and
    /// every shard spawned later — shares the same workers.
    pub fn with_pool(mut self, pool: Arc<ComputePool>) -> Router {
        self.pool = Some(pool);
        self
    }

    /// The attached compute pool, if any.
    pub fn pool(&self) -> Option<&Arc<ComputePool>> {
        self.pool.as_ref()
    }

    /// Install this router's pool (when present) on the current thread
    /// for the lifetime of the returned guard, routing the `blas`
    /// frames' band tasks to the persistent workers.
    fn enter_pool(&self) -> Option<pool::PoolGuard> {
        self.pool.as_ref().map(|p| pool::enter(p.clone()))
    }

    /// The PJRT backend's health probe, when one is attached (feeds the
    /// `/backends` report).
    pub fn pjrt_health(&self) -> Option<String> {
        self.pjrt.as_ref().map(|p| p.health())
    }

    /// The effective selection policy for one request: the router's
    /// policy with per-request backend health folded in. PJRT artifacts
    /// are shape- and policy-specialized, so a request the loaded
    /// artifact set cannot serve (or any request, when no backend is
    /// attached) sees PJRT denied — selection then falls through to the
    /// remaining preferences instead of planning an unservable backend.
    pub fn selection_for(&self, req: &BlasRequest, policy: FtPolicy)
                         -> SelectionPolicy {
        let pjrt_ok =
            self.pjrt.as_ref().is_some_and(|p| p.supports(req, policy));
        if pjrt_ok {
            self.selection.clone()
        } else {
            self.selection.clone().with_denied(Backend::Pjrt)
        }
    }

    /// The execution plan this request would get. Because the batcher
    /// groups by kernel id, one call describes a whole batch — the CLI
    /// prints it before executing, and batch-aware scheduling hooks in
    /// here.
    pub fn plan(&self, req: &BlasRequest, policy: FtPolicy)
                -> Option<ExecutionPlan> {
        Planner::new(&self.profile)
            .plan(req, &self.selection_for(req, policy), policy)
    }

    /// Execute a **pre-resolved** plan — the hot path. Workers receive
    /// admission-time plans from the
    /// [`crate::coordinator::plan::PlanCache`] and come here directly:
    /// no planner lookup, no registry scan, just the planned kernel.
    /// Plans that selected a PJRT registry descriptor are forwarded to
    /// the executor thread; everything else runs in-process.
    pub fn execute_planned(&self, plan: &ExecutionPlan, req: &BlasRequest,
                           fault: Option<Fault>) -> Result<BlasResponse> {
        if plan.kernel.backend == Backend::Pjrt {
            let pjrt = self.pjrt.as_ref().ok_or_else(|| {
                anyhow!("plan selected {} but no PJRT backend is attached",
                        plan.kernel.name)
            })?;
            return pjrt.execute(req, plan.policy, fault);
        }
        let _pool = self.enter_pool();
        Ok(execute_plan(req, plan, &self.profile, fault))
    }

    /// Execute a whole drained batch through one batch-fused kernel —
    /// the server's small-GEMM fast path. `kernel` must be a
    /// `dgemm/batched*` entry (the worker resolves it via
    /// [`crate::coordinator::registry::KernelRegistry::batched_sibling`])
    /// and every request must be a DGEMM whose plan resolved to that
    /// entry's serial sibling. The batch runs in **one** driver call
    /// under one threading frame; each item keeps its own fault (armed
    /// by the caller in batch order, so campaign occurrence sequences
    /// continue exactly) and gets its own [`BlasResponse`] with its own
    /// `FtReport`, index-aligned with `reqs`.
    ///
    /// The driver times the batch as a whole; the per-item
    /// `exec_seconds` is the batch mean, which keeps ledger sums exact.
    pub fn execute_batch(&self, kernel: &'static KernelDescriptor,
                         reqs: &[(&BlasRequest, Option<Fault>)],
                         threads: usize) -> Vec<BlasResponse> {
        let _pool = self.enter_pool();
        let t0 = std::time::Instant::now();
        let params = &self.profile.gemm;
        let mut dims = Vec::with_capacity(reqs.len());
        let mut outs: Vec<Vec<f64>> = Vec::with_capacity(reqs.len());
        for (req, fault) in reqs {
            let BlasRequest::Dgemm { alpha, a, b, beta, c } = req else {
                unreachable!("batch fusion drained a non-dgemm request: {}",
                             req.routine())
            };
            dims.push((a.rows, b.cols, a.cols, *alpha, *beta, &a.data,
                       &b.data, *fault));
            outs.push(c.data.clone());
        }
        let mut items: Vec<batched::GemmItem<'_>> = dims
            .iter()
            .zip(outs.iter_mut())
            .map(|(&(m, n, k, alpha, beta, a, b, fault), cd)| {
                let inject = match fault {
                    Some(f) => registry::strikes(
                        &[f], k.div_ceil(params.kc), m.max(1), n.max(1)),
                    None => Vec::new(),
                };
                batched::GemmItem {
                    m, n, k, alpha, beta,
                    a: &a[..], b: &b[..], c: &mut cd[..], inject,
                }
            })
            .collect();
        let reports = match (kernel.variant, kernel.scheme) {
            (Impl::Tuned, Scheme::None) => {
                batched::dgemm_batched(&mut items, params, threads);
                vec![FtReport::none(); reqs.len()]
            }
            (Impl::Simd, Scheme::None) => {
                batched::dgemm_batched_simd(&mut items, params, threads);
                vec![FtReport::none(); reqs.len()]
            }
            (Impl::Simd, Scheme::AbftFused) => {
                batched::dgemm_batched_abft_fused_simd(&mut items, params,
                                                       threads)
            }
            (v, s) => unreachable!(
                "{}: no batched driver for variant {}/scheme {s:?}",
                kernel.name, v.name()),
        };
        drop(items);
        let per_item = t0.elapsed().as_secs_f64() / reqs.len().max(1) as f64;
        dims.into_iter()
            .zip(outs)
            .zip(reports)
            .map(|(((m, n, ..), cd), ft)| BlasResponse {
                result: BlasResult::Matrix(Matrix::from_vec(m, n, cd)),
                ft,
                backend: kernel.backend,
                kernel: kernel.name,
                exec_seconds: per_item,
            })
            .collect()
    }
}

/// Run a resolved plan's kernel. Protection follows the hybrid strategy
/// encoded in the descriptors' capability lists — DMR for Level-1/2,
/// online ABFT (kc-paneled, fused into the tuned GEMM frame) for
/// Level-3 — and the planned fault is translated to each scheme's
/// injection point inside the registered kernel.
pub fn execute_plan(req: &BlasRequest, plan: &ExecutionPlan,
                    profile: &Profile, fault: Option<Fault>) -> BlasResponse {
    let t0 = std::time::Instant::now();
    let faults: &[Fault] = match &fault {
        Some(f) => std::slice::from_ref(f),
        None => &[],
    };
    let ctx = ExecCtx {
        req,
        profile,
        policy: plan.policy,
        faults,
        threads: plan.threads,
    };
    let (result, ft) = (plan.kernel.execute)(&ctx);
    BlasResponse {
        result,
        ft,
        backend: plan.kernel.backend,
        kernel: plan.kernel.name,
        exec_seconds: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::BlasResult;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::{allclose, Matrix};
    use crate::util::rng::Rng;

    /// Plan under a variant preference, then run the planned kernel —
    /// the same two calls every out-of-server caller now makes.
    fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
                  policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
        let sel = SelectionPolicy::for_variant(variant);
        let plan = Planner::new(profile)
            .plan(req, &sel, policy)
            .expect("registry serves every shipped routine/policy");
        execute_plan(req, &plan, profile, fault)
    }

    fn oracle(req: &BlasRequest) -> BlasResponse {
        run_native(req, Impl::Naive, &Profile::default(), FtPolicy::None, None)
    }

    fn close(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
        match (a, b) {
            (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
                (x - y).abs() <= tol * (1.0 + y.abs())
            }
            (BlasResult::Vector(x), BlasResult::Vector(y)) => {
                allclose(x, y, tol, tol)
            }
            (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
                allclose(&x.data, &y.data, tol, tol)
            }
            _ => false,
        }
    }

    fn sample_requests(rng: &mut Rng, n: usize) -> Vec<BlasRequest> {
        let a = Matrix::random(n, n, rng);
        let b = Matrix::random(n, n, rng);
        let c = Matrix::random(n, n, rng);
        let l = Matrix::random_lower_triangular(n, rng);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        vec![
            BlasRequest::Dscal { alpha: 1.7, x: x.clone() },
            BlasRequest::Daxpy { alpha: -0.8, x: x.clone(), y: y.clone() },
            BlasRequest::Ddot { x: x.clone(), y: y.clone() },
            BlasRequest::Dnrm2 { x: x.clone() },
            BlasRequest::Dasum { x: x.clone() },
            BlasRequest::Dgemv { alpha: 1.1, a: a.clone(), x: x.clone(),
                                 beta: 0.3, y: y.clone() },
            BlasRequest::Dtrsv { a: l.clone(), b: x.clone() },
            BlasRequest::Dgemm { alpha: 0.9, a: a.clone(), b: b.clone(),
                                 beta: 0.5, c: c.clone() },
            BlasRequest::Dsymm { alpha: 1.2, a: a.clone(), b: b.clone(),
                                 beta: 0.4, c: c.clone() },
            BlasRequest::Dtrmm { alpha: 0.7, a: l.clone(), b: b.clone() },
            BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
            BlasRequest::Dsyrk { alpha: 1.0, a: a.clone(), beta: 0.2,
                                 c: c.clone() },
        ]
    }

    #[test]
    fn every_variant_matches_oracle_unprotected() {
        check("router-native-matrix", 6, |g| {
            let n = 16 + 8 * g.rng.below(5);
            for req in sample_requests(&mut g.rng, n) {
                let want = oracle(&req);
                for v in [Impl::Blocked, Impl::Tuned] {
                    let got = run_native(&req, v, &Profile::default(),
                                         FtPolicy::None, None);
                    ensure(close(&got.result, &want.result, 1e-8),
                           format!("{} [{}]", req.routine(), v.name()))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hybrid_policy_matches_oracle_clean() {
        check("router-hybrid-clean", 4, |g| {
            let n = 16 + 8 * g.rng.below(4);
            for req in sample_requests(&mut g.rng, n) {
                let want = oracle(&req);
                let got = run_native(&req, Impl::Tuned, &Profile::default(),
                                     FtPolicy::Hybrid, None);
                ensure(got.ft.errors_detected == 0,
                       format!("{}: spurious detection", req.routine()))?;
                ensure(close(&got.result, &want.result, 1e-8),
                       format!("{} hybrid mismatch", req.routine()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn hybrid_policy_corrects_injected_faults() {
        check("router-hybrid-inject", 6, |g| {
            let n = 32;
            let reqs = sample_requests(&mut g.rng, n);
            for req in reqs {
                // dasum/dsyrk have no FT path (paper doesn't protect them)
                if matches!(req, BlasRequest::Dasum { .. } | BlasRequest::Dsyrk { .. }) {
                    continue;
                }
                let want = oracle(&req);
                let fault = Fault {
                    step: 1 + g.rng.below(2),
                    i: g.rng.below(8),
                    j: g.rng.below(n),
                    delta: g.rng.range(10.0, 1e5),
                };
                let got = run_native(&req, Impl::Tuned, &Profile::default(),
                                     FtPolicy::Hybrid, Some(fault));
                ensure(got.ft.errors_detected >= 1,
                       format!("{}: fault not detected", req.routine()))?;
                ensure(close(&got.result, &want.result, 1e-7),
                       format!("{}: fault not corrected", req.routine()))?;
            }
            Ok(())
        });
    }

    /// The response reports the registry kernel that actually ran.
    #[test]
    fn response_names_the_planned_kernel() {
        let mut rng = Rng::new(0x7E57);
        let n = 24;
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        let profile = Profile::default();
        let got = run_native(&req, Impl::Tuned, &profile,
                             FtPolicy::None, None);
        assert_eq!(got.kernel, "dgemm/tuned");
        let got = run_native(&req, Impl::Tuned, &profile,
                             FtPolicy::Hybrid, None);
        assert_eq!(got.kernel, "dgemm/abft-fused");
        let got = run_native(&req, Impl::Tuned,
                             &profile.clone().with_threads(4),
                             FtPolicy::Hybrid, None);
        assert_eq!(got.kernel, "dgemm/abft-fused-mt");
        // Router::plan describes a request (and, since batches share a
        // kernel-id key, a whole batch) without executing it
        let router = Router::native_only(profile, Backend::NativeTuned);
        let plan = router.plan(&req, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
        assert!(plan.describe().contains("dgemm/abft-fused"));
    }

    /// Capability selection across peer backends: an unavailable PJRT
    /// backend is denied (not planned), and a GPU-sim preference selects
    /// the simulated executor tier whose planned run matches the oracle.
    #[test]
    fn peer_backend_selection_and_fallback() {
        let mut rng = Rng::new(0x6B);
        let n = 24;
        let req = BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        };
        let want = oracle(&req);
        // no PJRT backend attached: preference falls back to tuned
        let router = Router::native_only(Profile::default(), Backend::Pjrt);
        let plan = router.plan(&req, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned");
        // GPU-sim preference: the protected warp-tiled tier runs
        let router = router
            .with_selection(SelectionPolicy::for_backend(Backend::GpuSim));
        let plan = router.plan(&req, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/gpusim-wmma16");
        let resp = router.execute_planned(&plan, &req, None).unwrap();
        assert_eq!(resp.backend, Backend::GpuSim);
        assert_eq!(resp.ft, FtReport::none());
        assert!(close(&resp.result, &want.result, 1e-8));
        // …and corrects a planned strike end to end
        let fault = Fault { step: 0, i: 7, j: 11, delta: 4e4 };
        let resp = router.execute_planned(&plan, &req, Some(fault)).unwrap();
        assert!(resp.ft.errors_detected >= 1);
        assert_eq!(resp.ft.errors_detected, resp.ft.errors_corrected);
        assert!(close(&resp.result, &want.result, 1e-7));
    }

    /// One `execute_batch` call serves every item of a fused batch:
    /// per-item results match the sequential oracle, per-item faults
    /// are corrected by the item that owns them, and every response
    /// reports the batched kernel name.
    #[test]
    fn execute_batch_serves_each_item_with_its_own_report() {
        use crate::coordinator::registry::KernelRegistry;
        let mut rng = Rng::new(0xBA);
        let dims = [(24usize, 16usize, 16usize), (9, 12, 8), (32, 8, 24)];
        let reqs: Vec<BlasRequest> = dims
            .iter()
            .map(|&(m, n, k)| BlasRequest::Dgemm {
                alpha: 1.0,
                a: Matrix::random(m, k, &mut rng),
                b: Matrix::random(k, n, &mut rng),
                beta: 0.0,
                c: Matrix::zeros(m, n),
            })
            .collect();
        let oracles: Vec<BlasResponse> = reqs.iter().map(oracle).collect();
        let router =
            Router::native_only(Profile::default(), Backend::NativeSimd);
        let kernel = KernelRegistry::global()
            .find("dgemm/batched-abft-fused-simd")
            .unwrap();
        // fault on items 0 and 2 only; item 1 must stay clean
        let strike = |m: usize, n: usize| {
            Some(Fault { step: 0, i: m / 2, j: n / 3, delta: 6e4 })
        };
        let batch: Vec<(&BlasRequest, Option<Fault>)> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let (m, n, _) = dims[i];
                (r, if i != 1 { strike(m, n) } else { None })
            })
            .collect();
        for threads in [1usize, 4] {
            let resps = router.execute_batch(kernel, &batch, threads);
            assert_eq!(resps.len(), reqs.len());
            for (i, (resp, want)) in resps.iter().zip(&oracles).enumerate() {
                assert_eq!(resp.kernel, "dgemm/batched-abft-fused-simd");
                let hit = (i != 1) as u64;
                assert_eq!(resp.ft.errors_detected, hit,
                           "t={threads} item {i}: detection count");
                assert_eq!(resp.ft.errors_corrected, hit,
                           "t={threads} item {i}: correction count");
                assert!(close(&resp.result, &want.result, 1e-7),
                        "t={threads} item {i}: batched result wrong");
            }
        }
        // the unprotected batched entries serve the same batch cleanly
        let clean: Vec<(&BlasRequest, Option<Fault>)> =
            reqs.iter().map(|r| (r, None)).collect();
        for name in ["dgemm/batched", "dgemm/batched-simd"] {
            let kernel = KernelRegistry::global().find(name).unwrap();
            let resps = router.execute_batch(kernel, &clean, 2);
            for (i, (resp, want)) in resps.iter().zip(&oracles).enumerate() {
                assert_eq!(resp.kernel, name);
                assert_eq!(resp.ft, crate::ft::FtReport::none());
                assert!(close(&resp.result, &want.result, 1e-8),
                        "{name} item {i}: batched result wrong");
            }
        }
    }

    /// The weighted-checksum policy is reachable end to end and corrects
    /// a planned strike on DGEMM.
    #[test]
    fn weighted_policy_end_to_end() {
        let mut rng = Rng::new(0x3E1);
        let n = 48;
        let req = BlasRequest::Dgemm {
            alpha: 0.9,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.4,
            c: Matrix::random(n, n, &mut rng),
        };
        let want = oracle(&req);
        let profile = Profile::default();
        let clean = run_native(&req, Impl::Tuned, &profile,
                               FtPolicy::AbftWeighted, None);
        assert_eq!(clean.kernel, "dgemm/abft-weighted");
        assert_eq!(clean.ft.errors_detected, 0);
        assert!(close(&clean.result, &want.result, 1e-8));
        let fault = Fault { step: 0, i: 17, j: 31, delta: 7.5e4 };
        let got = run_native(&req, Impl::Tuned, &profile,
                             FtPolicy::AbftWeighted, Some(fault));
        assert!(got.ft.errors_detected >= 1);
        assert_eq!(got.ft.errors_detected, got.ft.errors_corrected);
        assert!(close(&got.result, &want.result, 1e-7));
    }
}
