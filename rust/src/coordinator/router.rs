//! Router: executes a request on a chosen backend under an FT policy.
//!
//! Native backends run in the caller's thread (the server gives them a
//! worker pool); the PJRT backend forwards to the executor thread. When
//! the preferred backend cannot serve a request (PJRT artifacts are
//! shape-specialized), the router falls back to the tuned native kernels
//! — requests never fail for shape reasons.

use anyhow::Result;

use crate::blas::{blocked, level1, level2, level3, naive, Impl};
use crate::config::Profile;
use crate::coordinator::pjrt_backend::PjrtBackend;
use crate::coordinator::request::{
    Backend, BlasRequest, BlasResponse, BlasResult,
};
use crate::ft::injector::Fault;
use crate::ft::policy::FtPolicy;
use crate::ft::{abft, abft_fused, dmr, FtReport};
use crate::util::matrix::Matrix;

/// The router. `pjrt` is optional so the native path works without
/// artifacts on disk (e.g. unit tests).
pub struct Router {
    pub profile: Profile,
    pub pjrt: Option<PjrtBackend>,
    pub prefer: Backend,
}

impl Router {
    pub fn native_only(profile: Profile, prefer: Backend) -> Router {
        Router { profile, pjrt: None, prefer }
    }

    pub fn with_pjrt(profile: Profile, pjrt: PjrtBackend, prefer: Backend) -> Router {
        Router { profile, pjrt: Some(pjrt), prefer }
    }

    /// Where would this request actually run?
    pub fn resolve(&self, req: &BlasRequest, policy: FtPolicy) -> Backend {
        match self.prefer {
            Backend::Pjrt => match &self.pjrt {
                Some(p) if p.supports(req, policy) => Backend::Pjrt,
                _ => Backend::NativeTuned,
            },
            other => other,
        }
    }

    /// Execute a request under a policy with an optional planned fault.
    pub fn execute(&self, req: &BlasRequest, policy: FtPolicy,
                   fault: Option<Fault>) -> Result<BlasResponse> {
        match self.resolve(req, policy) {
            Backend::Pjrt => self
                .pjrt
                .as_ref()
                .expect("resolve() returned Pjrt without a backend")
                .execute(req, policy, fault),
            Backend::NativeNaive => {
                Ok(execute_native(req, Impl::Naive, &self.profile, policy, fault))
            }
            Backend::NativeBlocked => {
                Ok(execute_native(req, Impl::Blocked, &self.profile, policy, fault))
            }
            Backend::NativeTuned => {
                Ok(execute_native(req, Impl::Tuned, &self.profile, policy, fault))
            }
        }
    }
}

/// Execute on the native kernels. Protection per the hybrid strategy:
/// DMR for Level-1/2, online ABFT (kc-paneled, around the tuned GEMM) for
/// Level-3. The fault is translated to each scheme's injection point.
pub fn execute_native(req: &BlasRequest, variant: Impl, profile: &Profile,
                      policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let t0 = std::time::Instant::now();
    let protected = policy.protects();
    let params = &profile.gemm;
    let inj_elem = fault.map(|f| (f.i, f.delta));
    let backend = match variant {
        Impl::Naive => Backend::NativeNaive,
        Impl::Blocked => Backend::NativeBlocked,
        Impl::Tuned => Backend::NativeTuned,
    };

    let (result, ft) = match req {
        // -------------------------------------------------- Level 1
        BlasRequest::Dscal { alpha, x } => {
            let mut x = x.clone();
            let ft = if protected {
                dmr::dscal_ft(*alpha, &mut x, inj_elem)
            } else {
                match variant {
                    Impl::Naive => naive::dscal(*alpha, &mut x),
                    Impl::Blocked => blocked::dscal(*alpha, &mut x),
                    Impl::Tuned => level1::dscal(*alpha, &mut x),
                }
                FtReport::none()
            };
            (BlasResult::Vector(x), ft)
        }
        BlasRequest::Daxpy { alpha, x, y } => {
            let mut y = y.clone();
            let ft = if protected {
                dmr::daxpy_ft(*alpha, x, &mut y, inj_elem)
            } else {
                match variant {
                    Impl::Naive => naive::daxpy(*alpha, x, &mut y),
                    Impl::Blocked => blocked::daxpy(*alpha, x, &mut y),
                    Impl::Tuned => level1::daxpy(*alpha, x, &mut y),
                }
                FtReport::none()
            };
            (BlasResult::Vector(y), ft)
        }
        BlasRequest::Ddot { x, y } => {
            if protected {
                // reduction DMR injects per chunk: clamp to chunk range
                let inj = inj_elem.map(|(i, d)| (i % (x.len() / 8).max(1), d));
                let (d, ft) = dmr::ddot_ft(x, y, inj);
                (BlasResult::Scalar(d), ft)
            } else {
                let d = match variant {
                    Impl::Naive => naive::ddot(x, y),
                    Impl::Blocked => blocked::ddot(x, y),
                    Impl::Tuned => level1::ddot(x, y),
                };
                (BlasResult::Scalar(d), FtReport::none())
            }
        }
        BlasRequest::Dnrm2 { x } => {
            if protected {
                let inj = inj_elem.map(|(i, d)| (i % (x.len() / 8).max(1), d));
                let (d, ft) = dmr::dnrm2_ft(x, inj);
                (BlasResult::Scalar(d), ft)
            } else {
                let d = match variant {
                    Impl::Naive => naive::dnrm2(x),
                    Impl::Blocked => blocked::dnrm2(x),
                    Impl::Tuned => level1::dnrm2(x),
                };
                (BlasResult::Scalar(d), FtReport::none())
            }
        }
        BlasRequest::Dasum { x } => {
            if protected {
                let inj = inj_elem.map(|(i, d)| (i % (x.len() / 8).max(1), d));
                let (d, ft) = dmr::dasum_ft(x, inj);
                (BlasResult::Scalar(d), ft)
            } else {
                let d = match variant {
                    Impl::Naive => naive::dasum(x),
                    _ => level1::dasum(x),
                };
                (BlasResult::Scalar(d), FtReport::none())
            }
        }
        BlasRequest::Drot { x, y, c, s } => {
            let (mut x, mut y) = (x.clone(), y.clone());
            let ft = if protected {
                dmr::drot_ft(&mut x, &mut y, *c, *s, inj_elem)
            } else {
                match variant {
                    Impl::Naive => naive::drot(&mut x, &mut y, *c, *s),
                    _ => level1::drot(&mut x, &mut y, *c, *s),
                }
                FtReport::none()
            };
            let mut out = x;
            out.extend_from_slice(&y);
            (BlasResult::Vector(out), ft)
        }
        BlasRequest::Drotm { x, y, param } => {
            let (mut x, mut y) = (x.clone(), y.clone());
            let ft = if protected {
                dmr::drotm_ft(&mut x, &mut y, param, inj_elem)
            } else {
                match variant {
                    Impl::Naive => naive::drotm(&mut x, &mut y, param),
                    _ => level1::drotm(&mut x, &mut y, param),
                }
                FtReport::none()
            };
            let mut out = x;
            out.extend_from_slice(&y);
            (BlasResult::Vector(out), ft)
        }
        BlasRequest::Idamax { x } => {
            if protected {
                let inj = inj_elem.map(|(i, d)| (i, d));
                let (i, ft) = dmr::idamax_ft(x, inj);
                (BlasResult::Scalar(i as f64), ft)
            } else {
                let i = match variant {
                    Impl::Naive => naive::idamax(x),
                    _ => level1::idamax(x),
                };
                (BlasResult::Scalar(i as f64), FtReport::none())
            }
        }
        // -------------------------------------------------- Level 2
        BlasRequest::Dgemv { alpha, a, x, beta, y } => {
            let mut y = y.clone();
            let ft = if protected {
                dmr::dgemv_ft(a.rows, a.cols, *alpha, &a.data, x, *beta,
                              &mut y, inj_elem)
            } else {
                match variant {
                    Impl::Naive => {
                        naive::dgemv(a.rows, a.cols, *alpha, &a.data, x,
                                     *beta, &mut y)
                    }
                    Impl::Blocked => {
                        blocked::dgemv(a.rows, a.cols, *alpha, &a.data, x,
                                       *beta, &mut y)
                    }
                    Impl::Tuned => {
                        level2::dgemv(a.rows, a.cols, *alpha, &a.data, x,
                                      *beta, &mut y)
                    }
                }
                FtReport::none()
            };
            (BlasResult::Vector(y), ft)
        }
        BlasRequest::Dtrsv { a, b } => {
            let mut x = b.clone();
            let n = a.rows;
            let ft = if protected {
                // panel step 0 has no gemv update: clamp strikes to >= 1
                let nsteps = n.div_ceil(profile.trsv_panel);
                let inj = fault.map(|f| {
                    let s = if nsteps > 1 { 1 + f.step % (nsteps - 1) } else { 0 };
                    (s, f.delta)
                });
                dmr::dtrsv_ft(n, &a.data, &mut x, profile.trsv_panel, inj)
            } else {
                match variant {
                    Impl::Naive => naive::dtrsv_lower(n, &a.data, &mut x),
                    Impl::Blocked => blocked::dtrsv_lower(n, &a.data, &mut x),
                    Impl::Tuned => {
                        level2::dtrsv_lower(n, &a.data, &mut x, profile.trsv_panel)
                    }
                }
                FtReport::none()
            };
            (BlasResult::Vector(x), ft)
        }
        BlasRequest::Dger { alpha, x, y, a } => {
            let (m, n) = (a.rows, a.cols);
            let mut ad = a.data.clone();
            let ft = if protected {
                let inj = inj_elem.map(|(i, d)| (i % (m * n), d));
                dmr::dger_ft(m, n, *alpha, x, y, &mut ad, inj)
            } else {
                match variant {
                    Impl::Naive => naive::dger(m, n, *alpha, x, y, &mut ad),
                    _ => level2::dger(m, n, *alpha, x, y, &mut ad),
                }
                FtReport::none()
            };
            (BlasResult::Matrix(Matrix::from_vec(m, n, ad)), ft)
        }
        BlasRequest::Dsymv { alpha, a, x, beta, y } => {
            let n = a.rows;
            let mut y = y.clone();
            let ft = if protected {
                let inj = inj_elem.map(|(i, d)| (i % n, d));
                dmr::dsymv_ft(n, *alpha, &a.data, x, *beta, &mut y, inj)
            } else {
                match variant {
                    Impl::Naive => {
                        naive::dsymv_lower(n, *alpha, &a.data, x, *beta, &mut y)
                    }
                    _ => level2::dsymv_lower(n, *alpha, &a.data, x, *beta,
                                             &mut y),
                }
                FtReport::none()
            };
            (BlasResult::Vector(y), ft)
        }
        BlasRequest::Dtrmv { a, x } => {
            let n = a.rows;
            let mut x = x.clone();
            let ft = if protected {
                let inj = inj_elem.map(|(i, d)| (i % n, d));
                dmr::dtrmv_ft(n, &a.data, &mut x, inj)
            } else {
                match variant {
                    Impl::Naive => naive::dtrmv_lower(n, &a.data, &mut x),
                    _ => level2::dtrmv_lower(n, &a.data, &mut x),
                }
                FtReport::none()
            };
            (BlasResult::Vector(x), ft)
        }
        // -------------------------------------------------- Level 3
        BlasRequest::Dgemm { alpha, a, b, beta, c } => {
            let (m, n, k) = (a.rows, b.cols, a.cols);
            let mut cd = c.data.clone();
            let ft = if protected {
                // Hybrid → native fused online ABFT (paper §5.2):
                // checksums ride the packing routines + macro-kernel
                // write-back. AbftUnfused → the §5.1 "ABFT on a
                // third-party library" baseline for Fig. 8.
                let nsteps = k.div_ceil(params.kc);
                let inj: Vec<_> = fault
                    .map(|f| (f.step % nsteps, f.i % m, f.j % n, f.delta))
                    .into_iter()
                    .collect();
                if policy == FtPolicy::AbftUnfused {
                    let ascaled: Vec<f64> =
                        a.data.iter().map(|v| alpha * v).collect();
                    for v in cd.iter_mut() {
                        *v *= beta;
                    }
                    abft::dgemm_abft_unfused(
                        m, n, k, params.kc, &ascaled, &b.data, &mut cd,
                        |ap, bp, cc, mm, kk| {
                            level3::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc,
                                          params)
                        },
                        inj.first().copied(),
                    )
                } else {
                    abft_fused::dgemm_abft_fused(
                        m, n, k, *alpha, &a.data, &b.data, *beta, &mut cd,
                        params, &inj)
                }
            } else {
                match variant {
                    Impl::Naive => {
                        naive::dgemm(m, n, k, *alpha, &a.data, &b.data, *beta,
                                     &mut cd)
                    }
                    _ => level3::dgemm(m, n, k, *alpha, &a.data, &b.data,
                                       *beta, &mut cd, params),
                }
                FtReport::none()
            };
            (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
        }
        BlasRequest::Dsymm { alpha, a, b, beta, c } => {
            let (m, n) = (a.rows, b.cols);
            let mut cd = c.data.clone();
            let ft = if protected {
                let nsteps = m.div_ceil(params.kc);
                let inj: Vec<_> = fault
                    .map(|f| (f.step % nsteps, f.i % m, f.j % n, f.delta))
                    .into_iter()
                    .collect();
                if policy == FtPolicy::AbftUnfused {
                    // symmetrize (packing analog) then unfused-ABFT GEMM
                    let mut full = vec![0.0; m * m];
                    for i in 0..m {
                        for j in 0..=i {
                            let v = alpha * a.data[i * m + j];
                            full[i * m + j] = v;
                            full[j * m + i] = v;
                        }
                    }
                    for v in cd.iter_mut() {
                        *v *= beta;
                    }
                    abft::dgemm_abft_unfused(
                        m, n, m, params.kc, &full, &b.data, &mut cd,
                        |ap, bp, cc, mm, kk| {
                            level3::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc,
                                          params)
                        },
                        inj.first().copied(),
                    )
                } else {
                    abft_fused::dsymm_abft_fused(
                        m, n, *alpha, &a.data, &b.data, *beta, &mut cd,
                        params, &inj)
                }
            } else {
                match variant {
                    Impl::Naive => {
                        naive::dsymm_lower(m, n, *alpha, &a.data, &b.data,
                                           *beta, &mut cd)
                    }
                    _ => level3::dsymm_lower(m, n, *alpha, &a.data, &b.data,
                                             *beta, &mut cd, params),
                }
                FtReport::none()
            };
            (BlasResult::Matrix(Matrix::from_vec(m, n, cd)), ft)
        }
        BlasRequest::Dtrmm { alpha, a, b } => {
            let (m, n) = (a.rows, b.cols);
            let mut bd = b.data.clone();
            let ft = if protected {
                let nsteps = m.div_ceil(params.kc);
                let inj: Vec<_> = fault
                    .map(|f| (f.step % nsteps, f.i % m, f.j % n, f.delta))
                    .into_iter()
                    .collect();
                if policy == FtPolicy::AbftUnfused {
                    let mut low = vec![0.0; m * m];
                    for i in 0..m {
                        for j in 0..=i {
                            low[i * m + j] = alpha * a.data[i * m + j];
                        }
                    }
                    let b0 = bd.clone();
                    for v in bd.iter_mut() {
                        *v = 0.0;
                    }
                    abft::dgemm_abft_unfused(
                        m, n, m, params.kc, &low, &b0, &mut bd,
                        |ap, bp, cc, mm, kk| {
                            level3::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc,
                                          params)
                        },
                        inj.first().copied(),
                    )
                } else {
                    abft_fused::dtrmm_abft_fused(
                        m, n, *alpha, &a.data, &mut bd, params, &inj)
                }
            } else {
                match variant {
                    Impl::Naive => {
                        naive::dtrmm_lower(m, n, *alpha, &a.data, &mut bd)
                    }
                    _ => level3::dtrmm_lower(m, n, *alpha, &a.data, &mut bd,
                                             params),
                }
                FtReport::none()
            };
            (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), ft)
        }
        BlasRequest::Dtrsm { a, b } => {
            let (m, n) = (a.rows, b.cols);
            let mut bd = b.data.clone();
            let mut ft = FtReport::none();
            if protected {
                // paper's FT-TRSM: ABFT on the panel GEMM updates, DMR on
                // the diagonal solves
                ft = dtrsm_ft_native(m, n, &a.data, &mut bd,
                                     profile.trsm_panel, params, fault);
            } else {
                match variant {
                    Impl::Naive => naive::dtrsm_llnn(m, n, &a.data, &mut bd),
                    Impl::Blocked => blocked::dtrsm_llnn(m, n, &a.data, &mut bd),
                    Impl::Tuned => {
                        level3::dtrsm_llnn(m, n, &a.data, &mut bd,
                                           profile.trsm_panel, params)
                    }
                }
            }
            (BlasResult::Matrix(Matrix::from_vec(m, n, bd)), ft)
        }
        BlasRequest::Dsyrk { alpha, a, beta, c } => {
            let (n, k) = (a.rows, a.cols);
            let mut cd = c.data.clone();
            match variant {
                Impl::Naive => {
                    naive::dsyrk_lower(n, k, *alpha, &a.data, *beta, &mut cd)
                }
                _ => level3::dsyrk_lower(n, k, *alpha, &a.data, *beta, &mut cd,
                                         params),
            }
            (BlasResult::Matrix(Matrix::from_vec(n, n, cd)), FtReport::none())
        }
    };

    BlasResponse { result, ft, backend, exec_seconds: t0.elapsed().as_secs_f64() }
}

/// Native FT-TRSM: each panel's GEMM update is checksum-verified and
/// corrected online; diagonal solves are DMR-duplicated.
fn dtrsm_ft_native(m: usize, n: usize, a: &[f64], b: &mut [f64], panel: usize,
                   params: &crate::blas::level3::GemmParams,
                   fault: Option<Fault>) -> FtReport {
    let mut report = FtReport::none();
    let nsteps = m.div_ceil(panel);
    // step 0 has no off-diagonal panel; clamp planned strikes to [1, nsteps)
    let fault = fault.map(|mut f| {
        if nsteps > 1 {
            f.step = 1 + f.step % (nsteps - 1);
        } else {
            f.step = 0;
        }
        f.i %= panel; // panel-local strike position
        f.j %= n;
        f
    });
    let mut i = 0;
    let mut step = 0;
    while i < m {
        let pb = panel.min(m - i);
        if i > 0 {
            let mut apanel = vec![0.0; pb * i];
            for r in 0..pb {
                apanel[r * i..(r + 1) * i]
                    .copy_from_slice(&a[(i + r) * m..(i + r) * m + i]);
            }
            let (xdone, btail) = b.split_at_mut(i * n);
            let bblk = &mut btail[..pb * n];
            // B_block -= A_panel · X_done, in place through the fused-ABFT
            // GEMM frame (paper §5.2): the checksum traffic shares the
            // packing loads and the β=1 accumulation seeds the checksums
            // from B_block itself — no staging buffer, no extra subtract
            // pass over memory.
            let usteps = i.div_ceil(params.kc);
            let inj: Vec<_> = fault
                .filter(|f| f.step == step)
                // clamp the strike into this step's pb×n update (the last
                // panel can be narrower than the configured width)
                .map(|f| (f.step % usteps, f.i % pb, f.j % n, f.delta))
                .into_iter()
                .collect();
            report.merge(abft_fused::dgemm_abft_fused(
                pb, n, i, -1.0, &apanel, &xdone[..i * n], 1.0, bblk, params,
                &inj));
        }
        // Checksum-protected diagonal solve (the ABFT identity for a
        // triangular solve T·X = B: with w = Tᵀ·e, any computed X must
        // satisfy wᵀ·X = eᵀ·B column-wise). Verification costs one
        // O(pb·n) pass instead of duplicating the O(pb²·n/2) solve — the
        // L3 analog of the paper's "cast the cost into checksums, not
        // duplication" argument. A flagged column is re-solved twice on
        // the cold path (third computation + consensus).
        let binit: Vec<f64> = b[i * n..(i + pb) * n].to_vec();
        // column sums of the incoming rhs (eᵀ·B) — fused with the copy
        let mut sb = vec![0.0; n];
        for r in 0..pb {
            let row = &binit[r * n..(r + 1) * n];
            for (s, v) in sb.iter_mut().zip(row) {
                *s += v;
            }
        }
        // w = Tᵀ·e: column sums of the pb×pb lower-triangular block
        let mut w = vec![0.0; pb];
        let mut max_t = 0.0f64;
        for r in 0..pb {
            let gi = i + r;
            for (p, wv) in w.iter_mut().enumerate().take(r + 1) {
                let t = a[gi * m + i + p];
                *wv += t;
                max_t = max_t.max(t.abs());
            }
        }
        // the (single) vectorized forward solve
        {
            let (done, cur) = b.split_at_mut(i * n);
            let _ = done;
            let blk = &mut cur[..pb * n];
            for r in 0..pb {
                let gi = i + r;
                let (solved, rest) = blk.split_at_mut(r * n);
                let row = &mut rest[..n];
                for p in 0..r {
                    let aip = a[gi * m + i + p];
                    let prow = &solved[p * n..(p + 1) * n];
                    for (o, s) in row.iter_mut().zip(prow) {
                        *o -= aip * s;
                    }
                }
                let rd = 1.0 / a[gi * m + gi];
                for o in row.iter_mut() {
                    *o *= rd;
                }
            }
        }
        // single-panel matrices have no GEMM update to strike — the
        // planned fault lands on the diagonal solve output instead
        // (before verification reads it), exercising the checksum path
        if let Some(f) = fault {
            if f.step == step && i == 0 && m <= panel {
                b[(f.i % pb) * n + f.j % n] += f.delta;
            }
        }
        // verify wᵀ·X against eᵀ·B per column
        let x = &b[i * n..(i + pb) * n];
        let mut sx = vec![0.0; n];
        let mut max_x = 0.0f64;
        for r in 0..pb {
            let wr = w[r];
            let row = &x[r * n..(r + 1) * n];
            for (s, v) in sx.iter_mut().zip(row) {
                *s += wr * v;
            }
        }
        for v in x {
            max_x = max_x.max(v.abs());
        }
        let tol = crate::ft::abft::round_off_threshold(
            max_t.max(1.0) * max_x.max(1.0), pb, n);
        let bad: Vec<usize> = (0..n)
            .filter(|&cx| (sx[cx] - sb[cx]).abs() > tol)
            .collect();
        if !bad.is_empty() {
            // cold path: re-solve the flagged columns twice + consensus
            for &cx in &bad {
                let resolve = || -> Vec<f64> {
                    let mut col = vec![0.0; pb];
                    for r in 0..pb {
                        let gi = i + r;
                        let mut acc =
                            std::hint::black_box(binit[r * n + cx]);
                        for p in 0..r {
                            acc -= a[gi * m + i + p] * col[p];
                        }
                        col[r] = acc / a[gi * m + gi];
                    }
                    col
                };
                let c1 = resolve();
                let c2 = resolve();
                if c1 != c2 {
                    panic!("FT-BLAS DTRSM: diagonal re-solve disagrees — \
                            unrecoverable");
                }
                for r in 0..pb {
                    b[(i + r) * n + cx] = c1[r];
                }
            }
            report.errors_detected += 1;
            report.errors_corrected += 1;
        }
        i += pb;
        step += 1;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};
    use crate::util::matrix::allclose;
    use crate::util::rng::Rng;

    fn oracle(req: &BlasRequest) -> BlasResponse {
        execute_native(req, Impl::Naive, &Profile::default(), FtPolicy::None, None)
    }

    fn close(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
        match (a, b) {
            (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
                (x - y).abs() <= tol * (1.0 + y.abs())
            }
            (BlasResult::Vector(x), BlasResult::Vector(y)) => {
                allclose(x, y, tol, tol)
            }
            (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
                allclose(&x.data, &y.data, tol, tol)
            }
            _ => false,
        }
    }

    fn sample_requests(rng: &mut Rng, n: usize) -> Vec<BlasRequest> {
        let a = Matrix::random(n, n, rng);
        let b = Matrix::random(n, n, rng);
        let c = Matrix::random(n, n, rng);
        let l = Matrix::random_lower_triangular(n, rng);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        vec![
            BlasRequest::Dscal { alpha: 1.7, x: x.clone() },
            BlasRequest::Daxpy { alpha: -0.8, x: x.clone(), y: y.clone() },
            BlasRequest::Ddot { x: x.clone(), y: y.clone() },
            BlasRequest::Dnrm2 { x: x.clone() },
            BlasRequest::Dasum { x: x.clone() },
            BlasRequest::Dgemv { alpha: 1.1, a: a.clone(), x: x.clone(),
                                 beta: 0.3, y: y.clone() },
            BlasRequest::Dtrsv { a: l.clone(), b: x.clone() },
            BlasRequest::Dgemm { alpha: 0.9, a: a.clone(), b: b.clone(),
                                 beta: 0.5, c: c.clone() },
            BlasRequest::Dsymm { alpha: 1.2, a: a.clone(), b: b.clone(),
                                 beta: 0.4, c: c.clone() },
            BlasRequest::Dtrmm { alpha: 0.7, a: l.clone(), b: b.clone() },
            BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
            BlasRequest::Dsyrk { alpha: 1.0, a: a.clone(), beta: 0.2,
                                 c: c.clone() },
        ]
    }

    #[test]
    fn every_variant_matches_oracle_unprotected() {
        check("router-native-matrix", 6, |g| {
            let n = 16 + 8 * g.rng.below(5);
            for req in sample_requests(&mut g.rng, n) {
                let want = oracle(&req);
                for v in [Impl::Blocked, Impl::Tuned] {
                    let got = execute_native(&req, v, &Profile::default(),
                                             FtPolicy::None, None);
                    ensure(close(&got.result, &want.result, 1e-8),
                           format!("{} [{}]", req.routine(), v.name()))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn hybrid_policy_matches_oracle_clean() {
        check("router-hybrid-clean", 4, |g| {
            let n = 16 + 8 * g.rng.below(4);
            for req in sample_requests(&mut g.rng, n) {
                let want = oracle(&req);
                let got = execute_native(&req, Impl::Tuned, &Profile::default(),
                                         FtPolicy::Hybrid, None);
                ensure(got.ft.errors_detected == 0,
                       format!("{}: spurious detection", req.routine()))?;
                ensure(close(&got.result, &want.result, 1e-8),
                       format!("{} hybrid mismatch", req.routine()))?;
            }
            Ok(())
        });
    }

    #[test]
    fn hybrid_policy_corrects_injected_faults() {
        check("router-hybrid-inject", 6, |g| {
            let n = 32;
            let reqs = sample_requests(&mut g.rng, n);
            for req in reqs {
                // dasum/dsyrk have no FT path (paper doesn't protect them)
                if matches!(req, BlasRequest::Dasum { .. } | BlasRequest::Dsyrk { .. }) {
                    continue;
                }
                let want = oracle(&req);
                let fault = Fault {
                    step: 1 + g.rng.below(2),
                    i: g.rng.below(8),
                    j: g.rng.below(n),
                    delta: g.rng.range(10.0, 1e5),
                };
                let got = execute_native(&req, Impl::Tuned, &Profile::default(),
                                         FtPolicy::Hybrid, Some(fault));
                ensure(got.ft.errors_detected >= 1,
                       format!("{}: fault not detected", req.routine()))?;
                ensure(close(&got.result, &want.result, 1e-7),
                       format!("{}: fault not corrected", req.routine()))?;
            }
            Ok(())
        });
    }
}
