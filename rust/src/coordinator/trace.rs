//! Workload traces: Poisson-arrival mixed-routine request streams for the
//! end-to-end driver and the serving benches (DESIGN.md §6). An optional
//! [`Burst`] overlay makes arrivals bursty (an on/off modulated Poisson
//! process) to exercise the serving tier's queue-depth admission control
//! — shedding only shows up when arrivals outrun the drain rate.

use crate::coordinator::request::BlasRequest;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Mix weights over routine families (normalized internally).
#[derive(Clone, Debug)]
pub struct Mix {
    /// Weight of DSCAL requests (Level-1).
    pub dscal: f64,
    /// Weight of DDOT requests (Level-1).
    pub ddot: f64,
    /// Weight of DNRM2 requests (Level-1).
    pub dnrm2: f64,
    /// Weight of DGEMV requests (Level-2).
    pub dgemv: f64,
    /// Weight of DTRSV requests (Level-2).
    pub dtrsv: f64,
    /// Weight of DGEMM requests (Level-3).
    pub dgemm: f64,
    /// Weight of DTRSM requests (Level-3).
    pub dtrsm: f64,
}

impl Default for Mix {
    fn default() -> Self {
        // a solver-ish mix: mostly L1/L2 with periodic L3 heavy hitters
        Mix { dscal: 0.2, ddot: 0.2, dnrm2: 0.1, dgemv: 0.2, dtrsv: 0.1,
              dgemm: 0.15, dtrsm: 0.05 }
    }
}

/// Bursty-arrival overlay: every `period` requests, the first `len`
/// arrive at `factor × rate` (the on phase), the rest at the base rate.
/// A deterministic on/off modulated Poisson process — the serving tier
/// sees recurring arrival spikes that saturate a low admission
/// watermark while the average rate stays moderate.
#[derive(Clone, Debug)]
pub struct Burst {
    /// Requests per on/off cycle.
    pub period: usize,
    /// Leading requests of each cycle that arrive at the burst rate.
    pub len: usize,
    /// Arrival-rate multiplier during the on phase (> 1 = burstier).
    pub factor: f64,
}

impl Default for Burst {
    fn default() -> Self {
        // half of each cycle arrives ~50× faster than the base rate
        Burst { period: 16, len: 8, factor: 50.0 }
    }
}

impl Burst {
    /// Parse a named arrival pattern (the CLI's `--trace` flag):
    /// `"steady"` is plain Poisson arrivals (no overlay), `"burst"` the
    /// default on/off overlay. Unknown names are an error, listing the
    /// accepted values. Shape names that also change the request mix
    /// (e.g. `small-gemm`) parse through [`TraceShape::from_name`].
    pub fn from_pattern(name: &str) -> Result<Option<Burst>, String> {
        match name {
            "steady" => Ok(None),
            "burst" => Ok(Some(Burst::default())),
            other => Err(format!(
                "unknown trace pattern `{other}` (want steady|burst)")),
        }
    }
}

/// A named workload shape for the CLI's `--trace` flag. `Steady` and
/// `Burst` only set the arrival pattern; `SmallGemm` additionally
/// overrides the mix and dimensions to the batched small-GEMM serving
/// workload: an all-DGEMM stream of two small shapes (both under the
/// registry's batch ceiling and both resolving to the same planned
/// kernel) arriving in bursts, so the server's kernel-keyed batcher
/// repeatedly drains multi-item groups that fuse into single
/// batched-kernel calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceShape {
    /// Plain Poisson arrivals, default mix.
    Steady,
    /// Default on/off burst overlay, default mix.
    Burst,
    /// Bursty all-small-DGEMM stream exercising batch fusion.
    SmallGemm,
}

impl TraceShape {
    /// Parse a shape name: `steady`, `burst`, or `small-gemm`.
    pub fn from_name(name: &str) -> Result<TraceShape, String> {
        match name {
            "steady" => Ok(TraceShape::Steady),
            "burst" => Ok(TraceShape::Burst),
            "small-gemm" => Ok(TraceShape::SmallGemm),
            other => Err(format!(
                "unknown trace shape `{other}` (want steady|burst|small-gemm)"
            )),
        }
    }

    /// CLI/report name of the shape.
    pub fn name(&self) -> &'static str {
        match self {
            TraceShape::Steady => "steady",
            TraceShape::Burst => "burst",
            TraceShape::SmallGemm => "small-gemm",
        }
    }

    /// Apply the shape to a base config. `Steady`/`Burst` leave the mix
    /// and dimensions alone; `SmallGemm` replaces them with the batched
    /// small-GEMM workload (dims 32/24 — both clear the threaded
    /// planner's MR floor and sit under the batch ceiling, so every
    /// request shares one planned kernel and every drained group is
    /// fusable).
    pub fn apply(&self, mut cfg: TraceConfig) -> TraceConfig {
        cfg.burst = match self {
            TraceShape::Steady => None,
            TraceShape::Burst | TraceShape::SmallGemm => Some(Burst::default()),
        };
        if let TraceShape::SmallGemm = self {
            cfg.mix = Mix { dscal: 0.0, ddot: 0.0, dnrm2: 0.0, dgemv: 0.0,
                            dtrsv: 0.0, dgemm: 1.0, dtrsm: 0.0 };
            cfg.mat_dim = 32;
            cfg.mat_dim_alt = Some(24);
        }
        cfg
    }
}

/// Trace generation config.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// RNG seed; traces are fully deterministic given the config.
    pub seed: u64,
    /// Number of requests to generate.
    pub requests: usize,
    /// mean arrival rate (requests/second) for the Poisson process
    pub rate: f64,
    /// Routine-family mix weights.
    pub mix: Mix,
    /// vector length for L1 routines
    pub vec_len: usize,
    /// matrix dimension for L2/L3 routines
    pub mat_dim: usize,
    /// Optional second DGEMM dimension, alternated with `mat_dim`.
    /// Two shapes that clear the planner's MT floor resolve to the same
    /// kernel, so this exercises the server's planned-kernel batching
    /// (shapes share a batch window when their plans agree).
    pub mat_dim_alt: Option<usize>,
    /// Optional bursty-arrival overlay (None = plain Poisson arrivals).
    pub burst: Option<Burst>,
}

impl TraceConfig {
    /// Size the trace so a paced replay lasts roughly `secs` of
    /// wall-clock at the configured base arrival rate — how the soak
    /// driver turns a `--duration` into a request count. A burst
    /// overlay compresses on-phase gaps, so a bursty replay finishes
    /// somewhat *faster* than the nominal duration (the overlay
    /// modulates rate upward, never below the base).
    pub fn sized_for(mut self, secs: f64) -> TraceConfig {
        self.requests = (secs.max(0.0) * self.rate).ceil().max(1.0) as usize;
        self
    }
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0x7ACE,
            requests: 200,
            rate: 200.0,
            mix: Mix::default(),
            vec_len: 65536,
            mat_dim: 256,
            mat_dim_alt: None,
            burst: None,
        }
    }
}

/// One trace entry: the request plus its arrival offset from t=0.
pub struct TraceEntry {
    /// Arrival time, seconds after the trace starts.
    pub at_seconds: f64,
    /// The request arriving at that instant.
    pub request: BlasRequest,
}

/// Generate a deterministic trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceEntry> {
    let mut rng = Rng::new(cfg.seed);
    let m = &cfg.mix;
    let weights = [m.dscal, m.ddot, m.dnrm2, m.dgemv, m.dtrsv, m.dgemm, m.dtrsm];
    let total: f64 = weights.iter().sum();
    // pre-generate shared operands so trace generation stays cheap
    let a = Matrix::random(cfg.mat_dim, cfg.mat_dim, &mut rng);
    let b = Matrix::random(cfg.mat_dim, cfg.mat_dim, &mut rng);
    let c = Matrix::random(cfg.mat_dim, cfg.mat_dim, &mut rng);
    let l = Matrix::random_lower_triangular(cfg.mat_dim, &mut rng);
    let alt = cfg.mat_dim_alt.map(|d| {
        (Matrix::random(d, d, &mut rng), Matrix::random(d, d, &mut rng),
         Matrix::random(d, d, &mut rng))
    });

    let mut t = 0.0;
    let mut out = Vec::with_capacity(cfg.requests);
    for i in 0..cfg.requests {
        let rate = match &cfg.burst {
            Some(b) if b.period > 0 && i % b.period < b.len => {
                cfg.rate * b.factor.max(f64::MIN_POSITIVE)
            }
            _ => cfg.rate,
        };
        t += rng.exponential(rate);
        let mut pick = rng.uniform() * total;
        let mut idx = 0;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                idx = i;
                break;
            }
            pick -= w;
            idx = i;
        }
        let request = match idx {
            0 => BlasRequest::Dscal {
                alpha: rng.range(0.5, 2.0),
                x: rng.normal_vec(cfg.vec_len),
            },
            1 => BlasRequest::Ddot {
                x: rng.normal_vec(cfg.vec_len),
                y: rng.normal_vec(cfg.vec_len),
            },
            2 => BlasRequest::Dnrm2 { x: rng.normal_vec(cfg.vec_len) },
            3 => BlasRequest::Dgemv {
                alpha: 1.0,
                a: a.clone(),
                x: rng.normal_vec(cfg.mat_dim),
                beta: rng.range(0.0, 1.0),
                y: rng.normal_vec(cfg.mat_dim),
            },
            4 => BlasRequest::Dtrsv { a: l.clone(), b: rng.normal_vec(cfg.mat_dim) },
            5 => match &alt {
                Some((aa, ab, ac)) if rng.uniform() < 0.5 => {
                    BlasRequest::Dgemm {
                        alpha: 1.0,
                        a: aa.clone(),
                        b: ab.clone(),
                        beta: 0.0,
                        c: ac.clone(),
                    }
                }
                _ => BlasRequest::Dgemm {
                    alpha: 1.0,
                    a: a.clone(),
                    b: b.clone(),
                    beta: 0.0,
                    c: c.clone(),
                },
            },
            _ => BlasRequest::Dtrsm { a: l.clone(), b: b.clone() },
        };
        out.push(TraceEntry { at_seconds: t, request });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = TraceConfig { requests: 50, vec_len: 64, mat_dim: 16,
                                ..Default::default() };
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_seconds, y.at_seconds);
            assert_eq!(x.request.routine(), y.request.routine());
        }
    }

    #[test]
    fn arrival_times_increase() {
        let cfg = TraceConfig { requests: 100, vec_len: 32, mat_dim: 8,
                                ..Default::default() };
        let t = generate(&cfg);
        assert!(t.windows(2).all(|w| w[0].at_seconds <= w[1].at_seconds));
    }

    #[test]
    fn alt_dim_splits_dgemm_shapes() {
        let cfg = TraceConfig {
            requests: 400,
            vec_len: 8,
            mat_dim: 16,
            mat_dim_alt: Some(32),
            mix: Mix { dscal: 0.0, ddot: 0.0, dnrm2: 0.0, dgemv: 0.0,
                       dtrsv: 0.0, dgemm: 1.0, dtrsm: 0.0 },
            ..Default::default()
        };
        let t = generate(&cfg);
        let alt = t.iter().filter(|e| e.request.dim() == 32).count();
        let base = t.iter().filter(|e| e.request.dim() == 16).count();
        assert_eq!(alt + base, 400);
        assert!(alt > 100 && base > 100, "both shapes present: {alt}/{base}");
    }

    #[test]
    fn burst_overlay_compresses_on_phase_gaps() {
        let base = TraceConfig { requests: 400, vec_len: 8, mat_dim: 8,
                                 rate: 100.0, ..Default::default() };
        let burst = Burst { period: 10, len: 5, factor: 100.0 };
        let cfg = TraceConfig { burst: Some(burst.clone()), ..base.clone() };
        let t = generate(&cfg);
        // request i's arrival gap was drawn at the rate phase i selects
        let mut on = Vec::new();
        let mut off = Vec::new();
        let mut prev = 0.0;
        for (i, e) in t.iter().enumerate() {
            let gap = e.at_seconds - prev;
            prev = e.at_seconds;
            if i % burst.period < burst.len {
                on.push(gap);
            } else {
                off.push(gap);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert_eq!(on.len(), 200);
        assert_eq!(off.len(), 200);
        // 100× rate ⇒ ~100× tighter gaps; 10× leaves generous slack
        assert!(mean(&on) * 10.0 < mean(&off),
                "burst gaps not compressed: on={} off={}", mean(&on),
                mean(&off));
        // the overlay only modulates arrival times, never the mix
        let plain = generate(&base);
        for (a, b) in t.iter().zip(&plain) {
            assert_eq!(a.request.routine(), b.request.routine());
        }
    }

    #[test]
    fn sized_for_matches_duration_times_rate() {
        let cfg = TraceConfig { rate: 50.0, ..Default::default() }
            .sized_for(4.0);
        assert_eq!(cfg.requests, 200);
        // degenerate durations still produce a non-empty trace
        assert_eq!(TraceConfig::default().sized_for(0.0).requests, 1);
        assert_eq!(TraceConfig::default().sized_for(-3.0).requests, 1);
    }

    #[test]
    fn named_patterns_parse() {
        assert!(Burst::from_pattern("steady").unwrap().is_none());
        let b = Burst::from_pattern("burst").unwrap().unwrap();
        assert_eq!(b.period, Burst::default().period);
        assert!(Burst::from_pattern("storm").is_err());
    }

    /// The small-GEMM shape: every request is a DGEMM at one of the two
    /// small dims, arrivals are bursty, and names round-trip.
    #[test]
    fn small_gemm_shape_is_an_all_small_dgemm_burst() {
        for (name, shape) in [("steady", TraceShape::Steady),
                              ("burst", TraceShape::Burst),
                              ("small-gemm", TraceShape::SmallGemm)] {
            let s = TraceShape::from_name(name).unwrap();
            assert_eq!(s, shape);
            assert_eq!(s.name(), name);
        }
        assert!(TraceShape::from_name("tiny").is_err());
        let cfg = TraceShape::SmallGemm
            .apply(TraceConfig { requests: 200, ..Default::default() });
        assert!(cfg.burst.is_some(), "small-gemm arrivals are bursty");
        let t = generate(&cfg);
        assert_eq!(t.len(), 200);
        assert!(t.iter().all(|e| e.request.routine() == "dgemm"));
        let d32 = t.iter().filter(|e| e.request.dim() == 32).count();
        let d24 = t.iter().filter(|e| e.request.dim() == 24).count();
        assert_eq!(d32 + d24, 200, "only the two small shapes appear");
        assert!(d32 > 0 && d24 > 0, "both shapes present: {d32}/{d24}");
        // steady/burst leave the mix and dims untouched
        let base = TraceConfig::default();
        let kept = TraceShape::Burst.apply(base.clone());
        assert_eq!(kept.mat_dim, base.mat_dim);
        assert!(TraceShape::Steady.apply(base).burst.is_none());
    }

    #[test]
    fn mix_respected_roughly() {
        let cfg = TraceConfig {
            requests: 2000,
            vec_len: 8,
            mat_dim: 8,
            mix: Mix { dscal: 1.0, ddot: 0.0, dnrm2: 0.0, dgemv: 0.0,
                       dtrsv: 0.0, dgemm: 1.0, dtrsm: 0.0 },
            ..Default::default()
        };
        let t = generate(&cfg);
        let gemm = t.iter().filter(|e| e.request.routine() == "dgemm").count();
        assert!((800..1200).contains(&gemm), "gemm count {gemm}");
    }
}
