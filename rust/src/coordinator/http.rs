//! Minimal HTTP/1.1 wire layer for the gateway (no hyper/tokio in this
//! offline image; see DESIGN.md §9). The parser is a **pure function
//! over byte slices** — no sockets, no allocator tricks — so the
//! conformance proptests can feed it arbitrary byte prefixes and prove
//! it never panics: truncated input reports "incomplete", oversized
//! request lines and header blocks hit hard size caps (mapped to `431`
//! on the wire), and everything else malformed degrades to a typed
//! error (mapped to `400`). The blocking socket helpers
//! ([`read_request`], [`Response::write_to`], [`fetch`]) are thin
//! adapters over the pure core.
//!
//! Scope is deliberately narrow — exactly what the gateway's protocol
//! (`docs/PROTOCOL.md`) needs: one request per connection
//! (`Connection: close`), `Content-Length` bodies only (chunked
//! transfer encoding is rejected as unsupported), no continuation
//! lines, no trailers.

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Hard cap on the request head (request line + headers + blank line).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Hard cap on any single line in the head.
pub const MAX_LINE_BYTES: usize = 4 * 1024;
/// Hard cap on the number of header fields.
pub const MAX_HEADERS: usize = 64;
/// Hard cap on a request body (`Content-Length` above this is refused).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Typed parse failure, carrying its wire status.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// A size cap was exceeded (`431 Request Header Fields Too Large`).
    TooLarge(&'static str),
    /// The declared body exceeds [`MAX_BODY_BYTES`] (`413`).
    BodyTooLarge(usize),
    /// Anything else malformed (`400 Bad Request`).
    Malformed(String),
}

impl ParseError {
    /// The HTTP status this error maps to.
    pub fn status(&self) -> u16 {
        match self {
            ParseError::TooLarge(_) => 431,
            ParseError::BodyTooLarge(_) => 413,
            ParseError::Malformed(_) => 400,
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::TooLarge(what) => {
                write!(f, "{what} exceeds the size cap")
            }
            ParseError::BodyTooLarge(n) => {
                write!(f, "declared body of {n} bytes exceeds the \
                           {MAX_BODY_BYTES}-byte cap")
            }
            ParseError::Malformed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// A parsed request head: the request line plus header fields. Bodies
/// are read separately, by declared `Content-Length`.
#[derive(Clone, Debug)]
pub struct Head {
    /// Request method, as sent (methods are case-sensitive tokens).
    pub method: String,
    /// Request target (origin form, e.g. `/healthz`).
    pub target: String,
    /// Header fields in arrival order (names lowercased).
    pub headers: Vec<(String, String)>,
}

impl Head {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }

    /// The declared body length: 0 when absent, an error when
    /// unparseable, smuggling-shaped, or above [`MAX_BODY_BYTES`].
    /// Strict per RFC 9110: the value must be ASCII digits only (no
    /// sign, no surprises `usize::parse` would take), and duplicate
    /// `Content-Length` fields must all agree — a disagreeing pair is
    /// refused rather than silently resolved to the first.
    pub fn content_length(&self) -> Result<usize, ParseError> {
        let mut declared: Option<&str> = None;
        for (k, v) in &self.headers {
            if k != "content-length" {
                continue;
            }
            match declared {
                Some(prev) if prev != v.as_str() => {
                    return Err(ParseError::Malformed(format!(
                        "conflicting content-length fields `{prev}` and \
                         `{v}`")));
                }
                _ => declared = Some(v),
            }
        }
        match declared {
            None => Ok(0),
            Some(v) => {
                if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                    return Err(ParseError::Malformed(format!(
                        "content-length `{v}` is not a plain decimal")));
                }
                let n: usize = v.parse().map_err(|_| {
                    ParseError::Malformed(format!(
                        "unparseable content-length `{v}`"))
                })?;
                if n > MAX_BODY_BYTES {
                    return Err(ParseError::BodyTooLarge(n));
                }
                Ok(n)
            }
        }
    }
}

/// Is `b` a valid RFC 9110 token byte (method / header-name alphabet)?
fn is_token_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric()
        || matches!(b, b'!' | b'#' | b'$' | b'%' | b'&' | b'\'' | b'*'
                       | b'+' | b'-' | b'.' | b'^' | b'_' | b'`' | b'|'
                       | b'~')
}

/// Take the next line out of `buf` starting at `*pos`: bytes up to the
/// next LF, with one trailing CR stripped. `Ok(None)` = no complete
/// line yet (with the line-length cap enforced against the unterminated
/// tail, so a byte stream that never sends LF still terminates).
fn next_line<'b>(buf: &'b [u8], pos: &mut usize)
                 -> Result<Option<&'b [u8]>, ParseError> {
    let rest = &buf[*pos..];
    match rest.iter().position(|&b| b == b'\n') {
        Some(nl) => {
            if nl > MAX_LINE_BYTES {
                return Err(ParseError::TooLarge("header line"));
            }
            let mut line = &rest[..nl];
            if line.last() == Some(&b'\r') {
                line = &line[..line.len() - 1];
            }
            *pos += nl + 1;
            Ok(Some(line))
        }
        None if rest.len() > MAX_LINE_BYTES => {
            Err(ParseError::TooLarge("header line"))
        }
        None => Ok(None),
    }
}

/// Parse a request head from the front of `buf`.
///
/// - `Ok(Some((head, consumed)))` — a complete head; the body (if any)
///   starts at `buf[consumed..]`.
/// - `Ok(None)` — the head is incomplete; read more bytes and retry.
/// - `Err(_)` — the prefix can never become a valid head (size caps
///   and grammar violations are detected as early as possible, so a
///   malicious peer cannot buy buffering with garbage).
///
/// Total function over arbitrary bytes: no panic, no unbounded work.
pub fn parse_head(buf: &[u8]) -> Result<Option<(Head, usize)>, ParseError> {
    let mut pos = 0;
    let request_line = match next_line(buf, &mut pos)? {
        Some(line) => line,
        None => return Ok(None),
    };
    let line = std::str::from_utf8(request_line).map_err(|_| {
        ParseError::Malformed("request line is not UTF-8".into())
    })?;
    let mut parts = line.split(' ');
    let (method, target, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v), None) => (m, t, v),
            _ => {
                return Err(ParseError::Malformed(format!(
                    "malformed request line `{line}`")))
            }
        };
    if method.is_empty() || !method.bytes().all(is_token_byte) {
        return Err(ParseError::Malformed(format!(
            "malformed method `{method}`")));
    }
    if !target.starts_with('/') {
        return Err(ParseError::Malformed(format!(
            "request target `{target}` is not origin-form")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(ParseError::Malformed(format!(
            "unsupported protocol `{version}`")));
    }
    let mut headers = Vec::new();
    loop {
        if pos > MAX_HEAD_BYTES {
            return Err(ParseError::TooLarge("request head"));
        }
        let line = match next_line(buf, &mut pos)? {
            Some(line) => line,
            None if buf.len() > MAX_HEAD_BYTES => {
                return Err(ParseError::TooLarge("request head"));
            }
            None => return Ok(None),
        };
        if line.is_empty() {
            let head = Head {
                method: method.to_string(),
                target: target.to_string(),
                headers,
            };
            return Ok(Some((head, pos)));
        }
        if headers.len() >= MAX_HEADERS {
            return Err(ParseError::TooLarge("header count"));
        }
        let text = std::str::from_utf8(line).map_err(|_| {
            ParseError::Malformed("header line is not UTF-8".into())
        })?;
        let (name, value) = text.split_once(':').ok_or_else(|| {
            ParseError::Malformed(format!("header line `{text}` has no ':'"))
        })?;
        if name.is_empty() || !name.bytes().all(is_token_byte) {
            return Err(ParseError::Malformed(format!(
                "malformed header name `{name}`")));
        }
        headers.push((name.to_ascii_lowercase(),
                      value.trim().to_string()));
    }
}

/// Read failure on the blocking server path: transport errors abort the
/// connection silently, parse errors get a wire response.
#[derive(Debug)]
pub enum ReadError {
    /// The socket failed mid-read (peer reset, timeout).
    Io(std::io::Error),
    /// The bytes can never form a valid request.
    Parse(ParseError),
    /// The peer closed before completing the head (no response owed).
    Closed,
}

/// Total wall-clock budget for reading one request. The per-read socket
/// timeout alone is not enough: a slowloris peer trickling one byte per
/// read could hold a worker for hours inside the size caps, so elapsed
/// time is checked across reads and the whole request aborted past this
/// deadline.
pub const READ_BUDGET: Duration = Duration::from_secs(10);

/// Blocking server-side read of one full request (head + body) from a
/// stream, under the module's size caps and the [`READ_BUDGET`]
/// wall-clock deadline. Chunked transfer encoding is rejected — the
/// protocol uses `Content-Length` bodies only.
pub fn read_request<R: Read>(stream: &mut R)
                             -> Result<(Head, Vec<u8>), ReadError> {
    read_request_within(stream, READ_BUDGET)
}

/// [`read_request`] with an explicit wall-clock budget (tests pin the
/// slowloris abort without waiting out the real deadline).
pub fn read_request_within<R: Read>(stream: &mut R, budget: Duration)
                                    -> Result<(Head, Vec<u8>), ReadError> {
    let started = Instant::now();
    let overdue = |started: Instant| {
        ReadError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            format!("request not complete after {:?} (budget {budget:?})",
                    started.elapsed())))
    };
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 2048];
    let (head, consumed) = loop {
        match parse_head(&buf).map_err(ReadError::Parse)? {
            Some(parsed) => break parsed,
            None => {
                if started.elapsed() >= budget {
                    return Err(overdue(started));
                }
                let n = stream.read(&mut chunk).map_err(ReadError::Io)?;
                if n == 0 {
                    return Err(ReadError::Closed);
                }
                buf.extend_from_slice(&chunk[..n]);
            }
        }
    };
    if head.header("transfer-encoding").is_some() {
        return Err(ReadError::Parse(ParseError::Malformed(
            "chunked transfer encoding is not supported (send a \
             content-length body)".into())));
    }
    let want = head.content_length().map_err(ReadError::Parse)?;
    let mut body = buf[consumed..].to_vec();
    while body.len() < want {
        if started.elapsed() >= budget {
            return Err(overdue(started));
        }
        let n = stream.read(&mut chunk).map_err(ReadError::Io)?;
        if n == 0 {
            return Err(ReadError::Parse(ParseError::Malformed(format!(
                "body truncated at {} of {want} declared bytes",
                body.len()))));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(want);
    Ok((head, body))
}

/// The reason phrase for every status the gateway emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// An outgoing response; `write_to` stamps `Content-Length` and
/// `Connection: close` itself.
#[derive(Clone, Debug)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// Extra header fields (content-length/connection are automatic).
    pub headers: Vec<(String, String)>,
    /// Body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// An empty response with `status`.
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    /// A JSON-bodied response.
    pub fn json(status: u16, doc: &Json) -> Response {
        let mut r = Response::new(status);
        r.headers.push(("content-type".into(), "application/json".into()));
        r.body = doc.render().into_bytes();
        r
    }

    /// Append a header field (builder-style).
    pub fn header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    /// Serialize onto a stream (one response, then the connection
    /// closes — the protocol is single-request).
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut head = format!("HTTP/1.1 {} {}\r\n", self.status,
                               reason(self.status));
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("content-length: {}\r\n", self.body.len()));
        head.push_str("connection: close\r\n\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// A response as seen by the loopback client.
#[derive(Clone, Debug)]
pub struct WireResponse {
    /// Status code from the status line.
    pub status: u16,
    /// Header fields (names lowercased).
    pub headers: Vec<(String, String)>,
    /// Body bytes, decoded as UTF-8 (the gateway only emits JSON).
    pub body: String,
}

impl WireResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == want)
            .map(|(_, v)| v.as_str())
    }
}

fn invalid(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

/// Minimal blocking HTTP client for the conformance tests and the
/// `gateway --self-check` smoke: one request, read to EOF (the server
/// always closes), parse the status line + headers + body.
pub fn fetch(addr: &str, method: &str, path: &str, body: Option<&str>)
             -> std::io::Result<WireResponse> {
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nhost: {addr}\r\n\
         content-type: application/json\r\ncontent-length: {}\r\n\
         connection: close\r\n\r\n{body}",
        body.len());
    stream.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8(raw)
        .map_err(|_| invalid("response is not UTF-8"))?;
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| invalid("response has no head/body separator"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| invalid(format!("bad status line `{status_line}`")))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(k, v)| (k.to_ascii_lowercase(), v.trim().to_string()))
        .collect();
    Ok(WireResponse { status, headers, body: body.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_complete_head() {
        let raw = b"POST /v1/blas HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 2\r\n\r\n{}";
        let (head, consumed) = parse_head(raw).unwrap().unwrap();
        assert_eq!(head.method, "POST");
        assert_eq!(head.target, "/v1/blas");
        assert_eq!(head.header("HOST"), Some("x"));
        assert_eq!(head.content_length().unwrap(), 2);
        assert_eq!(&raw[consumed..], b"{}");
    }

    #[test]
    fn incomplete_heads_ask_for_more() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: x\r\n";
        for cut in 0..raw.len() {
            assert!(parse_head(&raw[..cut]).unwrap().is_none(),
                    "prefix of {cut} bytes should be incomplete");
        }
        assert!(parse_head(b"").unwrap().is_none());
    }

    #[test]
    fn lone_lf_line_endings_parse_too() {
        let raw = b"GET / HTTP/1.1\nhost: x\n\n";
        let (head, consumed) = parse_head(raw).unwrap().unwrap();
        assert_eq!(head.target, "/");
        assert_eq!(consumed, raw.len());
    }

    #[test]
    fn malformed_heads_are_typed_400s() {
        for bad in [&b"NOT A REQUEST LINE AT ALL\r\n\r\n"[..],
                    b"GET /\r\n\r\n",
                    b"GET / HTTP/2.0\r\n\r\n",
                    b"GET noslash HTTP/1.1\r\n\r\n",
                    b"G@T / HTTP/1.1\r\n\r\n",
                    b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n",
                    b"GET / HTTP/1.1\r\n: empty-name\r\n\r\n"] {
            let err = parse_head(bad).unwrap_err();
            assert_eq!(err.status(), 400, "{err} for {bad:?}");
        }
    }

    #[test]
    fn size_caps_map_to_431() {
        let long_line = vec![b'a'; MAX_LINE_BYTES + 2];
        assert_eq!(parse_head(&long_line).unwrap_err().status(), 431);
        let mut many = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..MAX_HEADERS + 1 {
            many.extend_from_slice(format!("h{i}: v\r\n").as_bytes());
        }
        many.extend_from_slice(b"\r\n");
        assert_eq!(parse_head(&many).unwrap_err().status(), 431);
    }

    #[test]
    fn oversized_bodies_are_refused_up_front() {
        let raw = format!("POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
                          MAX_BODY_BYTES + 1);
        let (head, _) = parse_head(raw.as_bytes()).unwrap().unwrap();
        assert_eq!(head.content_length().unwrap_err().status(), 413);
    }

    #[test]
    fn content_length_is_digits_only() {
        // header values arrive OWS-trimmed from parse_head, so inner
        // junk is what this guard must catch (not surrounding spaces)
        for bad in ["+2", "-1", "0x10", "1_0", "2.0", "1 2", ""] {
            let raw = format!(
                "POST / HTTP/1.1\r\ncontent-length:{bad}\r\n\r\n");
            let (head, _) = parse_head(raw.as_bytes()).unwrap().unwrap();
            let err = head.content_length().unwrap_err();
            assert_eq!(err.status(), 400, "`{bad}` gave {err}");
        }
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 42\r\n\r\n";
        let (head, _) = parse_head(raw).unwrap().unwrap();
        assert_eq!(head.content_length().unwrap(), 42);
    }

    #[test]
    fn conflicting_content_lengths_are_refused() {
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\
                    content-length: 3\r\n\r\n";
        let (head, _) = parse_head(raw).unwrap().unwrap();
        let err = head.content_length().unwrap_err();
        assert_eq!(err.status(), 400);
        assert!(err.to_string().contains("conflicting"), "{err}");
        // duplicates that agree are harmless
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\
                    content-length: 2\r\n\r\n";
        let (head, _) = parse_head(raw).unwrap().unwrap();
        assert_eq!(head.content_length().unwrap(), 2);
    }

    /// A reader that yields one byte per read() forever — the slowloris
    /// shape the wall-clock budget exists to abort.
    struct Trickle(u8);

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            buf[0] = self.0;
            Ok(1)
        }
    }

    #[test]
    fn slowloris_reads_abort_on_the_wall_clock_budget() {
        // an exhausted budget aborts even though the peer keeps sending
        let err = read_request_within(&mut Trickle(b'G'), Duration::ZERO)
            .unwrap_err();
        match err {
            ReadError::Io(e) => {
                assert_eq!(e.kind(), std::io::ErrorKind::TimedOut)
            }
            other => panic!("want a timed-out Io error, got {other:?}"),
        }
        // a sane budget still reads a prompt request in full
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\r\n{}";
        let (head, body) =
            read_request_within(&mut &raw[..], Duration::from_secs(5))
                .unwrap();
        assert_eq!(head.method, "POST");
        assert_eq!(body, b"{}");
    }

    #[test]
    fn responses_round_trip_through_the_client_parser() {
        let doc = Json::obj().field("ok", Json::Bool(true));
        let mut wire = Vec::new();
        Response::json(429, &doc)
            .header("retry-after", "1")
            .write_to(&mut wire)
            .unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("retry-after: 1\r\n"));
        assert!(text.ends_with("{\"ok\":true}"));
    }
}
