//! PJRT backend: maps typed BLAS requests onto the AOT artifacts and
//! interprets their outputs, including the Rust half of the online ABFT
//! control loop (verify → locate → correct per rank-k step).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::coordinator::executor::{OwnedArg, PjrtHandle};
use crate::coordinator::request::{Backend, BlasRequest, BlasResponse, BlasResult, Level};
use crate::ft::abft::{self, ChecksumState};
use crate::ft::injector::Fault;
use crate::ft::policy::FtPolicy;
use crate::ft::FtReport;
use crate::runtime::manifest::Manifest;
use crate::util::matrix::Matrix;

/// The backend: a handle to the executor thread plus its own parsed
/// manifest copy for routing decisions.
pub struct PjrtBackend {
    handle: PjrtHandle,
    manifest: Manifest,
}

fn inj3(f: Option<Fault>) -> OwnedArg {
    OwnedArg::Vec1(crate::ft::injector::to_inject3(f).to_vec())
}

fn inj4(f: Option<Fault>) -> OwnedArg {
    OwnedArg::Vec1(crate::ft::injector::to_inject4(f).to_vec())
}

fn inj4_step_row(f: Option<Fault>) -> OwnedArg {
    // the dtrsv_dmr kernel wants [flag, step, row, delta]
    let v = match f {
        Some(f) => vec![1.0, f.step as f64, f.i as f64, f.delta],
        None => vec![0.0; 4],
    };
    OwnedArg::Vec1(v)
}

fn inj5(f: Option<Fault>) -> OwnedArg {
    OwnedArg::Vec1(crate::ft::injector::to_inject5(f).to_vec())
}

impl PjrtBackend {
    /// Build the backend from an executor handle and its manifest
    /// directory.
    pub fn new(handle: PjrtHandle, artifact_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(PjrtBackend { handle, manifest })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn variant_for(&self, req: &BlasRequest, policy: FtPolicy) -> &'static str {
        match (policy, req.level(), req.routine()) {
            (FtPolicy::None, _, _) => "ori",
            (_, Level::L1, _) | (_, Level::L2, "dgemv") => "dmr",
            (_, Level::L2, "dtrsv") => "dmr",
            (FtPolicy::Hybrid, Level::L3, "dtrsm") => "ft",
            (FtPolicy::Hybrid, Level::L3, _) => "abft",
            // unfused ABFT runs the unprotected artifact + Rust checksums
            (FtPolicy::AbftUnfused, Level::L3, _) => "ori",
            _ => "ori",
        }
    }

    /// Can this request be served by an artifact (shape-specialized)?
    pub fn supports(&self, req: &BlasRequest, policy: FtPolicy) -> bool {
        // no artifact implements the weighted-checksum encoding; the
        // router falls back to the native registry kernel for it
        if policy == FtPolicy::AbftWeighted && req.level() == Level::L3 {
            return false;
        }
        let variant = self.variant_for(req, policy);
        self.manifest.find_n(req.routine(), variant, req.dim()).is_some()
    }

    /// Health probe for the `/backends` report: the backend is healthy
    /// exactly when its manifest resolved at least one artifact spec.
    pub fn health(&self) -> String {
        let specs = self.manifest.specs.len();
        if specs == 0 {
            "unavailable: manifest lists no artifact specs".to_string()
        } else {
            format!("healthy: {specs} artifact specs loaded")
        }
    }

    /// Pre-compile every artifact a request mix will touch.
    pub fn warmup_all(&self) -> Result<()> {
        for s in &self.manifest.specs {
            self.handle.warmup(&s.name)?;
        }
        Ok(())
    }

    /// Execute under a policy, with an optional planned fault.
    pub fn execute(&self, req: &BlasRequest, policy: FtPolicy,
                   fault: Option<Fault>) -> Result<BlasResponse> {
        let t0 = std::time::Instant::now();
        let (result, ft) = self.dispatch(req, policy, fault)?;
        Ok(BlasResponse {
            result,
            ft,
            backend: Backend::Pjrt,
            kernel: "pjrt",
            exec_seconds: t0.elapsed().as_secs_f64(),
        })
    }

    fn artifact(&self, routine: &str, variant: &str, n: usize) -> Result<String> {
        self.manifest
            .find_n(routine, variant, n)
            .map(|s| s.name.clone())
            .ok_or_else(|| anyhow!("no artifact {routine}/{variant} for n={n}"))
    }

    fn dispatch(&self, req: &BlasRequest, policy: FtPolicy,
                fault: Option<Fault>) -> Result<(BlasResult, FtReport)> {
        let protected = policy.protects();
        match req {
            // ------------------------------------------------- Level 1
            BlasRequest::Dscal { alpha, x } => {
                let n = x.len();
                if protected {
                    let name = self.artifact("dscal", "dmr", n)?;
                    let mut outs = self.handle.call(&name, vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Vec1(x.clone()),
                        inj3(fault),
                    ])?;
                    let errs = outs[1][0] as u64;
                    Ok((BlasResult::Vector(std::mem::take(&mut outs[0])),
                        FtReport { errors_detected: errs, errors_corrected: errs }))
                } else {
                    let name = self.artifact("dscal", "ori", n)?;
                    let mut outs = self.handle.call(&name, vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Vec1(x.clone()),
                    ])?;
                    Ok((BlasResult::Vector(std::mem::take(&mut outs[0])),
                        FtReport::none()))
                }
            }
            BlasRequest::Daxpy { alpha, x, y } => {
                let n = x.len();
                let (variant, mut args) = if protected {
                    ("dmr", vec![OwnedArg::Scalar(*alpha),
                                 OwnedArg::Vec1(x.clone()),
                                 OwnedArg::Vec1(y.clone()), inj3(fault)])
                } else {
                    ("ori", vec![OwnedArg::Scalar(*alpha),
                                 OwnedArg::Vec1(x.clone()),
                                 OwnedArg::Vec1(y.clone())])
                };
                let name = self.artifact("daxpy", variant, n)?;
                args.truncate(args.len());
                let mut outs = self.handle.call(&name, args)?;
                let ft = if protected {
                    let e = outs[1][0] as u64;
                    FtReport { errors_detected: e, errors_corrected: e }
                } else {
                    FtReport::none()
                };
                Ok((BlasResult::Vector(std::mem::take(&mut outs[0])), ft))
            }
            BlasRequest::Ddot { x, y } => {
                let n = x.len();
                let (variant, args) = if protected {
                    ("dmr", vec![OwnedArg::Vec1(x.clone()),
                                 OwnedArg::Vec1(y.clone()), inj3(fault)])
                } else {
                    ("ori", vec![OwnedArg::Vec1(x.clone()),
                                 OwnedArg::Vec1(y.clone())])
                };
                let name = self.artifact("ddot", variant, n)?;
                let outs = self.handle.call(&name, args)?;
                let ft = if protected {
                    let e = outs[1][0] as u64;
                    FtReport { errors_detected: e, errors_corrected: e }
                } else {
                    FtReport::none()
                };
                Ok((BlasResult::Scalar(outs[0][0]), ft))
            }
            BlasRequest::Dnrm2 { x } => {
                let n = x.len();
                let (variant, args) = if protected {
                    ("dmr", vec![OwnedArg::Vec1(x.clone()), inj3(fault)])
                } else {
                    ("ori", vec![OwnedArg::Vec1(x.clone())])
                };
                let name = self.artifact("dnrm2", variant, n)?;
                let outs = self.handle.call(&name, args)?;
                let ft = if protected {
                    let e = outs[1][0] as u64;
                    FtReport { errors_detected: e, errors_corrected: e }
                } else {
                    FtReport::none()
                };
                Ok((BlasResult::Scalar(outs[0][0]), ft))
            }
            BlasRequest::Dasum { x } => {
                let name = self.artifact("dasum", "ori", x.len())?;
                let outs = self.handle.call(&name,
                    vec![OwnedArg::Vec1(x.clone())])?;
                Ok((BlasResult::Scalar(outs[0][0]), FtReport::none()))
            }
            // ------------------------------------------------- Level 2
            BlasRequest::Dgemv { alpha, a, x, beta, y } => {
                let n = a.rows;
                // the DMR kernel's inject is [flag, row, jblk, delta]:
                // clamp the planned fault into the kernel's grid ranges
                let fault = fault.map(|mut f| {
                    f.i %= a.rows;
                    let bn = self
                        .manifest
                        .find_n("dgemv", "dmr", n)
                        .and_then(|s| s.meta_usize("bn"))
                        .unwrap_or(a.cols);
                    f.j %= (a.cols / bn).max(1);
                    f
                });
                let (variant, args) = if protected {
                    ("dmr", vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Mat(a.data.clone(), a.rows, a.cols),
                        OwnedArg::Vec1(x.clone()),
                        OwnedArg::Scalar(*beta),
                        OwnedArg::Vec1(y.clone()),
                        inj4(fault),
                    ])
                } else {
                    ("ori", vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Mat(a.data.clone(), a.rows, a.cols),
                        OwnedArg::Vec1(x.clone()),
                        OwnedArg::Scalar(*beta),
                        OwnedArg::Vec1(y.clone()),
                    ])
                };
                let name = self.artifact("dgemv", variant, n)?;
                let mut outs = self.handle.call(&name, args)?;
                let ft = if protected {
                    let e = outs[1][0] as u64;
                    FtReport { errors_detected: e, errors_corrected: e }
                } else {
                    FtReport::none()
                };
                Ok((BlasResult::Vector(std::mem::take(&mut outs[0])), ft))
            }
            BlasRequest::Dtrsv { a, b } => {
                let n = a.rows;
                // inject is [flag, step, row, delta] with a panel-local row
                let fault = fault.map(|mut f| {
                    let panel = self
                        .manifest
                        .find_n("dtrsv", "dmr", n)
                        .and_then(|s| s.meta_usize("panel"))
                        .unwrap_or(4);
                    f.step %= (n / panel).max(1);
                    f.i %= panel;
                    f
                });
                let (variant, args) = if protected {
                    ("dmr", vec![
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Vec1(b.clone()),
                        inj4_step_row(fault),
                    ])
                } else {
                    ("ori", vec![
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Vec1(b.clone()),
                    ])
                };
                let name = self.artifact("dtrsv", variant, n)?;
                let mut outs = self.handle.call(&name, args)?;
                let ft = if protected {
                    let e = outs[1][0] as u64;
                    FtReport { errors_detected: e, errors_corrected: e }
                } else {
                    FtReport::none()
                };
                Ok((BlasResult::Vector(std::mem::take(&mut outs[0])), ft))
            }
            // ------------------------------------------------- Level 3
            BlasRequest::Dgemm { alpha, a, b, beta, c } => {
                match policy {
                    FtPolicy::None => self.dgemm_ori(*alpha, a, b, *beta, c),
                    // weighted requests are rejected by supports(); if one
                    // arrives anyway, the fused-ABFT artifact still
                    // protects it
                    FtPolicy::Hybrid | FtPolicy::AbftWeighted => {
                        self.dgemm_abft(*alpha, a, b, *beta, c, fault)
                    }
                    FtPolicy::AbftUnfused => {
                        self.dgemm_unfused(*alpha, a, b, *beta, c, fault)
                    }
                }
            }
            BlasRequest::Dsymm { alpha, a, b, beta, c } => {
                if protected {
                    self.symm_like_abft("dsymm", *alpha, a, b, *beta, c, fault)
                } else {
                    let n = a.rows;
                    let name = self.artifact("dsymm", "ori", n)?;
                    let outs = self.handle.call(&name, vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
                        OwnedArg::Scalar(*beta),
                        OwnedArg::Mat(c.data.clone(), c.rows, c.cols),
                    ])?;
                    Ok((BlasResult::Matrix(Matrix::from_vec(
                        c.rows, c.cols, outs.into_iter().next().unwrap())),
                        FtReport::none()))
                }
            }
            BlasRequest::Dtrmm { alpha, a, b } => {
                let n = a.rows;
                if protected {
                    let name = self.artifact("dtrmm", "abft", n)?;
                    // alpha folds into A: alpha*tril(A) = tril(alpha*A)
                    let ascaled: Vec<f64> =
                        a.data.iter().map(|v| alpha * v).collect();
                    let outs = self.handle.call(&name, vec![
                        OwnedArg::Mat(ascaled.clone(), n, n),
                        OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
                        inj4(fault),
                    ])?;
                    let (mat, ft) = self.verify_abft_outputs(
                        outs, b.rows, b.cols, &ascaled, &b.data)?;
                    Ok((BlasResult::Matrix(mat), ft))
                } else {
                    let name = self.artifact("dtrmm", "ori", n)?;
                    let outs = self.handle.call(&name, vec![
                        OwnedArg::Scalar(*alpha),
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
                    ])?;
                    Ok((BlasResult::Matrix(Matrix::from_vec(
                        b.rows, b.cols, outs.into_iter().next().unwrap())),
                        FtReport::none()))
                }
            }
            BlasRequest::Dtrsm { a, b } => {
                let n = a.rows;
                if protected {
                    let name = self.artifact("dtrsm", "ft", n)?;
                    let mut outs = self.handle.call(&name, vec![
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
                        inj5(fault),
                    ])?;
                    let errs = outs[1][0] as u64;
                    Ok((BlasResult::Matrix(Matrix::from_vec(
                        b.rows, b.cols, std::mem::take(&mut outs[0]))),
                        FtReport { errors_detected: errs, errors_corrected: errs }))
                } else {
                    let name = self.artifact("dtrsm", "ori", n)?;
                    let outs = self.handle.call(&name, vec![
                        OwnedArg::Mat(a.data.clone(), n, n),
                        OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
                    ])?;
                    Ok((BlasResult::Matrix(Matrix::from_vec(
                        b.rows, b.cols, outs.into_iter().next().unwrap())),
                        FtReport::none()))
                }
            }
            BlasRequest::Dsyrk { alpha, a, beta, c } => {
                let n = a.rows;
                let name = self.artifact("dsyrk", "ori", n)?;
                let outs = self.handle.call(&name, vec![
                    OwnedArg::Scalar(*alpha),
                    OwnedArg::Mat(a.data.clone(), a.rows, a.cols),
                    OwnedArg::Scalar(*beta),
                    OwnedArg::Mat(c.data.clone(), c.rows, c.cols),
                ])?;
                Ok((BlasResult::Matrix(Matrix::from_vec(
                    c.rows, c.cols, outs.into_iter().next().unwrap())),
                    FtReport::none()))
            }
            // No artifacts are generated for these routines — the router's
            // `resolve` falls back to the tuned native kernels before this
            // dispatch is ever reached (`supports` returns false).
            BlasRequest::Drot { .. }
            | BlasRequest::Drotm { .. }
            | BlasRequest::Idamax { .. }
            | BlasRequest::Dger { .. }
            | BlasRequest::Dsymv { .. }
            | BlasRequest::Dtrmv { .. } => {
                Err(anyhow!("routine {} has no PJRT artifact", req.routine()))
            }
        }
    }

    fn dgemm_ori(&self, alpha: f64, a: &Matrix, b: &Matrix, beta: f64,
                 c: &Matrix) -> Result<(BlasResult, FtReport)> {
        let name = self.artifact("dgemm", "ori", a.rows)?;
        let outs = self.handle.call(&name, vec![
            OwnedArg::Scalar(alpha),
            OwnedArg::Mat(a.data.clone(), a.rows, a.cols),
            OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
            OwnedArg::Scalar(beta),
            OwnedArg::Mat(c.data.clone(), c.rows, c.cols),
        ])?;
        Ok((BlasResult::Matrix(Matrix::from_vec(
            c.rows, c.cols, outs.into_iter().next().unwrap())),
            FtReport::none()))
    }

    /// Fused online ABFT (paper §5.2): prefer the rank-k artifact and run
    /// the paper's per-step verification loop; fall back to the full-GEMM
    /// fused artifact (one verification interval).
    fn dgemm_abft(&self, alpha: f64, a: &Matrix, b: &Matrix, beta: f64,
                  c: &Matrix, fault: Option<Fault>)
                  -> Result<(BlasResult, FtReport)> {
        let n = a.rows;
        // alpha folds into A, beta into the C accumulator.
        let ascaled: Vec<f64> = a.data.iter().map(|v| alpha * v).collect();
        let cinit: Vec<f64> = c.data.iter().map(|v| beta * v).collect();

        if let Some(spec) = self.manifest.find_n("dgemm", "abft_rankk", n) {
            let kc = spec.meta_usize("kc").unwrap_or(n);
            let name = spec.name.clone();
            let steps = a.cols / kc;
            let mut cur = cinit;
            let mut state = ChecksumState::from_c(&cur, n, b.cols);
            let mut report = FtReport::none();
            let max_ab = ascaled.iter().chain(b.data.iter())
                .fold(0.0f64, |m, v| m.max(v.abs()));
            for s in 0..steps {
                // slice panels A(:, s*kc..) and B(s*kc.., :)
                let mut ap = vec![0.0; n * kc];
                for i in 0..n {
                    ap[i * kc..(i + 1) * kc].copy_from_slice(
                        &ascaled[i * a.cols + s * kc..i * a.cols + (s + 1) * kc]);
                }
                let bp = b.data[s * kc * b.cols..(s + 1) * kc * b.cols].to_vec();
                let step_fault = fault.filter(|f| f.step == s);
                let mut outs = self.handle.call(&name, vec![
                    OwnedArg::Mat(ap, n, kc),
                    OwnedArg::Mat(bp, kc, b.cols),
                    OwnedArg::Mat(cur, n, b.cols),
                    inj4(step_fault),
                ])?;
                cur = std::mem::take(&mut outs[0]);
                let (crr, ccr) = (&outs[1], &outs[2]);
                state.accumulate(&outs[3], &outs[4]);
                let tol = abft::round_off_threshold(
                    max_ab * max_ab, a.cols, n.max(b.cols));
                report.merge(abft::verify_and_correct(
                    &mut cur, b.cols, &state, crr, ccr, tol));
            }
            return Ok((BlasResult::Matrix(Matrix::from_vec(n, b.cols, cur)),
                       report));
        }

        // full fused artifact: C = A@B from zero; add beta*C after.
        let name = self.artifact("dgemm", "abft", n)?;
        let outs = self.handle.call(&name, vec![
            OwnedArg::Mat(ascaled.clone(), n, a.cols),
            OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
            inj4(fault),
        ])?;
        let (mut mat, mut ft) = self.verify_abft_outputs(
            outs, n, b.cols, &ascaled, &b.data)?;
        if beta != 0.0 {
            for (mv, cv) in mat.data.iter_mut().zip(&cinit) {
                *mv += cv;
            }
        }
        let _ = &mut ft;
        Ok((BlasResult::Matrix(mat), ft))
    }

    /// Interpret [C, Cr_ref, Cc_ref, Cr_enc, Cc_enc] outputs of a fused
    /// artifact: verify, locate, correct in Rust (the L3 half of the
    /// online loop).
    fn verify_abft_outputs(&self, mut outs: Vec<Vec<f64>>, m: usize, n: usize,
                           a: &[f64], b: &[f64])
                           -> Result<(Matrix, FtReport)> {
        if outs.len() != 5 {
            return Err(anyhow!("fused artifact returned {} outputs", outs.len()));
        }
        let mut c = std::mem::take(&mut outs[0]);
        let state = ChecksumState {
            cr_enc: std::mem::take(&mut outs[3]),
            cc_enc: std::mem::take(&mut outs[4]),
        };
        let max_ab = a.iter().chain(b.iter())
            .fold(0.0f64, |mx, v| mx.max(v.abs()));
        let k = a.len() / m;
        let tol = abft::round_off_threshold(max_ab * max_ab, k, n.max(m));
        let report = abft::verify_and_correct(
            &mut c, n, &state, &outs[1], &outs[2], tol);
        Ok((Matrix::from_vec(m, n, c), report))
    }

    /// Unfused ABFT on the unprotected artifact (paper §5.1): the GEMM
    /// itself runs on PJRT; the checksum encode + reference passes run as
    /// separate memory-bound sweeps here — the traffic fusion eliminates.
    fn dgemm_unfused(&self, alpha: f64, a: &Matrix, b: &Matrix, beta: f64,
                     c: &Matrix, fault: Option<Fault>)
                     -> Result<(BlasResult, FtReport)> {
        let (result, _) = self.dgemm_ori(alpha, a, b, beta, c)?;
        let mut mat = match result {
            BlasResult::Matrix(m) => m,
            _ => unreachable!(),
        };
        let (m, n) = (mat.rows, mat.cols);
        // encode expected checksums: alpha*A@B + beta*C sums
        let ascaled: Vec<f64> = a.data.iter().map(|v| alpha * v).collect();
        let (mut cr_enc, mut cc_enc) =
            abft::encode_panel(&ascaled, &b.data, m, a.cols, n);
        for i in 0..m {
            for j in 0..n {
                let v = beta * c.data[i * n + j];
                cr_enc[i] += v;
                cc_enc[j] += v;
            }
        }
        // simulated fault strikes C after compute, before verification
        if let Some(f) = fault {
            mat.data[f.i * n + f.j] += f.delta;
        }
        let (cr_ref, cc_ref) = abft::reference_checksums(&mat.data, m, n);
        let max_ab = ascaled.iter().chain(b.data.iter())
            .fold(0.0f64, |mx, v| mx.max(v.abs()));
        let tol = abft::round_off_threshold(max_ab * max_ab, a.cols, n.max(m));
        let state = ChecksumState { cr_enc, cc_enc };
        let report = abft::verify_and_correct(
            &mut mat.data, n, &state, &cr_ref, &cc_ref, tol);
        Ok((BlasResult::Matrix(mat), report))
    }

    /// DSYMM under fused ABFT (shares the fused-artifact output format).
    #[allow(clippy::too_many_arguments)]
    fn symm_like_abft(&self, routine: &str, alpha: f64, a: &Matrix, b: &Matrix,
                      beta: f64, c: &Matrix, fault: Option<Fault>)
                      -> Result<(BlasResult, FtReport)> {
        let n = a.rows;
        let name = self.artifact(routine, "abft", n)?;
        let ascaled: Vec<f64> = a.data.iter().map(|v| alpha * v).collect();
        let cinit: Vec<f64> = c.data.iter().map(|v| beta * v).collect();
        let outs = self.handle.call(&name, vec![
            OwnedArg::Mat(ascaled.clone(), n, n),
            OwnedArg::Mat(b.data.clone(), b.rows, b.cols),
            OwnedArg::Mat(cinit, c.rows, c.cols),
            inj4(fault),
        ])?;
        // the artifact accumulated beta*C internally; its enc checksums
        // come back as dCr/dCc of the A@B part, so rebuild full state:
        let mut outs = outs;
        let mut cmat = std::mem::take(&mut outs[0]);
        let mut state = ChecksumState::from_c(
            &c.data.iter().map(|v| beta * v).collect::<Vec<_>>(), n, b.cols);
        state.accumulate(&outs[3], &outs[4]);
        let max_ab = ascaled.iter().chain(b.data.iter())
            .fold(0.0f64, |mx, v| mx.max(v.abs()));
        let tol = abft::round_off_threshold(max_ab * max_ab, n, n.max(b.cols));
        let report = abft::verify_and_correct(
            &mut cmat, b.cols, &state, &outs[1], &outs[2], tol);
        Ok((BlasResult::Matrix(Matrix::from_vec(c.rows, c.cols, cmat)), report))
    }
}
