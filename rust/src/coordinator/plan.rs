//! Execution planning: resolve a request + policy + profile into the
//! registered kernel that should run it.
//!
//! The [`Planner`] is the single place routing decisions live. Given a
//! [`BlasRequest`], a preferred [`Impl`] variant, and an [`FtPolicy`],
//! it filters the [`KernelRegistry`] by capability and size, decides the
//! thread grant, and returns an [`ExecutionPlan`] that the router (and
//! through it the server's worker pool and the bench harnesses) execute
//! uniformly.
//!
//! The [`PlanCache`] memoizes resolutions by `(routine, dim, policy,
//! backend)` so the server plans each distinct shape **once at admission
//! time**: the hot serving path never touches the planner again, and the
//! cache's hit/miss counters flow into the metrics ledger.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::blas::Impl;
use crate::config::Profile;
use crate::coordinator::registry::{KernelDescriptor, KernelId, KernelRegistry};
use crate::coordinator::request::{Backend, BlasRequest};
use crate::ft::policy::FtPolicy;

/// A resolved execution: which kernel, how many threads, which policy.
#[derive(Clone, Copy)]
pub struct ExecutionPlan {
    /// The registered kernel that will run.
    pub kernel: &'static KernelDescriptor,
    /// Stable registry id of `kernel` — the batcher's scheduling key.
    pub kernel_id: KernelId,
    /// Threads granted to the kernel (1 for serial kernels).
    pub threads: usize,
    /// Protection policy the plan was resolved under.
    pub policy: FtPolicy,
}

impl ExecutionPlan {
    /// One-line human description (CLI `run` prints it).
    pub fn describe(&self) -> String {
        format!("{} (threads={}, policy={})", self.kernel.name, self.threads,
                self.policy.name())
    }

    /// Pool threads an in-flight batch of this plan occupies — what the
    /// server's thread-budget ledger debits while the batch executes.
    pub fn thread_cost(&self) -> usize {
        self.kernel.thread_cost(self.threads)
    }
}

/// Resolves requests against the kernel registry for one profile.
pub struct Planner<'p> {
    profile: &'p Profile,
    registry: &'static KernelRegistry,
}

impl<'p> Planner<'p> {
    /// A planner over the global registry for one profile.
    pub fn new(profile: &'p Profile) -> Planner<'p> {
        Planner { profile, registry: KernelRegistry::global() }
    }

    /// Plan a request. Selection order:
    ///
    /// 1. a threaded kernel of the requested variant, when the profile
    ///    grants more than one thread and the request clears the
    ///    kernel's MR-aligned size floor;
    /// 2. a serial kernel of the requested variant;
    /// 3. any serial kernel serving the policy — protected kernels
    ///    register under the tuned variant, so a protected request
    ///    carrying a naive/blocked variant preference still gets
    ///    protection (the pre-registry router behaved the same way).
    ///
    /// Returns `None` only if no registered kernel serves the routine
    /// under the policy; the registry's totality test guarantees this
    /// cannot happen for shipped routines.
    pub fn plan(&self, req: &BlasRequest, variant: Impl, policy: FtPolicy)
                -> Option<ExecutionPlan> {
        self.plan_dims(req.routine(), req.dim(), variant, policy)
    }

    /// Shape-only planning — the admission path's entry: the plan cache
    /// memoizes these resolutions, and since the server batches by the
    /// resulting kernel id a whole batch shares one plan.
    pub fn plan_dims(&self, routine: &str, dim: usize, variant: Impl,
                     policy: FtPolicy) -> Option<ExecutionPlan> {
        let mr = self.profile.gemm.mr;
        let threads = self.profile.threads.max(1);
        let supported: Vec<&'static KernelDescriptor> = self
            .registry
            .for_routine(routine)
            .into_iter()
            .filter(|k| k.supports(policy))
            .collect();
        let resolved = |k: &'static KernelDescriptor, threads: usize| {
            let kernel_id = self
                .registry
                .id_of(k)
                .expect("planner selected a descriptor outside the registry");
            ExecutionPlan { kernel: k, kernel_id, threads, policy }
        };
        if threads > 1 {
            if let Some(k) = supported.iter().copied().find(|k| {
                k.threaded && k.variant == variant && k.admits_dim(dim, mr)
            }) {
                return Some(resolved(k, threads));
            }
        }
        if let Some(k) = supported
            .iter()
            .copied()
            .find(|k| !k.threaded && k.variant == variant)
        {
            return Some(resolved(k, 1));
        }
        supported
            .iter()
            .copied()
            .find(|k| !k.threaded)
            .map(|k| resolved(k, 1))
    }
}

/// Memoized admission-time planning.
///
/// Keyed by `(routine, dim, policy, backend)`: everything the
/// [`Planner`] reads from a request, for one fixed profile. The server
/// — or, in sharded mode, the cluster front-end, which owns one shared
/// cache and also routes on the resulting kernel id — resolves each
/// request against this cache when it is *submitted*, so workers only
/// ever execute pre-resolved plans — the planner's registry scan runs
/// once per distinct shape, not once per request.
///
/// Backends without a native kernel variant (PJRT) are not planned
/// here; `resolve` returns `None` for them without touching the
/// counters (the PJRT executor plans per-artifact instead).
pub struct PlanCache {
    profile: Profile,
    plans: Mutex<HashMap<PlanKey, Option<ExecutionPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

type PlanKey = (&'static str, usize, FtPolicy, Backend);

impl PlanCache {
    /// An empty cache for one profile.
    pub fn new(profile: Profile) -> PlanCache {
        PlanCache {
            profile,
            plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The profile resolutions are planned under.
    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// Resolve a `(routine, dim, policy, backend)` key, memoizing the
    /// planner's answer. A cached entry is returned verbatim — the
    /// proptests assert it always equals a fresh planner resolution.
    pub fn resolve(&self, routine: &'static str, dim: usize,
                   policy: FtPolicy, backend: Backend)
                   -> Option<ExecutionPlan> {
        let variant = backend.variant()?;
        let key = (routine, dim, policy, backend);
        let mut plans = self.plans.lock().unwrap();
        match plans.get(&key) {
            Some(plan) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                *plan
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let plan = Planner::new(&self.profile)
                    .plan_dims(routine, dim, variant, policy);
                plans.insert(key, plan);
                plan
            }
        }
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::Scheme;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    fn dgemm_req(n: usize) -> BlasRequest {
        let mut rng = Rng::new(0x91A);
        BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        }
    }

    #[test]
    fn serial_profile_plans_serial_kernels() {
        let profile = Profile::skylake_sim();
        assert_eq!(profile.threads, 1);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned");
        assert_eq!(plan.threads, 1);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
    }

    #[test]
    fn threaded_profile_selects_mt_kernels_above_floor() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned-mt");
        assert_eq!(plan.threads, 4);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused-mt");
        assert!(plan.kernel.threaded);
        // below the MR-aligned floor the serial kernels stay in charge
        let small = dgemm_req(profile.gemm.mr);
        let plan = planner.plan(&small, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn naive_variant_never_rides_the_thread_pool() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(128);
        let plan = planner.plan(&req, Impl::Naive, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/naive");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn protected_request_with_naive_variant_still_protected() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let plan = planner.plan(&req, Impl::Naive, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.scheme, Scheme::AbftFused);
    }

    #[test]
    fn plan_cache_memoizes_and_counts() {
        let cache = PlanCache::new(Profile::skylake_sim().with_threads(4));
        let first = cache
            .resolve("dgemm", 64, FtPolicy::Hybrid, Backend::NativeTuned)
            .unwrap();
        assert_eq!(first.kernel.name, "dgemm/abft-fused-mt");
        assert_eq!(cache.stats(), (0, 1));
        let again = cache
            .resolve("dgemm", 64, FtPolicy::Hybrid, Backend::NativeTuned)
            .unwrap();
        assert_eq!(again.kernel_id, first.kernel_id);
        assert_eq!(again.threads, first.threads);
        assert_eq!(cache.stats(), (1, 1));
        // a different shape is a distinct key (below the MT floor here)
        let small = cache
            .resolve("dgemm", 4, FtPolicy::Hybrid, Backend::NativeTuned)
            .unwrap();
        assert_eq!(small.kernel.name, "dgemm/abft-fused");
        assert_eq!(cache.stats(), (1, 2));
        // PJRT has no native variant: unplanned and uncounted
        assert!(cache
            .resolve("dgemm", 64, FtPolicy::Hybrid, Backend::Pjrt)
            .is_none());
        assert_eq!(cache.stats(), (1, 2));
    }

    #[test]
    fn plans_carry_stable_ids_and_costs() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::None).unwrap();
        let reg = crate::coordinator::registry::KernelRegistry::global();
        assert!(std::ptr::eq(reg.by_id(plan.kernel_id).unwrap(), plan.kernel));
        assert_eq!(plan.thread_cost(), 4, "MT batch debits its whole grant");
        let serial = planner.plan(&req, Impl::Naive, FtPolicy::None).unwrap();
        assert_eq!(serial.thread_cost(), 1);
    }

    #[test]
    fn weighted_policy_routes_dgemm_to_weighted_kernel() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let plan = planner
            .plan(&req, Impl::Tuned, FtPolicy::AbftWeighted)
            .unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-weighted");
    }
}
