//! Execution planning: resolve a request + policy + profile into the
//! registered kernel that should run it.
//!
//! The [`Planner`] is the single place routing decisions live. Given a
//! [`BlasRequest`], a preferred [`Impl`] variant, and an [`FtPolicy`],
//! it filters the [`KernelRegistry`] by capability and size, decides the
//! thread grant, and returns an [`ExecutionPlan`] that the router (and
//! through it the server's worker pool and the bench harnesses) execute
//! uniformly.

use crate::blas::Impl;
use crate::config::Profile;
use crate::coordinator::registry::{KernelDescriptor, KernelRegistry};
use crate::coordinator::request::BlasRequest;
use crate::ft::policy::FtPolicy;

/// A resolved execution: which kernel, how many threads, which policy.
#[derive(Clone, Copy)]
pub struct ExecutionPlan {
    pub kernel: &'static KernelDescriptor,
    /// Threads granted to the kernel (1 for serial kernels).
    pub threads: usize,
    pub policy: FtPolicy,
}

impl ExecutionPlan {
    pub fn describe(&self) -> String {
        format!("{} (threads={}, policy={})", self.kernel.name, self.threads,
                self.policy.name())
    }
}

/// Resolves requests against the kernel registry for one profile.
pub struct Planner<'p> {
    profile: &'p Profile,
    registry: &'static KernelRegistry,
}

impl<'p> Planner<'p> {
    pub fn new(profile: &'p Profile) -> Planner<'p> {
        Planner { profile, registry: KernelRegistry::global() }
    }

    /// Plan a request. Selection order:
    ///
    /// 1. a threaded kernel of the requested variant, when the profile
    ///    grants more than one thread and the request clears the
    ///    kernel's MR-aligned size floor;
    /// 2. a serial kernel of the requested variant;
    /// 3. any serial kernel serving the policy — protected kernels
    ///    register under the tuned variant, so a protected request
    ///    carrying a naive/blocked variant preference still gets
    ///    protection (the pre-registry router behaved the same way).
    ///
    /// Returns `None` only if no registered kernel serves the routine
    /// under the policy; the registry's totality test guarantees this
    /// cannot happen for shipped routines.
    pub fn plan(&self, req: &BlasRequest, variant: Impl, policy: FtPolicy)
                -> Option<ExecutionPlan> {
        self.plan_dims(req.routine(), req.dim(), variant, policy)
    }

    /// Shape-only planning (the batcher groups by `(routine, dim)`, so
    /// a whole batch shares one plan).
    pub fn plan_dims(&self, routine: &str, dim: usize, variant: Impl,
                     policy: FtPolicy) -> Option<ExecutionPlan> {
        let mr = self.profile.gemm.mr;
        let threads = self.profile.threads.max(1);
        let supported: Vec<&'static KernelDescriptor> = self
            .registry
            .for_routine(routine)
            .into_iter()
            .filter(|k| k.supports(policy))
            .collect();
        if threads > 1 {
            if let Some(k) = supported.iter().copied().find(|k| {
                k.threaded && k.variant == variant && k.admits_dim(dim, mr)
            }) {
                return Some(ExecutionPlan { kernel: k, threads, policy });
            }
        }
        if let Some(k) = supported
            .iter()
            .copied()
            .find(|k| !k.threaded && k.variant == variant)
        {
            return Some(ExecutionPlan { kernel: k, threads: 1, policy });
        }
        supported
            .iter()
            .copied()
            .find(|k| !k.threaded)
            .map(|k| ExecutionPlan { kernel: k, threads: 1, policy })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::Scheme;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    fn dgemm_req(n: usize) -> BlasRequest {
        let mut rng = Rng::new(0x91A);
        BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        }
    }

    #[test]
    fn serial_profile_plans_serial_kernels() {
        let profile = Profile::skylake_sim();
        assert_eq!(profile.threads, 1);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned");
        assert_eq!(plan.threads, 1);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
    }

    #[test]
    fn threaded_profile_selects_mt_kernels_above_floor() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned-mt");
        assert_eq!(plan.threads, 4);
        let plan = planner.plan(&req, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused-mt");
        assert!(plan.kernel.threaded);
        // below the MR-aligned floor the serial kernels stay in charge
        let small = dgemm_req(profile.gemm.mr);
        let plan = planner.plan(&small, Impl::Tuned, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn naive_variant_never_rides_the_thread_pool() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(128);
        let plan = planner.plan(&req, Impl::Naive, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/naive");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn protected_request_with_naive_variant_still_protected() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let plan = planner.plan(&req, Impl::Naive, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.scheme, Scheme::AbftFused);
    }

    #[test]
    fn weighted_policy_routes_dgemm_to_weighted_kernel() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let plan = planner
            .plan(&req, Impl::Tuned, FtPolicy::AbftWeighted)
            .unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-weighted");
    }
}
