//! Execution planning: resolve a request + policy + profile into the
//! registered kernel that should run it.
//!
//! The [`Planner`] is the single place routing decisions live. Given a
//! [`BlasRequest`], a [`SelectionPolicy`] (ordered backend preferences
//! plus allowlist/denylist/capability constraints), and an
//! [`FtPolicy`], it filters the [`KernelRegistry`] by capability and
//! size, decides the thread grant, and returns an [`ExecutionPlan`]
//! that the router (and through it the server's worker pool and the
//! bench harnesses) execute uniformly. When nothing qualifies,
//! [`Planner::select_dims`] returns an exhaustive [`NoCandidate`]
//! diagnostic — every descriptor considered and the specific
//! capability each one missed — which the gateway surfaces through its
//! 400 preflight mapping.
//!
//! The [`PlanCache`] memoizes resolutions by `(routine, dim, policy,
//! selection)` so the server plans each distinct shape **once at
//! admission time**: the hot serving path never touches the planner
//! again, and the cache's hit/miss counters flow into the metrics
//! ledger.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::blas::Impl;
use crate::config::Profile;
use crate::coordinator::registry::{
    self, Capabilities, KernelDescriptor, KernelId, KernelRegistry, Scheme,
};
use crate::coordinator::request::{Backend, BlasRequest};
use crate::ft::policy::FtPolicy;

/// A resolved execution: which kernel, how many threads, which policy.
#[derive(Clone, Copy)]
pub struct ExecutionPlan {
    /// The registered kernel that will run.
    pub kernel: &'static KernelDescriptor,
    /// Stable registry id of `kernel` — the batcher's scheduling key.
    pub kernel_id: KernelId,
    /// Threads granted to the kernel (1 for serial kernels).
    pub threads: usize,
    /// Protection policy the plan was resolved under.
    pub policy: FtPolicy,
}

impl ExecutionPlan {
    /// One-line human description (CLI `run` prints it).
    pub fn describe(&self) -> String {
        format!("{} (threads={}, policy={})", self.kernel.name, self.threads,
                self.policy.name())
    }

    /// Pool threads an in-flight batch of this plan occupies — what the
    /// server's thread-budget ledger debits while the batch executes.
    pub fn thread_cost(&self) -> usize {
        self.kernel.thread_cost(self.threads)
    }
}

impl fmt::Debug for ExecutionPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ExecutionPlan")
            .field("kernel", &self.kernel.name)
            .field("kernel_id", &self.kernel_id)
            .field("threads", &self.threads)
            .field("policy", &self.policy.name())
            .finish()
    }
}

/// One capability a caller can require of every candidate (the CLI's
/// `--require cap=value` and the wire contract's `routing.require`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum CapRequirement {
    /// Element precision (`precision=f64`).
    Precision(String),
    /// Exact protection scheme (`scheme=abft-fused`).
    Scheme(Scheme),
    /// Thread shape (`threaded=true|false`).
    Threaded(bool),
    /// Batch-fusion capability (`batched=true|false`).
    Batched(bool),
    /// A required CPU feature (`feature=avx2`).
    Feature(String),
}

impl CapRequirement {
    /// Parse one `cap=value` pair.
    pub fn parse(key: &str, value: &str) -> Result<CapRequirement, String> {
        let boolean = |v: &str| match v {
            "true" => Ok(true),
            "false" => Ok(false),
            other => Err(format!("{key}: expected true|false, got {other:?}")),
        };
        match key {
            "precision" => Ok(CapRequirement::Precision(value.to_string())),
            "scheme" => Scheme::by_name(value)
                .map(CapRequirement::Scheme)
                .ok_or_else(|| format!("unknown scheme {value:?}")),
            "threaded" => boolean(value).map(CapRequirement::Threaded),
            "batched" => boolean(value).map(CapRequirement::Batched),
            "feature" => Ok(CapRequirement::Feature(value.to_string())),
            other => Err(format!(
                "unknown capability {other:?} (expected precision, scheme, \
                 threaded, batched, or feature)"
            )),
        }
    }

    /// Does `caps` satisfy this requirement?
    pub fn satisfied_by(&self, caps: &Capabilities) -> bool {
        match self {
            CapRequirement::Precision(p) => caps.precision == p,
            CapRequirement::Scheme(s) => caps.scheme == *s,
            CapRequirement::Threaded(t) => caps.threaded == *t,
            CapRequirement::Batched(b) => (caps.batch_dim_ceiling > 0) == *b,
            CapRequirement::Feature(f) => {
                caps.cpu_features.iter().any(|have| have == f)
            }
        }
    }

    /// The `cap=value` spelling (diagnostics and `/backends` echoes).
    pub fn describe(&self) -> String {
        match self {
            CapRequirement::Precision(p) => format!("precision={p}"),
            CapRequirement::Scheme(s) => format!("scheme={}", s.name()),
            CapRequirement::Threaded(t) => format!("threaded={t}"),
            CapRequirement::Batched(b) => format!("batched={b}"),
            CapRequirement::Feature(f) => format!("feature={f}"),
        }
    }
}

/// How the planner chooses among capability-qualified candidates:
/// ordered backend preferences plus hard constraints. The default
/// (everything empty) admits every registered kernel and falls back to
/// registration order — exactly the pre-redesign "any serial kernel
/// serving the policy" rung.
#[derive(Clone, Debug, Default, PartialEq, Eq, Hash)]
pub struct SelectionPolicy {
    /// Ordered backend preference; earlier entries win.
    pub prefer: Vec<Backend>,
    /// Allowlist — when non-empty, only these backends are candidates.
    pub allow: Vec<Backend>,
    /// Denylist — always excluded, even when preferred or allowed.
    pub deny: Vec<Backend>,
    /// Capability requirements every candidate must satisfy.
    pub require: Vec<CapRequirement>,
}

impl SelectionPolicy {
    /// Prefer `backend`, with the registry-order fallback intact. The
    /// non-native peers fall back to the tuned native tier — the same
    /// fallback the pre-redesign router hard-coded for PJRT.
    pub fn for_backend(backend: Backend) -> SelectionPolicy {
        let prefer = if backend.is_native() {
            vec![backend]
        } else {
            vec![backend, Backend::NativeTuned]
        };
        SelectionPolicy { prefer, ..SelectionPolicy::default() }
    }

    /// The `--variant` shorthand: prefer the variant's native backend.
    pub fn for_variant(variant: Impl) -> SelectionPolicy {
        SelectionPolicy::for_backend(Backend::for_variant(variant))
    }

    /// A hard pin: `backend` is both the only allowed backend and the
    /// only preference — selection fails rather than falling back.
    pub fn pinned(backend: Backend) -> SelectionPolicy {
        SelectionPolicy {
            prefer: vec![backend],
            allow: vec![backend],
            ..SelectionPolicy::default()
        }
    }

    /// Overlay request-scoped `routing` onto this (server-side) policy.
    /// Precedence: the request's preferences outrank the server's; the
    /// allowlist intersects when both sides set one (request-only or
    /// server-only lists pass through); denials and requirements union
    /// — a server-side denial can never be lifted by a request.
    pub fn merged_with(&self, routing: &SelectionPolicy) -> SelectionPolicy {
        let mut prefer = routing.prefer.clone();
        for be in &self.prefer {
            if !prefer.contains(be) {
                prefer.push(*be);
            }
        }
        let allow = match (routing.allow.is_empty(), self.allow.is_empty()) {
            (true, _) => self.allow.clone(),
            (false, true) => routing.allow.clone(),
            (false, false) => routing
                .allow
                .iter()
                .copied()
                .filter(|b| self.allow.contains(b))
                .collect(),
        };
        let mut deny = self.deny.clone();
        for be in &routing.deny {
            if !deny.contains(be) {
                deny.push(*be);
            }
        }
        let mut require = self.require.clone();
        for r in &routing.require {
            if !require.contains(r) {
                require.push(r.clone());
            }
        }
        SelectionPolicy { prefer, allow, deny, require }
    }

    /// Exclude `backend` (idempotent) — the router folds per-request
    /// backend availability in through this.
    pub fn with_denied(mut self, backend: Backend) -> SelectionPolicy {
        if !self.deny.contains(&backend) {
            self.deny.push(backend);
        }
        self
    }

    /// Why `k` is not a candidate for `(dim, policy)` under this
    /// selection — empty means it qualifies. Each entry names the
    /// specific capability or constraint missed, for the [`NoCandidate`]
    /// diagnostics.
    pub fn miss_reasons(&self, k: &KernelDescriptor, dim: usize,
                        policy: FtPolicy) -> Vec<String> {
        let mut missing = Vec::new();
        if !k.supports(policy) {
            let serves: Vec<&str> =
                k.policies.iter().map(|p| p.name()).collect();
            missing.push(format!("policy {} not served (serves: {})",
                                 policy.name(), serves.join(", ")));
        }
        if !k.serves_dim(dim) {
            missing.push(format!("dim {dim} above its max_dim {}", k.max_dim));
        }
        if !self.allow.is_empty() && !self.allow.contains(&k.backend) {
            missing.push(format!("backend {} not in the allowlist",
                                 k.backend.name()));
        }
        if self.deny.contains(&k.backend) {
            missing.push(format!("backend {} is denied", k.backend.name()));
        }
        let caps = k.capabilities();
        for r in &self.require {
            if !r.satisfied_by(&caps) {
                missing.push(format!("lacks required {}", r.describe()));
            }
        }
        missing
    }
}

/// One descriptor that was considered and rejected, with the exact
/// capabilities it missed.
#[derive(Clone, Debug)]
pub struct CandidateMiss {
    /// Registry name of the descriptor.
    pub name: &'static str,
    /// Its backend.
    pub backend: Backend,
    /// The constraints it failed, one message each.
    pub missing: Vec<String>,
}

/// SPEAR-style exhaustive no-candidate diagnostic: what was asked for
/// and why every considered descriptor was rejected.
#[derive(Clone, Debug)]
pub struct NoCandidate {
    /// Routine requested.
    pub routine: String,
    /// Principal dimension requested.
    pub dim: usize,
    /// Protection policy requested.
    pub policy: FtPolicy,
    /// How many descriptors were considered.
    pub considered: usize,
    /// Every rejection, in registration order.
    pub misses: Vec<CandidateMiss>,
}

impl fmt::Display for NoCandidate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "no candidate kernel for {} dim {} policy {} ({} considered)",
            self.routine, self.dim, self.policy.name(), self.considered
        )?;
        for m in &self.misses {
            write!(f, "; {} [{}]: {}", m.name, m.backend.name(),
                   m.missing.join(", "))?;
        }
        Ok(())
    }
}

/// Resolves requests against the kernel registry for one profile.
pub struct Planner<'p> {
    profile: &'p Profile,
    registry: &'static KernelRegistry,
}

impl<'p> Planner<'p> {
    /// A planner over the global registry for one profile.
    pub fn new(profile: &'p Profile) -> Planner<'p> {
        Planner { profile, registry: KernelRegistry::global() }
    }

    /// Plan a request under a selection policy; `None` when no
    /// candidate qualifies (see [`Planner::select_dims`] for the
    /// diagnostic-carrying form).
    pub fn plan(&self, req: &BlasRequest, sel: &SelectionPolicy,
                policy: FtPolicy) -> Option<ExecutionPlan> {
        self.plan_dims(req.routine(), req.dim(), sel, policy)
    }

    /// Shape-only planning — the admission path's entry: the plan cache
    /// memoizes these resolutions, and since the server batches by the
    /// resulting kernel id a whole batch shares one plan.
    pub fn plan_dims(&self, routine: &str, dim: usize, sel: &SelectionPolicy,
                     policy: FtPolicy) -> Option<ExecutionPlan> {
        self.select_dims(routine, dim, sel, policy).ok()
    }

    /// Select a kernel for `(routine, dim, policy)` under `sel`.
    ///
    /// Candidates are the registered kernels for the routine that serve
    /// the policy, fit the dimension cap, and pass the selection's
    /// allow/deny/requirement constraints. Selection order:
    ///
    /// 1. per preferred backend, in preference order: a threaded
    ///    candidate of that backend when the profile grants more than
    ///    one thread and the request clears the kernel's MR-aligned
    ///    floor, else a serial candidate of that backend;
    /// 2. any serial candidate, in registration order (protected
    ///    kernels register under the tuned backend, so a protected
    ///    request preferring naive/blocked still gets protection —
    ///    the pre-redesign rung 3);
    /// 3. any threaded candidate above its floor, when the constraints
    ///    exclude every serial one.
    ///
    /// On failure the returned [`NoCandidate`] lists every descriptor
    /// considered and the specific capability each missed.
    pub fn select_dims(&self, routine: &str, dim: usize,
                       sel: &SelectionPolicy, policy: FtPolicy)
                       -> Result<ExecutionPlan, NoCandidate> {
        let mr = self.profile.gemm.mr;
        let threads = self.profile.threads.max(1);
        let mut candidates: Vec<&'static KernelDescriptor> = Vec::new();
        let mut misses: Vec<CandidateMiss> = Vec::new();
        let mut considered = 0usize;
        for k in self.registry.for_routine(routine) {
            considered += 1;
            let missing = sel.miss_reasons(k, dim, policy);
            if missing.is_empty() {
                candidates.push(k);
            } else {
                misses.push(CandidateMiss {
                    name: k.name,
                    backend: k.backend,
                    missing,
                });
            }
        }
        let resolved = |k: &'static KernelDescriptor, threads: usize| {
            let kernel_id = self
                .registry
                .id_of(k)
                .expect("planner selected a descriptor outside the registry");
            ExecutionPlan { kernel: k, kernel_id, threads, policy }
        };
        for &be in &sel.prefer {
            if threads > 1 {
                if let Some(k) = candidates.iter().copied().find(|k| {
                    k.threaded && k.backend == be && k.admits_dim(dim, mr)
                }) {
                    return Ok(resolved(k, threads));
                }
            }
            if let Some(k) = candidates
                .iter()
                .copied()
                .find(|k| !k.threaded && k.backend == be)
            {
                return Ok(resolved(k, 1));
            }
        }
        if let Some(k) = candidates.iter().copied().find(|k| !k.threaded) {
            return Ok(resolved(k, 1));
        }
        if threads > 1 {
            if let Some(k) = candidates
                .iter()
                .copied()
                .find(|k| k.threaded && k.admits_dim(dim, mr))
            {
                return Ok(resolved(k, threads));
            }
        }
        // qualified candidates existed but none fit the thread shape
        for k in candidates {
            misses.push(CandidateMiss {
                name: k.name,
                backend: k.backend,
                missing: vec![format!(
                    "threaded-only candidate needs threads > 1 and dim ≥ \
                     {}×mr (profile grants {threads})",
                    k.min_mr_multiple
                )],
            });
        }
        Err(NoCandidate {
            routine: routine.to_string(),
            dim,
            policy,
            considered,
            misses,
        })
    }
}

/// Memoized admission-time planning.
///
/// Keyed by `(routine, dim, policy, selection)`: everything the
/// [`Planner`] reads from a request, for one fixed profile. The server
/// — or, in sharded mode, the cluster front-end, which owns one shared
/// cache and also routes on the resulting kernel id — resolves each
/// request against this cache when it is *submitted*, so workers only
/// ever execute pre-resolved plans — the planner's registry scan runs
/// once per distinct shape, not once per request. Every successful
/// resolution (hit or miss) bumps the registry's per-kernel selection
/// ledger, which `/backends` aggregates per backend.
pub struct PlanCache {
    profile: Profile,
    plans: Mutex<HashMap<PlanKey, Option<ExecutionPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

type PlanKey = (&'static str, usize, FtPolicy, SelectionPolicy);

impl PlanCache {
    /// An empty cache for one profile.
    pub fn new(profile: Profile) -> PlanCache {
        PlanCache {
            profile,
            plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The profile resolutions are planned under.
    pub fn profile(&self) -> &Profile {
        &self.profile
    }

    /// Resolve a `(routine, dim, policy, selection)` key, memoizing the
    /// planner's answer. A cached entry is returned verbatim — the
    /// proptests assert it always equals a fresh planner resolution.
    pub fn resolve(&self, routine: &'static str, dim: usize,
                   policy: FtPolicy, sel: &SelectionPolicy)
                   -> Option<ExecutionPlan> {
        let mut plans = self.plans.lock().unwrap();
        let plan = match plans.get(&(routine, dim, policy, sel.clone())) {
            Some(plan) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                *plan
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let plan = Planner::new(&self.profile)
                    .plan_dims(routine, dim, sel, policy);
                plans.insert((routine, dim, policy, sel.clone()), plan);
                plan
            }
        };
        if let Some(p) = plan {
            registry::note_selected(p.kernel_id);
        }
        plan
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::matrix::Matrix;
    use crate::util::rng::Rng;

    fn dgemm_req(n: usize) -> BlasRequest {
        let mut rng = Rng::new(0x91A);
        BlasRequest::Dgemm {
            alpha: 1.0,
            a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
            beta: 0.0,
            c: Matrix::zeros(n, n),
        }
    }

    fn tuned() -> SelectionPolicy {
        SelectionPolicy::for_variant(Impl::Tuned)
    }

    #[test]
    fn serial_profile_plans_serial_kernels() {
        let profile = Profile::skylake_sim();
        assert_eq!(profile.threads, 1);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, &tuned(), FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned");
        assert_eq!(plan.threads, 1);
        let plan = planner.plan(&req, &tuned(), FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
    }

    #[test]
    fn threaded_profile_selects_mt_kernels_above_floor() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, &tuned(), FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned-mt");
        assert_eq!(plan.threads, 4);
        let plan = planner.plan(&req, &tuned(), FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused-mt");
        assert!(plan.kernel.threaded);
        // below the MR-aligned floor the serial kernels stay in charge
        let small = dgemm_req(profile.gemm.mr);
        let plan = planner.plan(&small, &tuned(), FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-fused");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn naive_variant_never_rides_the_thread_pool() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(128);
        let sel = SelectionPolicy::for_variant(Impl::Naive);
        let plan = planner.plan(&req, &sel, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/naive");
        assert_eq!(plan.threads, 1);
    }

    #[test]
    fn protected_request_with_naive_variant_still_protected() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let sel = SelectionPolicy::for_variant(Impl::Naive);
        let plan = planner.plan(&req, &sel, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.scheme, Scheme::AbftFused);
    }

    #[test]
    fn peer_backends_are_planned_as_candidates() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        // PJRT preferred: its registry descriptor wins outright
        let sel = SelectionPolicy::for_backend(Backend::Pjrt);
        let plan = planner.plan(&req, &sel, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/pjrt");
        // …and falls back to the tuned native tier when denied
        let sel = sel.with_denied(Backend::Pjrt);
        let plan = planner.plan(&req, &sel, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/tuned");
        // GPU-sim tiers split on the dimension cap
        let sel = SelectionPolicy::for_backend(Backend::GpuSim);
        let plan = planner.plan(&req, &sel, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/gpusim-wmma16");
        let big = dgemm_req(96);
        let plan = planner.plan(&big, &sel, FtPolicy::Hybrid).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/gpusim-wmma32");
        let plan = planner.plan(&big, &sel, FtPolicy::None).unwrap();
        assert_eq!(plan.kernel.name, "dgemm/gpusim-ori");
    }

    #[test]
    fn no_candidate_diagnostics_are_exhaustive() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        // a hard pin to a backend whose only dgemm descriptors cannot
        // serve the policy at this dim: every miss must be explained
        let sel = SelectionPolicy {
            require: vec![CapRequirement::Threaded(true)],
            ..SelectionPolicy::default()
        };
        let err = planner
            .select_dims("dgemm", 64, &sel, FtPolicy::AbftWeighted)
            .unwrap_err();
        let reg = KernelRegistry::global();
        assert_eq!(err.considered, reg.for_routine("dgemm").len());
        assert_eq!(err.misses.len(), err.considered,
                   "every considered descriptor is accounted for");
        let text = err.to_string();
        assert!(text.contains("no candidate kernel for dgemm"));
        assert!(text.contains("policy abft-weighted not served"));
        assert!(text.contains("lacks required threaded=true"));

        // pinned selection refuses to fall back
        let pin = SelectionPolicy::pinned(Backend::GpuSim);
        let err = planner
            .select_dims("ddot", 64, &pin, FtPolicy::None)
            .unwrap_err();
        assert!(err.to_string().contains("not in the allowlist"));
    }

    #[test]
    fn requirement_parsing_round_trips() {
        for (k, v) in [("precision", "f64"), ("scheme", "abft-fused"),
                       ("threaded", "true"), ("batched", "false"),
                       ("feature", "avx2")] {
            let r = CapRequirement::parse(k, v).unwrap();
            assert_eq!(r.describe(), format!("{k}={v}"));
        }
        assert!(CapRequirement::parse("scheme", "warp").is_err());
        assert!(CapRequirement::parse("threaded", "maybe").is_err());
        assert!(CapRequirement::parse("tile", "16").is_err());
    }

    #[test]
    fn merged_with_respects_precedence() {
        let server = SelectionPolicy::for_backend(Backend::NativeTuned)
            .with_denied(Backend::Pjrt);
        let routing = SelectionPolicy {
            prefer: vec![Backend::GpuSim],
            deny: vec![Backend::NativeSimd],
            require: vec![CapRequirement::Scheme(Scheme::AbftFused)],
            ..SelectionPolicy::default()
        };
        let merged = server.merged_with(&routing);
        assert_eq!(merged.prefer, vec![Backend::GpuSim, Backend::NativeTuned]);
        assert!(merged.deny.contains(&Backend::Pjrt),
                "server denial survives the request overlay");
        assert!(merged.deny.contains(&Backend::NativeSimd));
        assert_eq!(merged.require,
                   vec![CapRequirement::Scheme(Scheme::AbftFused)]);
        // allowlists intersect when both sides set one
        let a = SelectionPolicy {
            allow: vec![Backend::NativeTuned, Backend::GpuSim],
            ..SelectionPolicy::default()
        };
        let b = SelectionPolicy {
            allow: vec![Backend::GpuSim, Backend::Pjrt],
            ..SelectionPolicy::default()
        };
        assert_eq!(a.merged_with(&b).allow, vec![Backend::GpuSim]);
    }

    #[test]
    fn plan_cache_memoizes_and_counts() {
        let cache = PlanCache::new(Profile::skylake_sim().with_threads(4));
        let first = cache
            .resolve("dgemm", 64, FtPolicy::Hybrid, &tuned())
            .unwrap();
        assert_eq!(first.kernel.name, "dgemm/abft-fused-mt");
        assert_eq!(cache.stats(), (0, 1));
        let again = cache
            .resolve("dgemm", 64, FtPolicy::Hybrid, &tuned())
            .unwrap();
        assert_eq!(again.kernel_id, first.kernel_id);
        assert_eq!(again.threads, first.threads);
        assert_eq!(cache.stats(), (1, 1));
        // a different shape is a distinct key (below the MT floor here)
        let small = cache
            .resolve("dgemm", 4, FtPolicy::Hybrid, &tuned())
            .unwrap();
        assert_eq!(small.kernel.name, "dgemm/abft-fused");
        assert_eq!(cache.stats(), (1, 2));
        // PJRT is a peer now: its selection resolves (and counts) too
        let pjrt = cache
            .resolve("dgemm", 64, FtPolicy::Hybrid,
                     &SelectionPolicy::for_backend(Backend::Pjrt))
            .unwrap();
        assert_eq!(pjrt.kernel.name, "dgemm/pjrt");
        assert_eq!(cache.stats(), (1, 3));
        // the selection ledger saw every successful resolve
        assert!(registry::selection_count(first.kernel_id) >= 2);
    }

    #[test]
    fn plans_carry_stable_ids_and_costs() {
        let profile = Profile::skylake_sim().with_threads(4);
        let planner = Planner::new(&profile);
        let req = dgemm_req(64);
        let plan = planner.plan(&req, &tuned(), FtPolicy::None).unwrap();
        let reg = crate::coordinator::registry::KernelRegistry::global();
        assert!(std::ptr::eq(reg.by_id(plan.kernel_id).unwrap(), plan.kernel));
        assert_eq!(plan.thread_cost(), 4, "MT batch debits its whole grant");
        let serial = planner
            .plan(&req, &SelectionPolicy::for_variant(Impl::Naive),
                  FtPolicy::None)
            .unwrap();
        assert_eq!(serial.thread_cost(), 1);
    }

    #[test]
    fn weighted_policy_routes_dgemm_to_weighted_kernel() {
        let profile = Profile::skylake_sim();
        let planner = Planner::new(&profile);
        let req = dgemm_req(48);
        let plan = planner
            .plan(&req, &tuned(), FtPolicy::AbftWeighted)
            .unwrap();
        assert_eq!(plan.kernel.name, "dgemm/abft-weighted");
    }
}
