//! Batching queue: groups pending requests that share a (routine, shape)
//! key so a worker drains a whole group in one pass (amortizing dispatch
//! and, on the PJRT path, keeping one hot executable in the instruction
//! cache — the serving analog of the paper's kernel locality argument).
//!
//! FIFO fairness is preserved across groups: groups are served in the
//! arrival order of their oldest member.

use std::collections::VecDeque;

/// A queued item: an opaque payload plus its batch key.
#[derive(Debug)]
pub struct Pending<T> {
    pub key: (&'static str, usize),
    pub seq: u64,
    pub item: T,
}

/// The batcher. Not thread-safe by itself; the server wraps it in a
/// Mutex+Condvar.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Pending<T>>,
    next_seq: u64,
    /// max items drained per batch
    pub max_batch: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize) -> Batcher<T> {
        Batcher { queue: VecDeque::new(), next_seq: 0, max_batch: max_batch.max(1) }
    }

    pub fn push(&mut self, key: (&'static str, usize), item: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(Pending { key, seq, item });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Drain the next batch: the oldest request's group, up to max_batch
    /// items, preserving arrival order within the group.
    pub fn next_batch(&mut self) -> Vec<Pending<T>> {
        let Some(front) = self.queue.front() else {
            return Vec::new();
        };
        let key = front.key;
        let mut batch = Vec::new();
        let mut rest = VecDeque::new();
        while let Some(p) = self.queue.pop_front() {
            if p.key == key && batch.len() < self.max_batch {
                batch.push(p);
            } else {
                rest.push_back(p);
            }
        }
        self.queue = rest;
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_group_by_key() {
        let mut b = Batcher::new(8);
        b.push(("dgemm", 256), 1);
        b.push(("dscal", 1024), 2);
        b.push(("dgemm", 256), 3);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].item, 1);
        assert_eq!(batch[1].item, 3);
        assert_eq!(b.len(), 1);
        let batch = b.next_batch();
        assert_eq!(batch[0].item, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(("dscal", 64), i);
        }
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn different_shapes_do_not_batch() {
        let mut b = Batcher::new(8);
        b.push(("dgemm", 128), 0);
        b.push(("dgemm", 256), 1);
        assert_eq!(b.next_batch().len(), 1);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn fifo_across_groups() {
        let mut b = Batcher::new(8);
        b.push(("a", 1), 0);
        b.push(("b", 1), 1);
        b.push(("a", 1), 2);
        b.push(("c", 1), 3);
        let order: Vec<&'static str> = std::iter::from_fn(|| {
            let batch = b.next_batch();
            batch.first().map(|p| p.key.0)
        })
        .take(3)
        .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }
}
