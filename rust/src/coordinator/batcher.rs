//! Batching queue: groups pending requests that share a scheduling key
//! so a worker drains a whole group in one pass (amortizing dispatch
//! and, on the PJRT path, keeping one hot executable in the instruction
//! cache — the serving analog of the paper's kernel locality argument).
//!
//! The key is opaque to the batcher. The server keys by **planned
//! kernel id** (admission-time plans from the
//! [`crate::coordinator::plan::PlanCache`]), so two shapes that resolve
//! to the same registered kernel share a batch window; unplanned (PJRT)
//! requests fall back to a `(routine, dim)` key.
//!
//! FIFO fairness is preserved across groups: groups are served in the
//! arrival order of their oldest member. Internally each key owns a
//! sub-queue and the groups are indexed by their head sequence number,
//! so a drain costs O(batch + log groups) instead of rebuilding the
//! whole queue.
//!
//! [`Batcher::next_batch_where`] makes draining cost-aware: the caller
//! passes an admission predicate (the server's thread-budget check) and
//! the oldest *admissible* group is drained while deferred groups keep
//! their place in line. Since the compute pool took over execution the
//! debited grant is an *admission ticket* bounding how many pool tasks
//! the batch may occupy at once, not a count of threads to spawn — see
//! [`crate::runtime::pool`].

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::hash::Hash;

/// A queued item: an opaque payload plus its batch key.
#[derive(Debug)]
pub struct Pending<K, T> {
    /// Scheduling key the item was pushed under.
    pub key: K,
    /// Globally unique arrival sequence number.
    pub seq: u64,
    /// The opaque payload.
    pub item: T,
}

/// Result of a cost-aware drain: the batch (empty when no group passed
/// the admission predicate) plus how many older groups were deferred —
/// skipped by the predicate — before the drained group was found.
#[derive(Debug)]
pub struct Drain<K, T> {
    /// The drained batch (all one key; empty when nothing passed).
    pub batch: Vec<Pending<K, T>>,
    /// Older groups the admission predicate skipped before this batch.
    pub deferred: usize,
}

/// The batcher. Not thread-safe by itself; the server wraps it in a
/// Mutex+Condvar.
#[derive(Debug)]
pub struct Batcher<K, T> {
    /// Per-key sub-queues; a key present here always has ≥ 1 item.
    queues: HashMap<K, VecDeque<Pending<K, T>>>,
    /// Non-empty groups indexed by their oldest member's seq — the
    /// cross-group FIFO. Seqs are globally unique, so this is a total
    /// order.
    order: BTreeMap<u64, K>,
    len: usize,
    next_seq: u64,
    /// max items drained per batch
    pub max_batch: usize,
}

impl<K: Copy + Eq + Hash, T> Batcher<K, T> {
    /// An empty queue draining at most `max_batch` items per batch
    /// (clamped to at least 1).
    pub fn new(max_batch: usize) -> Batcher<K, T> {
        Batcher {
            queues: HashMap::new(),
            order: BTreeMap::new(),
            len: 0,
            next_seq: 0,
            max_batch: max_batch.max(1),
        }
    }

    /// Enqueue one item under its scheduling key.
    pub fn push(&mut self, key: K, item: T) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let q = self.queues.entry(key).or_default();
        if q.is_empty() {
            self.order.insert(seq, key);
        }
        q.push_back(Pending { key, seq, item });
        self.len += 1;
    }

    /// Total pending items across all groups.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no items are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of distinct pending groups.
    pub fn groups(&self) -> usize {
        self.order.len()
    }

    /// Key of the group at the FIFO head — the one holding the oldest
    /// pending item. The server's anti-starvation aging watches this:
    /// a head that keeps getting bypassed by `next_batch_where`
    /// eventually gets the budget reserved for it.
    pub fn head_key(&self) -> Option<K> {
        self.order.values().next().copied()
    }

    /// Drain the next batch: the oldest request's group, up to max_batch
    /// items, preserving arrival order within the group.
    pub fn next_batch(&mut self) -> Vec<Pending<K, T>> {
        self.next_batch_where(|_| true).batch
    }

    /// Drain the oldest group whose key passes `admit`, up to max_batch
    /// items. Groups that fail the predicate stay queued (and keep
    /// their FIFO position) — their count is reported as `deferred` so
    /// the server's metrics ledger can record scheduling pressure.
    pub fn next_batch_where<F: FnMut(&K) -> bool>(&mut self, mut admit: F)
                                                  -> Drain<K, T> {
        let mut deferred = 0;
        let mut chosen = None;
        for (&seq, key) in self.order.iter() {
            if admit(key) {
                chosen = Some((seq, *key));
                break;
            }
            deferred += 1;
        }
        let Some((seq, key)) = chosen else {
            return Drain { batch: Vec::new(), deferred };
        };
        self.order.remove(&seq);
        let q = self.queues.get_mut(&key).expect("ordered group lost its queue");
        let take = self.max_batch.min(q.len());
        let batch: Vec<Pending<K, T>> = q.drain(..take).collect();
        self.len -= batch.len();
        match q.front() {
            Some(head) => {
                // partial drain: the group re-queues at its new head's
                // arrival position
                let head_seq = head.seq;
                self.order.insert(head_seq, key);
            }
            None => {
                self.queues.remove(&key);
            }
        }
        Drain { batch, deferred }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_group_by_key() {
        let mut b = Batcher::new(8);
        b.push(("dgemm", 256), 1);
        b.push(("dscal", 1024), 2);
        b.push(("dgemm", 256), 3);
        assert_eq!(b.groups(), 2);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].item, 1);
        assert_eq!(batch[1].item, 3);
        assert_eq!(b.len(), 1);
        let batch = b.next_batch();
        assert_eq!(batch[0].item, 2);
        assert!(b.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut b = Batcher::new(2);
        for i in 0..5 {
            b.push(("dscal", 64), i);
        }
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 2);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn different_keys_do_not_batch() {
        let mut b = Batcher::new(8);
        b.push(("dgemm", 128), 0);
        b.push(("dgemm", 256), 1);
        assert_eq!(b.next_batch().len(), 1);
        assert_eq!(b.next_batch().len(), 1);
    }

    #[test]
    fn fifo_across_groups() {
        let mut b = Batcher::new(8);
        b.push(("a", 1), 0);
        b.push(("b", 1), 1);
        b.push(("a", 1), 2);
        b.push(("c", 1), 3);
        let order: Vec<&'static str> = std::iter::from_fn(|| {
            let batch = b.next_batch();
            batch.first().map(|p| p.key.0)
        })
        .take(3)
        .collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn partial_drain_requeues_at_new_head_position() {
        // A(0), A(1), A(2), B(3) with max_batch=2: after draining
        // [0, 1], A's remainder (head seq 2) is still older than B.
        let mut b = Batcher::new(2);
        b.push(("a", 1), 0);
        b.push(("a", 1), 1);
        b.push(("a", 1), 2);
        b.push(("b", 1), 3);
        assert_eq!(b.next_batch().iter().map(|p| p.item).collect::<Vec<_>>(),
                   vec![0, 1]);
        assert_eq!(b.next_batch()[0].item, 2, "A's tail outranks B");
        assert_eq!(b.next_batch()[0].item, 3);
    }

    #[test]
    fn head_key_tracks_the_oldest_group() {
        let mut b = Batcher::new(1);
        assert_eq!(b.head_key(), None);
        b.push(("mt", 4), 0);
        b.push(("s1", 1), 1);
        assert_eq!(b.head_key(), Some(("mt", 4)));
        // bypassing the head does not change it
        let d = b.next_batch_where(|k| k.0 != "mt");
        assert_eq!(d.batch[0].item, 1);
        assert_eq!(b.head_key(), Some(("mt", 4)));
        b.next_batch();
        assert_eq!(b.head_key(), None);
    }

    #[test]
    fn deferred_groups_keep_their_place() {
        // "mt" is inadmissible: serial groups flow past it, and it is
        // drained first once admitted again.
        let mut b = Batcher::new(8);
        b.push(("mt", 4), 0);
        b.push(("s1", 1), 1);
        b.push(("s2", 1), 2);
        let d = b.next_batch_where(|k| k.0 != "mt");
        assert_eq!(d.deferred, 1);
        assert_eq!(d.batch[0].item, 1);
        let d = b.next_batch_where(|k| k.0 != "mt");
        assert_eq!(d.deferred, 1);
        assert_eq!(d.batch[0].item, 2);
        // nothing admissible: empty drain, deferral reported
        let d = b.next_batch_where(|k| k.0 != "mt");
        assert!(d.batch.is_empty());
        assert_eq!(d.deferred, 1);
        assert_eq!(b.len(), 1);
        let d = b.next_batch_where(|_| true);
        assert_eq!(d.batch[0].item, 0);
        assert_eq!(d.deferred, 0);
        assert!(b.is_empty());
    }
}
