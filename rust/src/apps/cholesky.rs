//! Blocked right-looking Cholesky factorization built entirely from
//! FT-BLAS Level-3 routines (DTRSM + DSYRK + DGEMM) — the classic
//! LAPACK dpotrf decomposition, here used as the downstream consumer that
//! exercises the library end to end (examples/solver.rs).

use anyhow::{anyhow, Result};

use crate::blas::level3::{self, GemmParams};
use crate::util::matrix::Matrix;

/// Factor SPD A (lower storage) = L L^T in place; returns L (lower
/// triangle; the strict upper triangle is zeroed).
pub fn dpotrf_lower(a: &Matrix, block: usize, params: &GemmParams)
                    -> Result<Matrix> {
    let n = a.rows;
    if a.cols != n {
        return Err(anyhow!("cholesky needs a square matrix"));
    }
    let mut l = a.clone();
    let nb = block.max(1);
    let mut k = 0;
    while k < n {
        let kb = nb.min(n - k);
        // factor the diagonal block A[k:k+kb, k:k+kb] (unblocked)
        for i in 0..kb {
            let gi = k + i;
            for j in 0..i {
                let gj = k + j;
                let mut s = l.at(gi, gj);
                for p in 0..j {
                    s -= l.at(gi, k + p) * l.at(gj, k + p);
                }
                l.set(gi, gj, s / l.at(gj, gj));
            }
            let mut d = l.at(gi, gi);
            for p in 0..i {
                d -= l.at(gi, k + p) * l.at(gi, k + p);
            }
            if d <= 0.0 {
                return Err(anyhow!("matrix not positive definite at {gi}"));
            }
            l.set(gi, gi, d.sqrt());
        }
        let rest = n - k - kb;
        if rest > 0 {
            // panel solve: L21 = A21 * L11^{-T}  (row-major: each row of
            // A21 solved against L11^T => dtrsm on the transposed system)
            // A21 is (rest x kb); solve X L11^T = A21  =>  L11 X^T = A21^T
            let mut a21t = vec![0.0; kb * rest];
            for r in 0..rest {
                for cidx in 0..kb {
                    a21t[cidx * rest + r] = l.at(k + kb + r, k + cidx);
                }
            }
            let mut l11 = vec![0.0; kb * kb];
            for i in 0..kb {
                for j in 0..=i {
                    l11[i * kb + j] = l.at(k + i, k + j);
                }
            }
            level3::dtrsm_llnn(kb, rest, &l11, &mut a21t, 8, params);
            for r in 0..rest {
                for cidx in 0..kb {
                    l.set(k + kb + r, k + cidx, a21t[cidx * rest + r]);
                }
            }
            // trailing update: A22 -= L21 L21^T (lower triangle)
            let mut l21 = vec![0.0; rest * kb];
            for r in 0..rest {
                for cidx in 0..kb {
                    l21[r * kb + cidx] = l.at(k + kb + r, k + cidx);
                }
            }
            let mut a22 = vec![0.0; rest * rest];
            for r in 0..rest {
                for cc in 0..rest {
                    a22[r * rest + cc] = l.at(k + kb + r, k + kb + cc);
                }
            }
            level3::dsyrk_lower(rest, kb, -1.0, &l21, 1.0, &mut a22, params);
            for r in 0..rest {
                for cc in 0..=r {
                    l.set(k + kb + r, k + kb + cc, a22[r * rest + cc]);
                }
            }
        }
        k += kb;
    }
    // zero the strict upper triangle
    for i in 0..n {
        for j in (i + 1)..n {
            l.set(i, j, 0.0);
        }
    }
    Ok(l)
}

/// Solve SPD A x = b via Cholesky: L L^T x = b (forward + backward
/// substitution through the Level-2 kernels).
pub fn solve_spd(a: &Matrix, b: &[f64], block: usize, params: &GemmParams)
                 -> Result<Vec<f64>> {
    let n = a.rows;
    let l = dpotrf_lower(a, block, params)?;
    // forward: L y = b
    let mut y = b.to_vec();
    crate::blas::level2::dtrsv_lower(n, &l.data, &mut y, 4);
    // backward: L^T x = y  — solve via the transposed lower triangle
    let lt = l.transpose();
    let mut x = y;
    for i in (0..n).rev() {
        let mut acc = x[i];
        for j in (i + 1)..n {
            acc -= lt.data[i * n + j] * x[j];
        }
        x[i] = acc / lt.data[i * n + i];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn factorization_reconstructs() {
        check("cholesky-llt", 8, |g| {
            let n = 8 + 8 * g.rng.below(8);
            let a = Matrix::random_spd(n, &mut g.rng);
            let l = dpotrf_lower(&a, 16, &GemmParams::default())
                .map_err(|e| e.to_string())?;
            // check A == L L^T on the lower triangle
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0;
                    for p in 0..=j {
                        s += l.at(i, p) * l.at(j, p);
                    }
                    let want = a.at(i, j);
                    if (s - want).abs() > 1e-8 * (1.0 + want.abs()) {
                        return Err(format!("LL^T mismatch at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_spd_residual() {
        check("cholesky-solve", 8, |g| {
            let n = 16 + 8 * g.rng.below(6);
            let a = Matrix::random_spd(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let x = solve_spd(&a, &b, 16, &GemmParams::default())
                .map_err(|e| e.to_string())?;
            let mut r = vec![0.0; n];
            crate::blas::naive::dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut r);
            let num: f64 = r.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
            let den: f64 = b.iter().map(|v| v * v).sum();
            ensure((num / den).sqrt() < 1e-8, "residual too large")
        });
    }

    #[test]
    fn not_spd_rejected() {
        let mut rng = Rng::new(2);
        let mut a = Matrix::random_symmetric(8, &mut rng);
        a.set(3, 3, -100.0);
        assert!(dpotrf_lower(&a, 4, &GemmParams::default()).is_err());
    }
}
