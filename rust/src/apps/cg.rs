//! Conjugate Gradient solver for SPD systems built from the FT-BLAS
//! Level-1/2 kernels (DSYMV/DGEMV for the operator apply, DDOT, DAXPY,
//! DSCAL for the vector work) — the iterative-method downstream consumer.
//!
//! A protected variant runs every kernel through the DMR wrappers, which
//! demonstrates the paper's point for iterative methods: a single
//! uncorrected soft error silently poisons *every* subsequent iterate,
//! while the DMR-protected solver converges identically to the clean run
//! (see `examples/solver.rs` and the `iterative_poisoning` test).

use anyhow::{anyhow, Result};

use crate::blas::{level1, level2};
use crate::ft::{dmr, FtReport};
use crate::util::matrix::Matrix;

/// Convergence report of a CG run.
#[derive(Clone, Debug)]
pub struct CgResult {
    /// The solution iterate.
    pub x: Vec<f64>,
    /// Iterations taken.
    pub iterations: usize,
    /// Final relative residual.
    pub residual: f64,
    /// Whether the tolerance was reached.
    pub converged: bool,
    /// Accumulated FT counters across all BLAS calls.
    pub ft: FtReport,
}

/// Plain CG on the tuned (unprotected) kernels.
pub fn solve(a: &Matrix, b: &[f64], tol: f64, max_iter: usize)
             -> Result<CgResult> {
    cg_impl(a, b, tol, max_iter, None)
}

/// DMR-protected CG: every kernel call runs duplicated + verified. An
/// optional fault `(iteration, index, delta)` is injected into that
/// iteration's operator apply (DSYMV) — the protected solver corrects it
/// in place and converges as if nothing happened.
pub fn solve_protected(a: &Matrix, b: &[f64], tol: f64, max_iter: usize,
                       fault: Option<(usize, usize, f64)>) -> Result<CgResult> {
    cg_impl(a, b, tol, max_iter, Some(fault))
}

/// `protect: None` → unprotected kernels; `Some(fault)` → DMR kernels
/// with an optional planned strike.
fn cg_impl(a: &Matrix, b: &[f64], tol: f64, max_iter: usize,
           protect: Option<Option<(usize, usize, f64)>>) -> Result<CgResult> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return Err(anyhow!("cg needs square A and matching b"));
    }
    let mut ft = FtReport::none();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec(); // r = b - A·0
    let mut p = r.clone();
    let bnorm = level1::dnrm2(b).max(f64::MIN_POSITIVE);
    let mut rsq = level1::ddot(&r, &r);

    for it in 0..max_iter {
        let res = rsq.sqrt() / bnorm;
        if res < tol {
            return Ok(CgResult {
                x,
                iterations: it,
                residual: res,
                converged: true,
                ft,
            });
        }
        // q = A p (the operator apply — the hot kernel)
        let mut q = vec![0.0; n];
        match protect {
            None => level2::dsymv_lower(n, 1.0, &a.data, &p, 0.0, &mut q),
            Some(fault) => {
                let inj = fault.and_then(|(fit, idx, delta)| {
                    (fit == it).then_some((idx % n, delta))
                });
                ft.merge(dmr::dsymv_ft(n, 1.0, &a.data, &p, 0.0, &mut q, inj));
            }
        }
        let pq = match protect {
            None => level1::ddot(&p, &q),
            Some(_) => {
                let (d, rep) = dmr::ddot_ft(&p, &q, None);
                ft.merge(rep);
                d
            }
        };
        if pq <= 0.0 {
            return Err(anyhow!("matrix not SPD (p·Ap = {pq} at iter {it})"));
        }
        let alpha = rsq / pq;
        // x += alpha p ; r -= alpha q
        match protect {
            None => {
                level1::daxpy(alpha, &p, &mut x);
                level1::daxpy(-alpha, &q, &mut r);
            }
            Some(_) => {
                ft.merge(dmr::daxpy_ft(alpha, &p, &mut x, None));
                ft.merge(dmr::daxpy_ft(-alpha, &q, &mut r, None));
            }
        }
        let rsq_new = match protect {
            None => level1::ddot(&r, &r),
            Some(_) => {
                let (d, rep) = dmr::ddot_ft(&r, &r, None);
                ft.merge(rep);
                d
            }
        };
        let beta = rsq_new / rsq;
        rsq = rsq_new;
        // p = r + beta p
        match protect {
            None => {
                level1::dscal(beta, &mut p);
                level1::daxpy(1.0, &r, &mut p);
            }
            Some(_) => {
                ft.merge(dmr::dscal_ft(beta, &mut p, None));
                ft.merge(dmr::daxpy_ft(1.0, &r, &mut p, None));
            }
        }
    }
    let res = rsq.sqrt() / bnorm;
    Ok(CgResult {
        x,
        iterations: max_iter,
        residual: res,
        converged: res < tol,
        ft,
    })
}

/// Unprotected CG with a raw injected fault (no detection): shows how a
/// single soft error in the operator apply poisons the iteration — the
/// baseline the paper's protected library is compared against.
pub fn solve_unprotected_faulty(a: &Matrix, b: &[f64], tol: f64,
                                max_iter: usize,
                                fault: (usize, usize, f64)) -> Result<CgResult> {
    let n = a.rows;
    if a.cols != n || b.len() != n {
        return Err(anyhow!("cg needs square A and matching b"));
    }
    let (fit, fidx, fdelta) = fault;
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let bnorm = level1::dnrm2(b).max(f64::MIN_POSITIVE);
    let mut rsq = level1::ddot(&r, &r);
    for it in 0..max_iter {
        let res = rsq.sqrt() / bnorm;
        if res < tol {
            return Ok(CgResult {
                x,
                iterations: it,
                residual: res,
                converged: true,
                ft: FtReport::none(),
            });
        }
        let mut q = vec![0.0; n];
        level2::dsymv_lower(n, 1.0, &a.data, &p, 0.0, &mut q);
        if it == fit {
            q[fidx % n] += fdelta; // the undetected soft error
        }
        let pq = level1::ddot(&p, &q);
        if pq <= 0.0 {
            // the corrupted operator broke positive-definiteness
            return Ok(CgResult {
                x,
                iterations: it,
                residual: f64::INFINITY,
                converged: false,
                ft: FtReport::none(),
            });
        }
        let alpha = rsq / pq;
        level1::daxpy(alpha, &p, &mut x);
        level1::daxpy(-alpha, &q, &mut r);
        let rsq_new = level1::ddot(&r, &r);
        let beta = rsq_new / rsq;
        rsq = rsq_new;
        level1::dscal(beta, &mut p);
        level1::daxpy(1.0, &r, &mut p);
    }
    let res = rsq.sqrt() / bnorm;
    Ok(CgResult {
        x,
        iterations: max_iter,
        residual: res,
        converged: res < tol,
        ft: FtReport::none(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};

    fn true_residual(a: &Matrix, x: &[f64], b: &[f64]) -> f64 {
        let n = a.rows;
        let mut r = vec![0.0; n];
        crate::blas::naive::dgemv(n, n, 1.0, &a.data, x, 0.0, &mut r);
        let num: f64 = r.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum();
        let den: f64 = b.iter().map(|v| v * v).sum();
        (num / den).sqrt()
    }

    #[test]
    fn converges_on_spd() {
        check("cg-converge", 8, |g| {
            let n = 16 + 16 * g.rng.below(8);
            let a = Matrix::random_spd(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let r = solve(&a, &b, 1e-10, 10 * n).map_err(|e| e.to_string())?;
            ensure(r.converged, format!("cg failed: res {}", r.residual))?;
            ensure(true_residual(&a, &r.x, &b) < 1e-8, "true residual large")
        });
    }

    #[test]
    fn protected_matches_clean_under_fault() {
        check("cg-protected", 8, |g| {
            let n = 32 + 16 * g.rng.below(6);
            let a = Matrix::random_spd(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let clean = solve(&a, &b, 1e-10, 10 * n).map_err(|e| e.to_string())?;
            let fault = (g.rng.below(5), g.rng.below(n), g.rng.range(1e3, 1e6));
            let prot = solve_protected(&a, &b, 1e-10, 10 * n, Some(fault))
                .map_err(|e| e.to_string())?;
            ensure(prot.converged, "protected cg did not converge")?;
            ensure(prot.ft.errors_detected >= 1, "fault not detected")?;
            ensure(true_residual(&a, &prot.x, &b) < 1e-8,
                   "protected solution inaccurate")?;
            // same iteration count as the clean run: the correction is
            // transparent to the iteration trajectory
            ensure(prot.iterations == clean.iterations,
                   format!("iters {} vs clean {}", prot.iterations,
                           clean.iterations))
        });
    }

    #[test]
    fn iterative_poisoning_without_protection() {
        check("cg-poison", 8, |g| {
            let n = 64;
            let a = Matrix::random_spd(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let clean = solve(&a, &b, 1e-10, 4 * n).map_err(|e| e.to_string())?;
            // strike early, large: the unprotected run must degrade
            let fault = (1, g.rng.below(n), 1e8);
            let bad = solve_unprotected_faulty(&a, &b, 1e-10, clean.iterations,
                                               fault)
                .map_err(|e| e.to_string())?;
            // within the clean run's iteration budget the poisoned run
            // cannot reach the clean solution quality
            ensure(!bad.converged
                       || true_residual(&a, &bad.x, &b)
                           > 10.0 * true_residual(&a, &clean.x, &b),
                   "fault did not degrade the unprotected run?")
        });
    }

    #[test]
    fn rejects_bad_shapes() {
        let a = Matrix::zeros(4, 5);
        assert!(solve(&a, &[0.0; 4], 1e-8, 10).is_err());
    }
}
