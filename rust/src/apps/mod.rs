//! Downstream applications built on the FT-BLAS public API — proof that
//! the library composes (DESIGN.md S10).

pub mod cg;
pub mod cholesky;
pub mod lu;
