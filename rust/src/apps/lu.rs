//! Blocked right-looking LU factorization with partial pivoting, built
//! from the FT-BLAS kernels (IDAMAX for pivot search, DSWAP-style row
//! exchange, DSCAL for the column scale, DTRSM + DGEMM for the panel
//! solve and trailing update) — the classic LAPACK dgetrf decomposition,
//! used as a second downstream consumer of the library.

use anyhow::{anyhow, Result};

use crate::blas::level3::{self, GemmParams};
use crate::blas::{level1, level2};
use crate::util::matrix::Matrix;

/// Result of an LU factorization: PA = LU packed into one matrix
/// (unit-lower L below the diagonal, U on and above) plus the pivot
/// permutation `piv` (row i was swapped with `piv[i]` at step i).
#[derive(Clone, Debug)]
pub struct LuFactors {
    /// Packed L (unit lower) and U factors.
    pub lu: Matrix,
    /// Row-pivot permutation.
    pub piv: Vec<usize>,
}

/// Factor A = P L U with partial pivoting, blocked right-looking
/// (LAPACK dgetrf shape). `block` is the panel width.
pub fn dgetrf(a: &Matrix, block: usize, params: &GemmParams)
              -> Result<LuFactors> {
    let n = a.rows;
    if a.cols != n {
        return Err(anyhow!("lu needs a square matrix"));
    }
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();
    let nb = block.max(1);
    let mut k = 0;
    while k < n {
        let kb = nb.min(n - k);
        // ---- panel factorization (unblocked, with pivoting) on
        // columns k..k+kb
        for j in k..k + kb {
            // pivot search down column j (IDAMAX over the subcolumn)
            let col: Vec<f64> = (j..n).map(|r| lu.at(r, j)).collect();
            let p = j + level1::idamax(&col);
            if lu.at(p, j) == 0.0 {
                return Err(anyhow!("singular matrix at column {j}"));
            }
            if p != j {
                lu.swap_rows(p, j);
                piv.swap(p, j);
            }
            // scale the subcolumn (DSCAL on the strided column — gathered
            // to a contiguous buffer first, like a packed panel)
            let inv = 1.0 / lu.at(j, j);
            let mut sub: Vec<f64> = ((j + 1)..n).map(|r| lu.at(r, j)).collect();
            level1::dscal(inv, &mut sub);
            for (off, v) in sub.iter().enumerate() {
                lu.set(j + 1 + off, j, *v);
            }
            // rank-1 update of the remaining panel columns (DGER shape,
            // restricted to the panel)
            let hi = (k + kb).min(n);
            if j + 1 < hi {
                let xs: Vec<f64> = ((j + 1)..n).map(|r| lu.at(r, j)).collect();
                let ys: Vec<f64> = ((j + 1)..hi).map(|c| lu.at(j, c)).collect();
                let mut ablk = vec![0.0; xs.len() * ys.len()];
                for (r, _) in xs.iter().enumerate() {
                    for (c, _) in ys.iter().enumerate() {
                        ablk[r * ys.len() + c] = lu.at(j + 1 + r, j + 1 + c);
                    }
                }
                level2::dger(xs.len(), ys.len(), -1.0, &xs, &ys, &mut ablk);
                for r in 0..xs.len() {
                    for c in 0..ys.len() {
                        lu.set(j + 1 + r, j + 1 + c, ablk[r * ys.len() + c]);
                    }
                }
            }
        }
        let rest = n - k - kb;
        if rest > 0 {
            // ---- U12 = L11^{-1} A12 (unit-lower TRSM on the panel)
            let mut l11 = vec![0.0; kb * kb];
            for i in 0..kb {
                for j in 0..i {
                    l11[i * kb + j] = lu.at(k + i, k + j);
                }
                l11[i * kb + i] = 1.0; // unit diagonal
            }
            let mut a12 = vec![0.0; kb * rest];
            for i in 0..kb {
                for j in 0..rest {
                    a12[i * rest + j] = lu.at(k + i, k + kb + j);
                }
            }
            level3::dtrsm_llnn(kb, rest, &l11, &mut a12, 8, params);
            for i in 0..kb {
                for j in 0..rest {
                    lu.set(k + i, k + kb + j, a12[i * rest + j]);
                }
            }
            // ---- trailing update A22 -= L21 U12 (DGEMM)
            let mut l21 = vec![0.0; rest * kb];
            for i in 0..rest {
                for j in 0..kb {
                    l21[i * kb + j] = lu.at(k + kb + i, k + j);
                }
            }
            let mut a22 = vec![0.0; rest * rest];
            for i in 0..rest {
                for j in 0..rest {
                    a22[i * rest + j] = lu.at(k + kb + i, k + kb + j);
                }
            }
            level3::dgemm(rest, rest, kb, -1.0, &l21, &a12, 1.0, &mut a22,
                          params);
            for i in 0..rest {
                for j in 0..rest {
                    lu.set(k + kb + i, k + kb + j, a22[i * rest + j]);
                }
            }
        }
        k += kb;
    }
    Ok(LuFactors { lu, piv })
}

/// Solve A x = b given PA = LU: apply the permutation, then forward
/// (unit-lower) and backward (upper) substitution.
pub fn lu_solve(f: &LuFactors, b: &[f64]) -> Vec<f64> {
    let n = f.lu.rows;
    assert_eq!(b.len(), n);
    // apply P: piv was built by successive swaps, replay them
    let mut x = b.to_vec();
    // reconstruct the swap sequence: piv[i] holds the final source row of
    // position i — replay by permutation application
    let mut xp = vec![0.0; n];
    for (i, &src) in f.piv.iter().enumerate() {
        xp[i] = x[src];
    }
    x = xp;
    // forward: L y = Pb (unit diagonal)
    for i in 0..n {
        let mut acc = x[i];
        for j in 0..i {
            acc -= f.lu.at(i, j) * x[j];
        }
        x[i] = acc;
    }
    // backward: U x = y
    for i in (0..n).rev() {
        let mut acc = x[i];
        for j in (i + 1)..n {
            acc -= f.lu.at(i, j) * x[j];
        }
        x[i] = acc / f.lu.at(i, i);
    }
    x
}

/// Convenience: solve A x = b end to end.
pub fn solve(a: &Matrix, b: &[f64], block: usize, params: &GemmParams)
             -> Result<Vec<f64>> {
    let f = dgetrf(a, block, params)?;
    Ok(lu_solve(&f, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn lu_reconstructs_pa() {
        check("lu-palu", 10, |g| {
            let n = 4 + g.rng.below(60);
            let a = Matrix::random(n, n, &mut g.rng);
            let f = dgetrf(&a, 16, &GemmParams::default())
                .map_err(|e| e.to_string())?;
            // PA == LU: L unit-lower, U upper, both packed in f.lu
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for p in 0..=i.min(j) {
                        let lip = if p == i { 1.0 } else { f.lu.at(i, p) };
                        s += lip * f.lu.at(p, j);
                    }
                    let want = a.at(f.piv[i], j);
                    if (s - want).abs() > 1e-8 * (1.0 + want.abs()) {
                        return Err(format!(
                            "PA != LU at ({i},{j}): {s} vs {want}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_residual_small() {
        check("lu-solve", 10, |g| {
            let n = 8 + 8 * g.rng.below(16);
            let a = Matrix::random_diag_dominant(n, &mut g.rng);
            let b = g.rng.normal_vec(n);
            let x = solve(&a, &b, 24, &GemmParams::default())
                .map_err(|e| e.to_string())?;
            let mut r = vec![0.0; n];
            crate::blas::naive::dgemv(n, n, 1.0, &a.data, &x, 0.0, &mut r);
            let num: f64 = r.iter().zip(&b).map(|(p, q)| (p - q) * (p - q)).sum();
            let den: f64 = b.iter().map(|v| v * v).sum();
            ensure((num / den).sqrt() < 1e-9, "lu residual too large")
        });
    }

    #[test]
    fn pivoting_actually_pivots() {
        // a matrix that requires pivoting (zero leading diagonal)
        let a = Matrix::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let f = dgetrf(&a, 2, &GemmParams::default()).expect("pivots");
        assert_eq!(f.piv, vec![1, 0]);
        let x = lu_solve(&f, &[3.0, 5.0]);
        assert!((x[0] - 5.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn singular_rejected() {
        let mut rng = Rng::new(5);
        let mut a = Matrix::random(6, 6, &mut rng);
        for j in 0..6 {
            a.set(2, j, 0.0); // a zero row
        }
        // row 2 zero => at some column the pivot search finds only zeros
        assert!(dgetrf(&a, 3, &GemmParams::default()).is_err());
    }
}
