//! Benchmark harness (deliverable (d)): regenerates every table and
//! figure of the paper's evaluation section. criterion is not vendored in
//! this offline image, so `rust/benches/*` are `harness = false` binaries
//! that call into this module; `ftblas bench --exp <id>` reaches the same
//! drivers directly.

pub mod ablations;
pub mod figures_ft;
pub mod figures_perf;
pub mod harness;

pub use harness::{BenchCtx, Row};

/// Run one experiment by id (table1, fig5..fig11).
pub fn run(id: &str, ctx: &mut harness::BenchCtx) -> anyhow::Result<()> {
    match id {
        "smoke" => figures_perf::smoke(ctx),
        "table1" => figures_perf::table1(ctx),
        "fig5" => figures_perf::fig5(ctx),
        "fig6" => figures_perf::fig6(ctx),
        "fig7" => figures_perf::fig7(ctx),
        "fig8a" => figures_ft::fig8a(ctx),
        "fig8b" => figures_ft::fig8b(ctx),
        "fig9" => figures_ft::fig9(ctx),
        "fig10" => figures_ft::fig10(ctx),
        "fig11" => figures_ft::fig11(ctx),
        "ablation-kc" => ablations::ablation_kc(ctx),
        "ablation-trsm-panel" => ablations::ablation_trsm_panel(ctx),
        "ablation-threads" => ablations::ablation_threads(ctx),
        "ablation-weighted" => ablations::ablation_weighted(ctx),
        "ablations" => {
            ablations::ablation_kc(ctx)?;
            ablations::ablation_trsm_panel(ctx)?;
            ablations::ablation_threads(ctx)?;
            ablations::ablation_weighted(ctx)
        }
        "all" => {
            for id in ["table1", "fig5", "fig6", "fig7", "fig8a", "fig8b",
                       "fig9", "fig10", "fig11"] {
                run(id, ctx)?;
            }
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown experiment `{other}`")),
    }
}
