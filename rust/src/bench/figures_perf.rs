//! Performance figures without fault injection: Table 1, Figs. 5-7.
//!
//! Variant ladders (naive / blocked / tuned / simd) are enumerated from
//! the kernel registry — adding a variant to the registry adds its
//! bench row; the figures keep no hand-maintained kernel lists.

use anyhow::Result;
use std::hint::black_box;

use crate::bench::harness::{
    self, header, print_rows, registry_variant_rows, row, BenchCtx, Row,
};
use crate::blas::batched::{self, GemmItem};
use crate::blas::level3::GemmParams;
use crate::blas::{level2, parallel, simd, stepwise};
use crate::coordinator::registry::{ExecCtx, KernelRegistry};
use crate::coordinator::request::{Backend, BlasRequest};
use crate::ft::policy::FtPolicy;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

fn l1_n(ctx: &BenchCtx) -> usize {
    // paper: averaged over 5e6..7e6 elements
    if ctx.quick { 1 << 20 } else { 6_000_000 }
}

fn l2_n(ctx: &BenchCtx) -> usize {
    if ctx.quick { 512 } else { 2048 }
}

fn l3_n(ctx: &BenchCtx) -> usize {
    if ctx.quick { 256 } else { 768 }
}

/// Table 1: the optimization-feature survey, reproduced as the feature
/// matrix of our own variants plus microbench evidence per feature.
pub fn table1(_ctx: &mut BenchCtx) -> Result<()> {
    header("Table 1", "Level-1 routine optimization survey (our variants)");
    println!("{:<10} {:<28} {:<28}", "routine", "blocked (OpenBLAS-sim)",
             "tuned (FT-BLAS Ori)");
    let rows = [
        ("dscal", "SIMD-width, unroll, NO prefetch", "SIMD-width, unroll, prefetch"),
        ("dnrm2", "SSE2-width (2 lanes)", "AVX512-width (8 lanes), prefetch"),
        ("ddot", "single accumulator", "4 accumulator chains, prefetch"),
        ("daxpy", "scalar loop", "SIMD-width, unroll, prefetch"),
        ("dcopy", "memcpy", "memcpy"),
    ];
    for (r, b, t) in rows {
        println!("{r:<10} {b:<28} {t:<28}");
    }
    println!("(paper Table 1: OpenBLAS ships DNRM2 as SSE-only and DSCAL \
              without prefetch — the gaps FT-BLAS exploits)");
    Ok(())
}

/// CI smoke: one registry-driven row set at tiny dims plus the batched
/// small-GEMM pair. Exercises the descriptor-table bench path (registry
/// enumeration → `ExecCtx` → kernel → Row) and the batch-fused driver
/// end to end in well under a second, so the bench plumbing cannot
/// silently rot between full runs.
pub fn smoke(ctx: &mut BenchCtx) -> Result<()> {
    header("smoke", "registry bench path at tiny dims");
    let n = 32;
    let mut rng = Rng::new(0x5304E);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: Matrix::random(n, n, &mut rng),
        b: Matrix::random(n, n, &mut rng),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };
    let mut rows = registry_variant_rows(ctx, &req, 2.0 * (n * n * n) as f64);
    // a hard failure, not harness::expect's warning: this row set going
    // empty is exactly the rot the CI smoke step exists to catch
    if rows.is_empty() {
        anyhow::bail!("bench smoke: registry produced no dgemm rows");
    }
    print_rows(&rows);

    // ---- batched small-GEMM pair: the fusion win the batcher exploits.
    // A per-call baseline (the serial SIMD kernel once per item — what
    // an unfused batch of below-banding-floor items costs) against the
    // batch-fused driver draining the *same* items as one task queue
    // under one thread scope. Labels are stable: `bench-diff` gates the
    // batched row against its committed baseline like any other kernel.
    let batch = 16usize;
    let (bm, bn, bk) = (32usize, 32usize, 32usize);
    let mats: Vec<(Matrix, Matrix)> = (0..batch)
        .map(|_| (Matrix::random(bm, bk, &mut rng),
                  Matrix::random(bk, bn, &mut rng)))
        .collect();
    let params = GemmParams::default();
    let bflops = (batch * 2 * bm * bn * bk) as f64;
    let mut outs: Vec<Vec<f64>> = vec![vec![0.0; bm * bn]; batch];
    let mut brows = Vec::new();
    brows.push(row(ctx, "dgemm/small-batch/per-call-simd", bflops,
                   "16x 32^3, one simd call per item", || {
        for ((a, b), c) in mats.iter().zip(outs.iter_mut()) {
            simd::dgemm(bm, bn, bk, 1.0, &a.data, &b.data, 0.0, c, &params);
        }
    }));
    brows.push(row(ctx, "dgemm/small-batch/batched-simd", bflops,
                   "same items, one fused task queue (4 threads)", || {
        let mut items: Vec<GemmItem<'_>> = mats
            .iter()
            .zip(outs.iter_mut())
            .map(|((a, b), c)| GemmItem {
                m: bm, n: bn, k: bk, alpha: 1.0, beta: 0.0,
                a: &a.data, b: &b.data, c: &mut c[..],
                inject: Vec::new(),
            })
            .collect();
        batched::dgemm_batched_simd(&mut items, &params, 4);
    }));
    print_rows(&brows);
    rows.extend(brows);

    // ---- MT scoped vs pooled pair: the per-call fork/join the
    // persistent compute pool eliminates. Both rows run the identical
    // banded SIMD MT frame at the same grant; only the threading
    // substrate differs — a `std::thread::scope` per call (the
    // `--no-pool` A/B mode) vs task submission to one long-lived pool.
    // Labels are stable so `bench-diff` gates the pooled row against
    // its committed baseline like any other kernel.
    let (pm, pn, pk) = (128usize, 64usize, 64usize);
    let pa = Matrix::random(pm, pk, &mut rng);
    let pb = Matrix::random(pk, pn, &mut rng);
    let mut pc = vec![0.0; pm * pn];
    let pflops = (2 * pm * pn * pk) as f64;
    let mut prows = Vec::new();
    prows.push(row(ctx, "dgemm/mt-scoped", pflops,
                   "128x64x64, 4 threads, scope per call", || {
        parallel::dgemm_simd_mt(pm, pn, pk, 1.0, &pa.data, &pb.data, 0.0,
                                &mut pc, &params, 4);
    }));
    {
        let compute =
            std::sync::Arc::new(crate::runtime::pool::ComputePool::new(4));
        let _guard = crate::runtime::pool::enter(compute);
        prows.push(row(ctx, "dgemm/mt-pooled", pflops,
                       "same frame on the persistent pool", || {
            parallel::dgemm_simd_mt(pm, pn, pk, 1.0, &pa.data, &pb.data,
                                    0.0, &mut pc, &params, 4);
        }));
    }
    print_rows(&prows);
    rows.extend(prows);

    // ---- simulated GPU tiers: the warp-tiled peer-backend executors,
    // enumerated from the registry like the native ladder so adding a
    // tier adds its row. Each runs under the first policy its
    // descriptor serves — the fused-ABFT tiers do not serve the
    // unprotected policy at all, so their rows price the checksum
    // frame in, exactly as selection would deliver them.
    let mut grows = Vec::new();
    for entry in KernelRegistry::global().for_routine("dgemm") {
        if entry.backend != Backend::GpuSim || !entry.serves_dim(n) {
            continue;
        }
        let ectx = ExecCtx {
            req: &req,
            profile: &ctx.profile,
            policy: entry.policies[0],
            faults: &[],
            threads: 1,
        };
        grows.push(row(ctx, entry.name, 2.0 * (n * n * n) as f64,
                       entry.summary, || {
            black_box((entry.execute)(&ectx));
        }));
    }
    print_rows(&grows);
    rows.extend(grows);

    if let Some(path) = &ctx.out {
        let doc = harness::rows_json("smoke", ctx.profile.name, ctx.quick,
                                     &rows);
        harness::write_json(path, &doc)?;
        println!("[bench] smoke rows written to {}", path.display());
    }
    Ok(())
}

/// Fig. 5: selected Level-1/2 routines vs the baselines, one registry
/// ladder per routine.
pub fn fig5(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 5", "Level-1/2 BLAS: FT-BLAS Ori vs naive/blocked/XLA");
    let mut rng = Rng::new(55);
    let n1 = l1_n(ctx);

    // ---- DSCAL
    let req = BlasRequest::Dscal { alpha: 1.0000001, x: rng.normal_vec(n1) };
    let rows = registry_variant_rows(ctx, &req, n1 as f64);
    print_rows(&rows);
    harness::expect(rows[2].gflops >= rows[1].gflops * 0.97,
                    "paper: tuned DSCAL >= blocked (+3.85%)")?;

    // ---- DNRM2
    let req = BlasRequest::Dnrm2 { x: rng.normal_vec(n1) };
    let rows = registry_variant_rows(ctx, &req, 2.0 * n1 as f64);
    print_rows(&rows);
    harness::expect(rows[2].gflops > rows[1].gflops,
                    "paper: AVX-512 DNRM2 beats SSE2 (+17.89%)")?;

    // ---- DGEMV
    let n2 = l2_n(ctx);
    let req = BlasRequest::Dgemv {
        alpha: 1.0,
        a: Matrix::random(n2, n2, &mut rng),
        x: rng.normal_vec(n2),
        beta: 0.0,
        y: rng.normal_vec(n2),
    };
    let rows = registry_variant_rows(ctx, &req, 2.0 * (n2 * n2) as f64);
    print_rows(&rows);

    // ---- DTRSV: the registry ladder (blocked = B=64 OpenBLAS default,
    // tuned = the paper's B=4) plus the explicit panel ablation row
    let l = Matrix::random_lower_triangular(n2, &mut rng);
    let b = rng.normal_vec(n2);
    let req = BlasRequest::Dtrsv { a: l.clone(), b: b.clone() };
    let fl = (n2 * n2) as f64;
    let mut rows = registry_variant_rows(ctx, &req, fl);
    let mut xs = b.clone();
    rows.push(row(ctx, "dtrsv/tuned(B=64 ablation)", fl,
                  "tuned kernel forced to the OpenBLAS panel", || {
        xs.copy_from_slice(&b);
        level2::dtrsv_lower(n2, &l.data, &mut xs, 64);
    }));
    print_rows(&rows);

    // ---- PJRT (XLA / MKL-sim) columns where artifacts exist
    if ctx.pjrt.is_some() {
        pjrt_l12_rows(ctx)?;
    }
    Ok(())
}

fn pjrt_l12_rows(ctx: &mut BenchCtx) -> Result<()> {
    let mut rng = Rng::new(56);
    println!("-- PJRT artifact backend (XLA, closed-source-vendor stand-in) --");
    let mut rows = Vec::new();
    let n = 262144;
    {
        let pjrt = ctx.pjrt.as_ref().unwrap();
        let req = BlasRequest::Dscal { alpha: 1.01, x: rng.normal_vec(n) };
        if pjrt.supports(&req, FtPolicy::None) {
            pjrt.execute(&req, FtPolicy::None, None)?; // warm compile
            let s = ctx.time(|| {
                ctx.pjrt.as_ref().unwrap()
                    .execute(&req, FtPolicy::None, None).unwrap();
            });
            rows.push(Row {
                label: format!("dscal/pjrt n={n}"),
                gflops: n as f64 / s.mean / 1e9,
                seconds: s.mean,
                note: "incl. host<->device copies".into(),
            });
        }
    }
    for n2 in [256usize, 512, 1024] {
        let a = Matrix::random(n2, n2, &mut rng);
        let req = BlasRequest::Dgemv {
            alpha: 1.0, a, x: rng.normal_vec(n2), beta: 0.0,
            y: rng.normal_vec(n2),
        };
        let supported = ctx.pjrt.as_ref().unwrap().supports(&req, FtPolicy::None);
        if supported {
            ctx.pjrt.as_ref().unwrap().execute(&req, FtPolicy::None, None)?;
            let s = ctx.time(|| {
                ctx.pjrt.as_ref().unwrap()
                    .execute(&req, FtPolicy::None, None).unwrap();
            });
            rows.push(Row {
                label: format!("dgemv/pjrt n={n2}"),
                gflops: 2.0 * (n2 * n2) as f64 / s.mean / 1e9,
                seconds: s.mean,
                note: "".into(),
            });
        }
    }
    print_rows(&rows);
    Ok(())
}

/// Fig. 6: Level-3 routines vs baselines, enumerated from the registry.
pub fn fig6(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 6", "Level-3 BLAS: DGEMM / DTRSM vs baselines");
    let mut rng = Rng::new(66);
    let n = l3_n(ctx);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let c0 = Matrix::random(n, n, &mut rng);

    let req = BlasRequest::Dgemm {
        alpha: 1.0, a: a.clone(), b: b.clone(), beta: 0.0, c: c0.clone(),
    };
    let rows = registry_variant_rows(ctx, &req, 2.0 * (n * n * n) as f64);
    print_rows(&rows);

    // ---- DTRSM: scalar diagonal (blocked) vs tuned diagonal kernel
    let l = Matrix::random_lower_triangular(n, &mut rng);
    let req = BlasRequest::Dtrsm { a: l, b: b.clone() };
    let rows = registry_variant_rows(ctx, &req, (n * n * n) as f64);
    print_rows(&rows);
    harness::expect(
        rows[2].gflops >= rows[1].gflops,
        "paper: tuned DTRSM beats the scalar-diagonal prototype (+22.19%)")?;

    // PJRT dgemm artifacts
    if ctx.pjrt.is_some() {
        println!("-- PJRT artifact backend --");
        let mut rows = Vec::new();
        for np in [128usize, 256, 512] {
            let a = Matrix::random(np, np, &mut rng);
            let b = Matrix::random(np, np, &mut rng);
            let req = BlasRequest::Dgemm {
                alpha: 1.0, a, b, beta: 0.0, c: Matrix::zeros(np, np),
            };
            if ctx.pjrt.as_ref().unwrap().supports(&req, FtPolicy::None) {
                ctx.pjrt.as_ref().unwrap().execute(&req, FtPolicy::None, None)?;
                let s = ctx.time(|| {
                    ctx.pjrt.as_ref().unwrap()
                        .execute(&req, FtPolicy::None, None).unwrap();
                });
                rows.push(Row {
                    label: format!("dgemm/pjrt n={np}"),
                    gflops: 2.0 * (np * np * np) as f64 / s.mean / 1e9,
                    seconds: s.mean,
                    note: "".into(),
                });
            }
        }
        print_rows(&rows);
    }
    Ok(())
}

/// Fig. 7: the DSCAL DMR optimization ladder — FT overhead per step.
pub fn fig7(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 7", "DSCAL step-wise optimization, FT vs non-FT overhead");
    let n = l1_n(ctx);
    let mut rng = Rng::new(77);
    let x0 = rng.normal_vec(n);
    let alpha = 1.0000001; // keep values stable across many in-place reps

    let mut table = Vec::new();
    for step in stepwise::STEPS {
        let mut xa = x0.clone();
        let mut xb = x0.clone();
        let (ori, ft) = ctx.time_pair(
            || (step.ori)(black_box(alpha), &mut xa),
            || {
                black_box((step.ft)(black_box(alpha), &mut xb, None));
            },
        );
        table.push((step.name.to_string(), ori, ft,
                    Some(step.paper_overhead_pct)));
    }
    harness::print_overhead_table("step", &table);
    let first = harness::overhead_pct(table[0].1, table[0].2);
    let last = harness::overhead_pct(table[table.len() - 1].1,
                                     table[table.len() - 1].2);
    harness::expect(last < first,
                    "paper: overhead falls monotonically 50.8% -> 0.36%")?;
    Ok(())
}
