//! Ablations of the design choices DESIGN.md calls out (not paper
//! figures, but the knobs behind them):
//!
//! - **A1 — verification interval K_C**: the fused-ABFT overhead as a
//!   function of the rank-k step size. Smaller intervals catch more
//!   errors per run but pay more O(m+n) verifications and thinner
//!   packing; the paper picks K_C = the GEMM's cache-blocking step.
//! - **A2 — DTRSM panel width**: the diagonal-solve vs panel-GEMM split
//!   (§3.2.2's "minimize B" argument inverts once the diagonal solve is
//!   vectorized — measured, this is why the profile ships B = 64).
//! - **A3 — thread scaling**: the parallel row-band GEMM, plain and
//!   fused-ABFT, 1..=4 threads — FT protection is band-local so its
//!   overhead must not grow with the thread count.

use anyhow::Result;

use crate::bench::harness::{self, header, print_rows, BenchCtx, Row};
use crate::blas::level3::{self, GemmParams};
use crate::coordinator::registry::{ExecCtx, KernelRegistry, Scheme};
use crate::coordinator::request::BlasRequest;
use crate::ft::abft_fused;
use crate::ft::policy::FtPolicy;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;
use crate::util::stats;

/// A1: fused-ABFT overhead vs verification interval K_C.
pub fn ablation_kc(ctx: &mut BenchCtx) -> Result<()> {
    header("Ablation A1", "fused-ABFT overhead vs verification interval K_C");
    let n = if ctx.quick { 256 } else { 384 };
    let mut rng = Rng::new(0xA1);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let base_params = ctx.profile.gemm;

    let mut table = Vec::new();
    for kc in [16usize, 32, 64, 128, 256] {
        let params = GemmParams { kc, ..base_params };
        let mut c1 = vec![0.0; n * n];
        let mut c2 = vec![0.0; n * n];
        let (ori, ft) = ctx.time_pair(
            || {
                c1.fill(0.0);
                level3::dgemm(n, n, n, 1.0, &a.data, &b.data, 0.0, &mut c1,
                              &params);
            },
            || {
                c2.fill(0.0);
                std::hint::black_box(abft_fused::dgemm_abft_fused(
                    n, n, n, 1.0, &a.data, &b.data, 0.0, &mut c2, &params,
                    &[]));
            },
        );
        let intervals = n.div_ceil(kc);
        table.push((format!("kc={kc} ({intervals} intervals)"), ori, ft,
                    None));
    }
    harness::print_overhead_table("interval", &table);
    println!("(more intervals -> more correctable errors per run, more \
              verification passes; the profile ships kc={} — the GEMM's \
              own cache-blocking step)", base_params.kc);
    Ok(())
}

/// A2: tuned DTRSM wallclock vs panel width.
pub fn ablation_trsm_panel(ctx: &mut BenchCtx) -> Result<()> {
    header("Ablation A2", "DTRSM panel width (diagonal solve vs GEMM split)");
    let n = if ctx.quick { 384 } else { 768 };
    let mut rng = Rng::new(0xA2);
    let l = Matrix::random_lower_triangular(n, &mut rng);
    let b0 = Matrix::random(n, n, &mut rng);
    let params = ctx.profile.gemm;
    let fl = (n * n * n) as f64;

    let mut rows = Vec::new();
    for panel in [8usize, 16, 32, 64, 128] {
        let s = ctx.time(|| {
            let mut b = b0.data.clone();
            level3::dtrsm_llnn(n, n, &l.data, &mut b, panel, &params);
            std::hint::black_box(&b);
        });
        rows.push(Row {
            label: format!("dtrsm panel={panel}"),
            gflops: stats::gflops(fl, s.mean),
            seconds: s.mean,
            note: if panel == ctx.profile.trsm_panel {
                "<- profile default".into()
            } else {
                String::new()
            },
        });
    }
    print_rows(&rows);
    Ok(())
}

/// A3: thread scaling of the registered threaded GEMM kernels, plain vs
/// fused-ABFT — the kernel list comes from the registry.
pub fn ablation_threads(ctx: &mut BenchCtx) -> Result<()> {
    header("Ablation A3", "parallel row-band GEMM scaling (plain vs FT)");
    let n = if ctx.quick { 256 } else { 512 };
    let mut rng = Rng::new(0xA3);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: Matrix::random(n, n, &mut rng),
        b: Matrix::random(n, n, &mut rng),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };
    let fl = 2.0 * (n * n * n) as f64;

    let mut rows = Vec::new();
    for entry in KernelRegistry::global()
        .for_routine("dgemm")
        .into_iter()
        .filter(|e| e.threaded)
    {
        for threads in [1usize, 2, 4] {
            let ectx = ExecCtx {
                req: &req,
                profile: &ctx.profile,
                policy: entry.policies[0],
                faults: &[],
                threads,
            };
            let s = ctx.time(|| {
                std::hint::black_box((entry.execute)(&ectx));
            });
            rows.push(Row {
                label: format!("{:<22} t={threads}", entry.name),
                gflops: stats::gflops(fl, s.mean),
                seconds: s.mean,
                note: if threads == 1 { entry.summary.into() }
                      else { String::new() },
            });
        }
    }
    print_rows(&rows);
    println!("(FT state is band-local: the FT/plain gap must stay flat \
              as threads grow)");
    Ok(())
}

/// A4: weighted (double) checksum vs row+column locate — overhead of the
/// two single-error location schemes (paper §2.1 cites both), pulled
/// from the registry by scheme tag.
pub fn ablation_weighted(ctx: &mut BenchCtx) -> Result<()> {
    header("Ablation A4",
           "error location scheme: row+column vs weighted double checksum");
    let n = if ctx.quick { 256 } else { 384 };
    let mut rng = Rng::new(0xA4);
    let req = BlasRequest::Dgemm {
        alpha: 1.0,
        a: Matrix::random(n, n, &mut rng),
        b: Matrix::random(n, n, &mut rng),
        beta: 0.0,
        c: Matrix::zeros(n, n),
    };

    let reg = KernelRegistry::global();
    let find_scheme = |s: Scheme| {
        reg.for_routine("dgemm")
            .into_iter()
            .find(|e| !e.threaded && e.scheme == s)
            .unwrap_or_else(|| panic!("no dgemm kernel with scheme {s:?}"))
    };
    let fused = find_scheme(Scheme::AbftFused);
    let weighted = find_scheme(Scheme::AbftWeighted);
    let fctx = ExecCtx {
        req: &req, profile: &ctx.profile, policy: FtPolicy::Hybrid,
        faults: &[], threads: 1,
    };
    let wctx = ExecCtx {
        req: &req, profile: &ctx.profile, policy: FtPolicy::AbftWeighted,
        faults: &[], threads: 1,
    };
    let (rc, wt) = ctx.time_pair(
        || {
            std::hint::black_box((fused.execute)(&fctx));
        },
        || {
            std::hint::black_box((weighted.execute)(&wctx));
        },
    );
    let table = vec![
        (format!("{} (row+column §5.2)", fused.name), rc, rc, None),
        (format!("{} (double checksum)", weighted.name), rc, wt, None),
    ];
    harness::print_overhead_table("scheme", &table);
    println!("(the weighted scheme locates the row from the two row-space \
              checksums alone — no column checksums at all — at the cost \
              of one extra weighted encoding stream)");
    Ok(())
}
