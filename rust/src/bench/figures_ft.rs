//! Fault-tolerance figures: Fig. 8 (ABFT fusion), Fig. 9 (FT overhead for
//! eight routines), Figs. 10/11 (performance under error injection).

use anyhow::Result;
use std::hint::black_box;

use crate::bench::harness::{self, header, print_rows, row, BenchCtx, Row};
use crate::blas::{level2, level3, naive, Impl};
use crate::config::Profile;
use crate::coordinator::plan::{Planner, SelectionPolicy};
use crate::coordinator::registry::{ExecCtx, KernelRegistry, Scheme};
use crate::coordinator::request::{BlasRequest, BlasResponse, BlasResult};
use crate::coordinator::router::execute_plan;
use crate::ft::abft;
use crate::ft::injector::Fault;
use crate::ft::policy::FtPolicy;
use crate::util::matrix::{allclose, Matrix};
use crate::util::rng::Rng;

fn n3(ctx: &BenchCtx) -> usize {
    if ctx.quick { 256 } else { 512 }
}

/// Plan onto a pinned native variant and run the plan — the figures'
/// direct executions (same planner overhead in both timed arms, so the
/// ori/ft ratios stay comparable).
fn run_native(req: &BlasRequest, variant: Impl, profile: &Profile,
              policy: FtPolicy, fault: Option<Fault>) -> BlasResponse {
    let plan = Planner::new(profile)
        .plan(req, &SelectionPolicy::for_variant(variant), policy)
        .expect("the native ladder serves every routine");
    execute_plan(req, &plan, profile, fault)
}

/// Fig. 8a: every registered DGEMM protection scheme vs the unprotected
/// tuned baseline, clean and under a planned error — the scheme list
/// comes from the kernel registry, not a hand-maintained table.
pub fn fig8a(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 8a", "ABFT DGEMM: registered schemes, w/ and w/o errors");
    let mut rng = Rng::new(88);
    let n = n3(ctx);
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);
    let fl = 2.0 * (n * n * n) as f64;
    let fault = Fault { step: 1, i: n / 3, j: n / 2, delta: 1e4 };
    let req = BlasRequest::Dgemm {
        alpha: 1.0, a: a.clone(), b: b.clone(), beta: 1.0,
        c: Matrix::zeros(n, n),
    };

    let reg = KernelRegistry::global();
    let mut rows = Vec::new();
    // baseline: the unprotected serial tuned kernel
    let tuned = reg.find("dgemm/tuned").expect("registry lost dgemm/tuned");
    {
        let ectx = ExecCtx {
            req: &req, profile: &ctx.profile, policy: FtPolicy::None,
            faults: &[], threads: 1,
        };
        rows.push(row(ctx, &format!("{} (no FT) n={n}", tuned.name), fl,
                      "baseline", || {
            black_box((tuned.execute)(&ectx));
        }));
    }
    // every serial protected DGEMM kernel, clean
    let schemes: Vec<_> = reg
        .for_routine("dgemm")
        .into_iter()
        .filter(|e| !e.threaded && e.scheme != Scheme::None)
        .collect();
    for e in &schemes {
        let ectx = ExecCtx {
            req: &req, profile: &ctx.profile, policy: e.policies[0],
            faults: &[], threads: 1,
        };
        rows.push(row(ctx, &format!("{}, clean", e.name), fl, e.summary, || {
            black_box((e.execute)(&ectx));
        }));
    }
    // the §5.1 unfused baseline pays an extra checksum pass on error
    let unfused = reg
        .find("dgemm/abft-unfused")
        .expect("registry lost dgemm/abft-unfused");
    {
        let faults = [fault];
        let ectx = ExecCtx {
            req: &req, profile: &ctx.profile, policy: FtPolicy::AbftUnfused,
            faults: &faults, threads: 1,
        };
        rows.push(row(ctx, &format!("{}, 1 error", unfused.name), fl,
                      "extra column-checksum pass on recovery", || {
            black_box((unfused.execute)(&ectx));
        }));
    }
    print_rows(&rows);
    let base = rows[0].seconds;
    for r in &rows[1..] {
        println!("{:<34} {:+.2}% vs baseline", r.label,
                 harness::overhead_pct(base, r.seconds));
    }
    println!("(paper Fig 8a on AVX-512: fused ~2.9%; unfused ~9% clean, \
              ~15% with errors)");

    // fused path (PJRT artifact): ori vs fused-ABFT artifact
    if ctx.pjrt.is_some() {
        println!("-- fused (Pallas kernel, PJRT) --");
        let mut rows = Vec::new();
        for np in [256usize, 512] {
            let a = Matrix::random(np, np, &mut rng);
            let b = Matrix::random(np, np, &mut rng);
            let flp = 2.0 * (np * np * np) as f64;
            let req = BlasRequest::Dgemm {
                alpha: 1.0, a: a.clone(), b: b.clone(), beta: 0.0,
                c: Matrix::zeros(np, np),
            };
            let pj = ctx.pjrt.as_ref().unwrap();
            if !pj.supports(&req, FtPolicy::None) {
                continue;
            }
            pj.execute(&req, FtPolicy::None, None)?;
            let s_ori = ctx.time(|| {
                ctx.pjrt.as_ref().unwrap()
                    .execute(&req, FtPolicy::None, None).unwrap();
            });
            rows.push(Row { label: format!("dgemm/pjrt ori n={np}"),
                            gflops: flp / s_ori.mean / 1e9,
                            seconds: s_ori.mean, note: "".into() });
            ctx.pjrt.as_ref().unwrap().execute(&req, FtPolicy::Hybrid, None)?;
            let s_ft = ctx.time(|| {
                ctx.pjrt.as_ref().unwrap()
                    .execute(&req, FtPolicy::Hybrid, None).unwrap();
            });
            rows.push(Row { label: format!("dgemm/pjrt fused-abft n={np}"),
                            gflops: flp / s_ft.mean / 1e9,
                            seconds: s_ft.mean,
                            note: format!("ovhd {:+.2}% (paper: 2.9%)",
                                harness::overhead_pct(s_ori.mean, s_ft.mean)) });
        }
        print_rows(&rows);
    }
    Ok(())
}

/// Fig. 8b: unfused-ABFT overhead as a function of the backing library.
pub fn fig8b(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 8b", "ABFT overhead by backing library (unfused)");
    let mut rng = Rng::new(89);
    let n = n3(ctx);
    let params = ctx.profile.gemm;
    let a = Matrix::random(n, n, &mut rng);
    let b = Matrix::random(n, n, &mut rng);

    // baseline = the same kc-paneled GEMM loop WITHOUT checksums, so the
    // difference is exactly the unfused checksum traffic the paper blames
    let panel_gemm = |gemm: &mut dyn FnMut(&[f64], &[f64], &mut [f64], usize, usize),
                      c: &mut [f64]| {
        let mut p0 = 0;
        while p0 < n {
            let kcb = params.kc.min(n - p0);
            let mut ap = vec![0.0; n * kcb];
            for i in 0..n {
                ap[i * kcb..(i + 1) * kcb]
                    .copy_from_slice(&a.data[i * n + p0..i * n + p0 + kcb]);
            }
            let bp = &b.data[p0 * n..(p0 + kcb) * n];
            gemm(&ap, bp, c, n, kcb);
            p0 += kcb;
        }
    };
    let mut table = Vec::new();
    // naive backend
    let mut c1 = vec![0.0; n * n];
    let mut c2 = vec![0.0; n * n];
    let (base, ft) = ctx.time_pair(
        || {
            for v in c1.iter_mut() { *v = 0.0; }
            let mut g = |ap: &[f64], bp: &[f64], cc: &mut [f64], mm: usize, kk: usize|
                naive::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc);
            panel_gemm(&mut g, &mut c1);
        },
        || {
            for v in c2.iter_mut() { *v = 0.0; }
            black_box(abft::dgemm_abft_unfused(
                n, n, n, params.kc, &a.data, &b.data, &mut c2,
                |ap, bp, cc, mm, kk| naive::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc),
                None));
        },
    );
    table.push(("on-naive(LAPACK-sim)".to_string(), base, ft, None));
    // tuned backend
    let mut c1 = vec![0.0; n * n];
    let mut c2 = vec![0.0; n * n];
    let (base, ft) = ctx.time_pair(
        || {
            for v in c1.iter_mut() { *v = 0.0; }
            let mut g = |ap: &[f64], bp: &[f64], cc: &mut [f64], mm: usize, kk: usize|
                level3::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc, &params);
            panel_gemm(&mut g, &mut c1);
        },
        || {
            for v in c2.iter_mut() { *v = 0.0; }
            black_box(abft::dgemm_abft_unfused(
                n, n, n, params.kc, &a.data, &b.data, &mut c2,
                |ap, bp, cc, mm, kk| {
                    level3::dgemm(mm, n, kk, 1.0, ap, bp, 1.0, cc, &params)
                },
                None));
        },
    );
    table.push(("on-tuned(FT-BLAS Ori)".to_string(), base, ft, None));
    harness::print_overhead_table("backend", &table);
    println!("(paper Fig 8b: the faster the backing GEMM, the larger the \
              relative cost of the memory-bound checksum passes — fusion \
              removes it)");
    let naive_ovhd = harness::overhead_pct(table[0].1, table[0].2);
    let tuned_ovhd = harness::overhead_pct(table[1].1, table[1].2);
    harness::expect(tuned_ovhd > naive_ovhd,
                    "unfused overhead grows with backend speed")?;
    Ok(())
}

/// Fig. 9: eight routines — Ori vs FT vs the references.
pub fn fig9(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 9", "FT-BLAS: Ori vs FT across eight routines");
    let profile = ctx.profile.clone();
    let mut rng = Rng::new(99);
    let n1 = if ctx.quick { 1 << 20 } else { 4 << 20 };
    let n2 = if ctx.quick { 512 } else { 1024 };
    let n3v = if ctx.quick { 256 } else { 512 };

    let reqs: Vec<(BlasRequest, f64)> = {
        let x = rng.normal_vec(n1);
        let a2 = Matrix::random(n2, n2, &mut rng);
        let l2m = Matrix::random_lower_triangular(n2, &mut rng);
        let a3 = Matrix::random(n3v, n3v, &mut rng);
        let b3 = Matrix::random(n3v, n3v, &mut rng);
        let c3 = Matrix::random(n3v, n3v, &mut rng);
        let l3m = Matrix::random_lower_triangular(n3v, &mut rng);
        vec![
            (BlasRequest::Dscal { alpha: 1.0000001, x: x.clone() }, n1 as f64),
            (BlasRequest::Dnrm2 { x: x.clone() }, 2.0 * n1 as f64),
            (BlasRequest::Dgemv { alpha: 1.0, a: a2.clone(),
                                  x: rng.normal_vec(n2), beta: 0.0,
                                  y: rng.normal_vec(n2) },
             2.0 * (n2 * n2) as f64),
            (BlasRequest::Dtrsv { a: l2m.clone(), b: rng.normal_vec(n2) },
             (n2 * n2) as f64),
            (BlasRequest::Dgemm { alpha: 1.0, a: a3.clone(), b: b3.clone(),
                                  beta: 0.0, c: c3.clone() },
             2.0 * (n3v * n3v * n3v) as f64),
            (BlasRequest::Dsymm { alpha: 1.0, a: a3.clone(), b: b3.clone(),
                                  beta: 0.0, c: c3.clone() },
             2.0 * (n3v * n3v * n3v) as f64),
            (BlasRequest::Dtrmm { alpha: 1.0, a: l3m.clone(), b: b3.clone() },
             (n3v * n3v * n3v) as f64),
            (BlasRequest::Dtrsm { a: l3m.clone(), b: b3.clone() },
             (n3v * n3v * n3v) as f64),
        ]
    };

    let mut table = Vec::new();
    for (req, _fl) in &reqs {
        let (ori, ft) = ctx.time_pair(
            || {
                black_box(run_native(req, Impl::Tuned, &profile,
                                     FtPolicy::None, None));
            },
            || {
                black_box(run_native(req, Impl::Tuned, &profile,
                                     FtPolicy::Hybrid, None));
            },
        );
        let paper = match req.routine() {
            "dscal" => Some(0.36),
            "dnrm2" => Some(0.97),
            "dgemv" => Some(1.79),
            "dtrsv" => Some(3.10),
            "dgemm" => Some(2.94),
            "dsymm" => Some(1.62),
            "dtrmm" => Some(2.14),
            "dtrsm" => Some(2.35),
            _ => None,
        };
        table.push((format!("{} n={}", req.routine(), req.dim()),
                    ori, ft, paper));
    }
    harness::print_overhead_table("routine", &table);
    println!("(native L3 FT is the fused §5.2 scheme — ft/abft_fused.rs; \
              the unfused §5.1 baseline is measured in fig8a/fig8b and the \
              Pallas fused kernel on the PJRT backend in fig8a)");
    Ok(())
}

/// The shared body of Figs. 10 and 11: inject 20 errors per run into
/// DGEMV/DTRSV/DGEMM/DTRSM under the hybrid policy, verify the output
/// against the unprotected oracle, and compare throughput.
fn injection_figure(ctx: &mut BenchCtx, profile: &Profile) -> Result<()> {
    let mut rng = Rng::new(1010);
    let n2 = if ctx.quick { 512 } else { 1024 };
    let n3v = if ctx.quick { 256 } else { 512 };
    let a2 = Matrix::random(n2, n2, &mut rng);
    let l2m = Matrix::random_lower_triangular(n2, &mut rng);
    let a3 = Matrix::random(n3v, n3v, &mut rng);
    let b3 = Matrix::random(n3v, n3v, &mut rng);
    let l3m = Matrix::random_lower_triangular(n3v, &mut rng);

    let reqs = vec![
        BlasRequest::Dgemv { alpha: 1.0, a: a2.clone(), x: rng.normal_vec(n2),
                             beta: 0.0, y: rng.normal_vec(n2) },
        BlasRequest::Dtrsv { a: l2m.clone(), b: rng.normal_vec(n2) },
        BlasRequest::Dgemm { alpha: 1.0, a: a3.clone(), b: b3.clone(),
                             beta: 0.0, c: Matrix::zeros(n3v, n3v) },
        BlasRequest::Dtrsm { a: l3m.clone(), b: b3.clone() },
    ];

    // 20 errors per run (the paper's §6.3 setup): we re-run the routine 20
    // times, striking a different position each run — equivalent error
    // rate, and each strike is verified corrected.
    const ERRORS: usize = 20;
    let mut table = Vec::new();
    for req in &reqs {
        let oracle = run_native(req, Impl::Naive, profile,
                                FtPolicy::None, None);
        // under injection: each timed call carries one planned fault
        let dim = req.dim();
        let mut strike = 0usize;
        let mut detected = 0u64;
        let mut all_correct = true;
        let (ori, ft) = ctx.time_pair(
            || {
                black_box(run_native(req, Impl::Tuned, profile,
                                     FtPolicy::None, None));
            },
            || {
                let fault = Fault {
                    step: 1 + (strike % 3),
                    i: (strike * 37) % dim.min(64),
                    j: (strike * 61) % dim,
                    delta: 1e4 + strike as f64,
                };
                strike = (strike + 1) % ERRORS;
                let resp = run_native(req, Impl::Tuned, profile,
                                      FtPolicy::Hybrid, Some(fault));
                detected += resp.ft.errors_detected;
                all_correct &= results_match(&resp.result, &oracle.result, 1e-7);
            },
        );
        harness::expect(detected > 0,
                        &format!("{}: injected faults detected", req.routine()))?;
        harness::expect(all_correct,
                        &format!("{}: outputs equal oracle under injection",
                                 req.routine()))?;
        table.push((format!("{} n={} (+{} err)", req.routine(), req.dim(),
                            ERRORS),
                    ori, ft, Some(3.22)));
    }
    harness::print_overhead_table("routine", &table);
    println!("(paper Figs 10/11: 2.47%-3.22% overhead under injection; all \
              errors detected and corrected — verified against the oracle \
              here)");
    Ok(())
}

fn results_match(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => allclose(x, y, tol, tol),
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, tol, tol)
        }
        _ => false,
    }
}

/// Fig. 10: performance under error injection (Skylake-sim profile).
pub fn fig10(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 10", "Performance under error injection (skylake_sim)");
    let profile = ctx.profile.clone();
    injection_figure(ctx, &profile)
}

/// Fig. 11: the same experiment on the second machine profile
/// (cascade_sim — DESIGN.md substitution #4).
pub fn fig11(ctx: &mut BenchCtx) -> Result<()> {
    header("Fig 11", "Performance under error injection (cascade_sim)");
    let profile = Profile::cascade_sim();
    injection_figure(ctx, &profile)?;
    // DTRSV ladder across sizes, as the paper plots ms-scale times
    let mut rng = Rng::new(111);
    let mut rows = Vec::new();
    for n in [256usize, 512, 1024] {
        if ctx.quick && n > 512 {
            break;
        }
        let l = Matrix::random_lower_triangular(n, &mut rng);
        let b = rng.normal_vec(n);
        let fl = (n * n) as f64;
        let mut x = b.clone();
        rows.push(row(ctx, &format!("dtrsv/tuned+FT n={n}"), fl, "", || {
            x.copy_from_slice(&b);
            black_box(crate::ft::dmr::dtrsv_ft(n, &l.data, &mut x,
                                               profile.trsv_panel, None));
        }));
        let mut x = b.clone();
        rows.push(row(ctx, &format!("dtrsv/blocked(B=64) n={n}"), fl, "", || {
            x.copy_from_slice(&b);
            level2::dtrsv_lower(n, &l.data, &mut x, 64);
        }));
    }
    print_rows(&rows);
    Ok(())
}
