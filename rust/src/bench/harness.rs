//! Measurement plumbing shared by all figure drivers.

use anyhow::Result;

use crate::config::Profile;
use crate::coordinator::executor::PjrtExecutor;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::pjrt_backend::PjrtBackend;
use crate::coordinator::registry::{ExecCtx, KernelRegistry};
use crate::coordinator::request::BlasRequest;
use crate::ft::policy::FtPolicy;
use crate::util::json::Json;
use crate::util::stats::{self, Summary};

/// Context for a bench run.
pub struct BenchCtx {
    /// Machine profile the run measures under.
    pub profile: Profile,
    /// Keep the executor alive for the PJRT backend's lifetime.
    pub executor: Option<PjrtExecutor>,
    /// The PJRT backend, when artifacts are available.
    pub pjrt: Option<PjrtBackend>,
    /// Fewer reps / smaller sizes for CI-style runs.
    pub quick: bool,
    /// Measurement repetitions (the paper averages 20).
    pub reps: usize,
    /// When set, experiments that produce a machine-readable artifact
    /// (currently the CI `smoke` row set) also write it here as JSON
    /// (the CLI's `--out`).
    pub out: Option<std::path::PathBuf>,
}

impl BenchCtx {
    /// Native-only context.
    pub fn native(profile: Profile, quick: bool) -> BenchCtx {
        let reps = if quick { 7 } else { 20 }; // paper: average of 20
        BenchCtx { profile, executor: None, pjrt: None, quick, reps,
                   out: None }
    }

    /// Context with the PJRT backend if artifacts exist.
    pub fn with_artifacts(profile: Profile, quick: bool) -> BenchCtx {
        let mut ctx = BenchCtx::native(profile, quick);
        let dir = ctx.profile.artifact_path();
        if dir.join("manifest.tsv").exists() {
            match PjrtExecutor::spawn(dir.clone()) {
                Ok(exec) => {
                    match PjrtBackend::new(exec.handle.clone(), &dir) {
                        Ok(backend) => {
                            ctx.pjrt = Some(backend);
                            ctx.executor = Some(exec);
                        }
                        Err(e) => eprintln!("[bench] no PJRT backend: {e}"),
                    }
                }
                Err(e) => eprintln!("[bench] no PJRT executor: {e}"),
            }
        } else {
            eprintln!("[bench] {} missing — PJRT columns skipped (run `make artifacts`)",
                      dir.join("manifest.tsv").display());
        }
        ctx
    }

    /// Time a closure: warmup + reps, return summary of seconds.
    pub fn time<F: FnMut()>(&self, f: F) -> Summary {
        let warmup = if self.quick { 1 } else { 2 };
        Summary::from_samples(&stats::time_reps(warmup, self.reps, f))
    }

    /// Time two closures with *interleaved* repetitions for overhead
    /// comparisons (FT vs Ori). On a shared VM the machine's throughput
    /// drifts on second scales, so independent minima of the two sides
    /// can land in different throughput phases and invert a small
    /// overhead. Back-to-back pairs share each phase, so the per-pair
    /// time *ratio* is drift-immune: we report the best baseline time
    /// and scale it by the median pair ratio.
    pub fn time_pair<F: FnMut(), G: FnMut()>(&self, mut a: F, mut b: G)
                                             -> (f64, f64) {
        let warmup = if self.quick { 1 } else { 2 };
        for _ in 0..warmup {
            a();
            b();
        }
        let reps = self.reps * 3; // overheads are small; oversample
        let mut best_a = f64::INFINITY;
        let mut ratios = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            a();
            let ta = t0.elapsed().as_secs_f64();
            let t0 = std::time::Instant::now();
            b();
            let tb = t0.elapsed().as_secs_f64();
            best_a = best_a.min(ta);
            ratios.push(tb / ta);
        }
        ratios.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let med = ratios[ratios.len() / 2];
        (best_a, best_a * med)
    }
}

/// A printed result row.
#[derive(Clone, Debug)]
pub struct Row {
    /// Row label (variant / size).
    pub label: String,
    /// Measured throughput.
    pub gflops: f64,
    /// Best measured seconds.
    pub seconds: f64,
    /// Free-form annotation (paper reference, fault counts, ...).
    pub note: String,
}

/// Print a figure header.
pub fn header(id: &str, title: &str) {
    println!("\n=== {id}: {title} ===");
}

/// Print rows with a relative column against the first row.
pub fn print_rows(rows: &[Row]) {
    if rows.is_empty() {
        return;
    }
    let base = rows[0].gflops;
    println!("{:<38} {:>10} {:>12} {:>9}  {}", "impl", "GFLOPS", "time", "vs[0]",
             "note");
    for r in rows {
        let rel = if base > 0.0 { r.gflops / base } else { 0.0 };
        println!("{:<38} {:>10.3} {:>12} {:>8.3}x  {}",
                 r.label, r.gflops,
                 format!("{:.3}ms", r.seconds * 1e3), rel, r.note);
    }
}

/// Convenience: measure a closure's mean seconds and build a row.
pub fn row<F: FnMut()>(ctx: &BenchCtx, label: &str, flops: f64, note: &str,
                       f: F) -> Row {
    let s = ctx.time(f);
    Row {
        label: label.to_string(),
        gflops: stats::gflops(flops, s.mean),
        seconds: s.mean,
        note: note.to_string(),
    }
}

/// Time the serial unprotected variant ladder of one routine straight
/// off the kernel registry (naive → blocked → tuned → simd, in
/// registration order) — the figure drivers enumerate descriptors
/// instead of hand-maintaining variant lists.
///
/// The uniform `execute` entry clones the request's output buffer, so
/// every row carries the same clone cost and the `vs[0]` column (the
/// within-routine ratio) is the meaningful figure. For Level-1 routines
/// — where one O(n) clone is commensurate with the O(n) kernel — an
/// extra `(request-clone floor)` row makes that shared cost visible.
pub fn registry_variant_rows(ctx: &BenchCtx, req: &BlasRequest, flops: f64)
                             -> Vec<Row> {
    let mut rows = Vec::new();
    for entry in KernelRegistry::global().serial_variants(req.routine()) {
        let ectx = ExecCtx {
            req,
            profile: &ctx.profile,
            policy: FtPolicy::None,
            faults: &[],
            threads: 1,
        };
        rows.push(row(ctx, entry.name, flops, entry.summary, || {
            std::hint::black_box((entry.execute)(&ectx));
        }));
    }
    if req.level() == crate::coordinator::request::Level::L1 {
        let s = ctx.time(|| {
            std::hint::black_box(req.clone());
        });
        rows.push(Row {
            label: format!("({}: request-clone floor)", req.routine()),
            gflops: 0.0,
            seconds: s.mean,
            note: "shared by every row above".into(),
        });
    }
    rows
}

/// Print a server metrics snapshot as the per-kernel serving ledger:
/// one row per executed kernel (exec / e2e / queue-wait latencies, FT
/// counters) plus the scheduling counters (plan-cache hit rate, thread
/// budget, deferrals). Shared by `ftblas serve` and the e2e example.
pub fn print_ledger(snap: &MetricsSnapshot) {
    println!("{:<26} {:>6} {:>10} {:>10} {:>10} {:>9} {:>5} {:>5} {:>5}",
             "kernel", "n", "exec-mean", "e2e-p99", "queue-mean", "slo",
             "burn", "det", "corr");
    let mut kernels: Vec<_> = snap.kernels.iter().collect();
    kernels.sort_by(|a, b| a.0.cmp(b.0));
    for (name, k) in &kernels {
        println!("{:<26} {:>6} {:>8.2}ms {:>8.2}ms {:>8.2}ms {:>7.1}ms \
                  {:>5} {:>5} {:>5}",
                 name, k.completed, k.exec.mean * 1e3, k.e2e.p99 * 1e3,
                 k.queue.mean * 1e3, k.slo_target * 1e3, k.slo_burns,
                 k.errors_detected, k.errors_corrected);
    }
    let overall = snap.overall_e2e();
    println!("overall: {} completed, {} failed | e2e p50={:.2}ms p99={:.2}ms",
             snap.completed, snap.failed, overall.p50 * 1e3,
             overall.p99 * 1e3);
    println!("slo: {} of {} completions over target",
             snap.slo_burns(), snap.completed);
    println!("admission: {} shed (max queue depth {})", snap.shed,
             snap.max_queue_depth);
    let resolutions = snap.plan_cache_hits + snap.plan_cache_misses;
    let hit_pct = if resolutions > 0 {
        100.0 * snap.plan_cache_hits as f64 / resolutions as f64
    } else {
        0.0
    };
    println!("plan cache: {} hits / {} misses ({hit_pct:.1}% hit)",
             snap.plan_cache_hits, snap.plan_cache_misses);
    println!("thread budget: {} (max in-flight {}, {} deferrals, \
              {} starvation reserves)",
             snap.thread_budget, snap.max_in_flight_threads, snap.deferrals,
             snap.starvation_reserves);
    println!("scaling: {} up / {} down, {} kernel-id keys migrated",
             snap.scale_ups, snap.scale_downs, snap.keys_migrated);
    println!("batching: {} batches fused ({} items)", snap.batches_fused,
             snap.items_fused);
    println!("arena: {} f64 capacity, {} grows, {} leases (server workers)",
             snap.arena_capacity, snap.arena_grows, snap.arena_leases);
    let p = &snap.pool;
    if p.workers > 0 {
        println!("pool: {} workers | {} submitted / {} executed | \
                  {} steals, {} park wakeups",
                 p.workers, p.tasks_submitted, p.tasks_executed, p.steals,
                 p.park_wakeups);
        println!("pool arena: {} f64 capacity, {} grows, {} leases",
                 p.arena_capacity, p.arena_grows, p.arena_leases);
        for (label, s) in p.queue_summaries() {
            println!("  {:<24} queue-wait mean={:.1}us p99={:.1}us (n={})",
                     label, s.mean * 1e6, s.p99 * 1e6, s.n);
        }
    } else {
        println!("pool: none (scoped frames — --no-pool or non-cluster)");
    }
    // FT outcomes: per kernel and overall, headed by the injection
    // mode (campaign = rate-based cluster-wide schedule, per-call =
    // a planned per-run injector)
    let mode = match snap.injection_mode {
        "" => "no injection",
        m => m,
    };
    println!("ft outcomes [{mode}]:");
    let struck: Vec<_> = kernels
        .iter()
        .filter(|(_, k)| k.errors_injected > 0 || k.errors_detected > 0)
        .collect();
    if struck.is_empty() {
        println!("  (no faults injected)");
    }
    for (name, k) in struck {
        println!("  {:<24} injected={:<5} detected={:<5} corrected={:<5} \
                  escaped={}",
                 name, k.errors_injected, k.errors_detected,
                 k.errors_corrected, k.errors_escaped);
    }
    println!("  overall: injected={} detected={} corrected={} escaped={}",
             snap.errors_injected, snap.errors_detected,
             snap.errors_corrected, snap.errors_escaped);
}

/// Write a JSON document to `path`, creating parent directories —
/// the CI artifact writer behind `--out`.
pub fn write_json(path: &std::path::Path, doc: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.render() + "\n")?;
    Ok(())
}

/// The bench-smoke rows as a stable JSON artifact
/// (`ftblas.bench-smoke.v1`): one row per measured kernel variant, in
/// print order, so the perf trajectory is machine-readable across PRs.
/// Every document records the host's probed `cpu_features` so committed
/// rows are comparable across machines.
pub fn rows_json(exp: &str, profile: &str, quick: bool, rows: &[Row]) -> Json {
    Json::obj()
        .field("schema", Json::Str("ftblas.bench-smoke.v1".into()))
        .field("exp", Json::Str(exp.into()))
        .field("profile", Json::Str(profile.into()))
        .field("quick", Json::Bool(quick))
        .field("cpu_features",
               Json::Str(crate::blas::simd::CpuFeatures::summary().into()))
        .field("rows", Json::Arr(rows.iter().map(|r| {
            Json::obj()
                .field("label", Json::Str(r.label.clone()))
                .field("gflops", Json::Num(r.gflops))
                .field("seconds", Json::Num(r.seconds))
                .field("note", Json::Str(r.note.clone()))
        }).collect()))
}

/// Percent overhead of the FT run relative to the baseline, in the
/// paper's definition: the *performance drop* (P_ori − P_ft)/P_ori =
/// 1 − t_ori/t_ft. (The paper's "50.8 %" step-0 overhead means the FT
/// version runs at half the baseline's GFLOPS, i.e. 2× the time.)
pub fn overhead_pct(base_secs: f64, ft_secs: f64) -> f64 {
    if ft_secs <= 0.0 {
        return 0.0;
    }
    (1.0 - base_secs / ft_secs) * 100.0
}

/// Print an FT-vs-baseline overhead table with the paper's reference
/// column.
pub fn print_overhead_table(title: &str,
                            rows: &[(String, f64, f64, Option<f64>)]) {
    // rows: (label, base_secs, ft_secs, paper_pct)
    println!("{:<24} {:>12} {:>12} {:>10} {:>12}", title, "ori", "ft",
             "ovhd%", "paper-ovhd%");
    for (label, base, ft, paper) in rows {
        println!("{:<24} {:>11.3}ms {:>11.3}ms {:>9.2}% {:>12}",
                 label, base * 1e3, ft * 1e3, overhead_pct(*base, *ft),
                 paper.map(|p| format!("{p:.2}%")).unwrap_or_else(|| "-".into()));
    }
}

/// Assert-and-report helper used by benches that double as regression
/// checks: warn loudly when a shape claim fails rather than panicking.
pub fn expect(cond: bool, what: &str) -> Result<()> {
    if !cond {
        eprintln!("[bench][SHAPE-MISMATCH] {what}");
    }
    Ok(())
}
