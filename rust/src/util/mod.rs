//! Shared utilities: matrices, deterministic RNG, stats, and the mini
//! property-testing harness (proptest is not vendored in this offline
//! image — see DESIGN.md §9).

pub mod arena;
pub mod check;
pub mod json;
pub mod matrix;
pub mod rng;
pub mod stats;
