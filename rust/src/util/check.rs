//! Mini property-testing harness (quickcheck-lite).
//!
//! proptest is not vendored in this offline image (DESIGN.md §9), so the
//! repository's property tests use this small, seeded harness: a property
//! is a closure over a `Gen`; `check` runs it for `cases` seeds and
//! reports the first failing seed so failures are reproducible with
//! `check_seed`.

use crate::util::rng::Rng;

/// Generator handed to properties: a seeded RNG plus sizing helpers.
pub struct Gen {
    /// The case's seeded RNG.
    pub rng: Rng,
    /// Zero-based case index within the check run.
    pub case: usize,
}

impl Gen {
    /// Matrix dimension that grows with the case index (small cases first,
    /// like proptest's sizing).
    pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
        let span = hi - lo + 1;
        lo + self.rng.below(span)
    }

    /// A dimension rounded up to a multiple of `m`.
    pub fn dim_multiple_of(&mut self, m: usize, lo: usize, hi: usize) -> usize {
        let d = self.dim(lo, hi);
        d.div_ceil(m) * m
    }
}

/// Run `prop` for `cases` random cases. Panics with the failing seed on
/// the first failure.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut prop: F,
) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<F: FnMut(&mut Gen) -> Result<(), String>>(
    name: &str,
    seed: u64,
    mut prop: F,
) {
    let mut g = Gen { rng: Rng::new(seed), case: 0 };
    if let Err(msg) = prop(&mut g) {
        panic!("property `{name}` failed (seed={seed:#x}): {msg}");
    }
}

/// Assertion helpers returning Result for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond { Ok(()) } else { Err(msg.into()) }
}

/// `ensure` specialized to relative f64 closeness.
pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + b.abs()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.rng.normal();
            let b = g.rng.normal();
            ensure(a + b == b + a, "not commutative")
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn failing_property_reports() {
        check("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn dims_in_range() {
        check("dims", 100, |g| {
            let d = g.dim(3, 9);
            ensure((3..=9).contains(&d), format!("dim {d} out of range"))?;
            let m = g.dim_multiple_of(4, 5, 20);
            ensure(m % 4 == 0 && (5..=24).contains(&m), format!("bad multiple {m}"))
        });
    }
}
