//! Dense row-major f64 matrix — the value type flowing through the
//! coordinator, the native kernels, and the PJRT literal conversions.

use crate::util::rng::Rng;

/// Row-major dense matrix of f64 (the paper's D-precision).
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f64>,
}

/// `rows * cols` with the multiplication checked: a shape whose element
/// count overflows `usize` panics here instead of wrapping in release
/// builds — a wrapped length would produce a Matrix whose `data` length
/// disagrees with its dims, which the unsafe kernel backends trust.
fn checked_len(rows: usize, cols: usize) -> usize {
    rows.checked_mul(cols)
        .unwrap_or_else(|| panic!("matrix shape {rows}x{cols} overflows \
                                   the address space"))
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; checked_len(rows, cols)] }
    }

    /// Wrap existing row-major data (panics on a shape mismatch).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), checked_len(rows, cols),
                   "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// The n×n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Standard-normal entries from the seeded RNG.
    pub fn random(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(checked_len(rows, cols)) }
    }

    /// Random lower-triangular with a dominant diagonal (well conditioned
    /// for the TRSV/TRSM benches, like the paper's test matrices).
    pub fn random_lower_triangular(n: usize, rng: &mut Rng) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                m.data[i * n + j] = rng.normal();
            }
            m.data[i * n + i] += 4.0;
        }
        m
    }

    /// Random symmetric (stored dense; routines read the lower triangle).
    pub fn random_symmetric(n: usize, rng: &mut Rng) -> Self {
        let mut m = Self::random(n, n, rng);
        for i in 0..n {
            for j in 0..i {
                m.data[j * n + i] = m.data[i * n + j];
            }
        }
        m
    }

    /// Random symmetric positive definite: A = L L^T + n·I.
    pub fn random_spd(n: usize, rng: &mut Rng) -> Self {
        let l = Self::random_lower_triangular(n, rng);
        let mut a = Self::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..=j.min(i) {
                    s += l.data[i * n + k] * l.data[j * n + k];
                }
                a.data[i * n + j] = s;
            }
            a.data[i * n + i] += n as f64;
        }
        a
    }

    /// Random strictly diagonally dominant matrix (always nonsingular and
    /// well-conditioned — the natural LU test input).
    pub fn random_diag_dominant(n: usize, rng: &mut Rng) -> Self {
        let mut a = Self::random(n, n, rng);
        for i in 0..n {
            let rsum: f64 = a.data[i * n..(i + 1) * n]
                .iter()
                .map(|v| v.abs())
                .sum();
            a.data[i * n + i] = rsum + 1.0;
        }
        a
    }

    /// Swap two rows in place (the DSWAP of a pivoting factorization).
    pub fn swap_rows(&mut self, r1: usize, r2: usize) {
        if r1 == r2 {
            return;
        }
        let c = self.cols;
        let (lo, hi) = (r1.min(r2), r1.max(r2));
        let (head, tail) = self.data.split_at_mut(hi * c);
        head[lo * c..(lo + 1) * c].swap_with_slice(&mut tail[..c]);
    }

    /// Element `(i, j)`.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    /// Set element `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// A transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Per-row sums (the ABFT row-checksum primitive).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.data[i * self.cols..(i + 1) * self.cols].iter().sum())
            .collect()
    }

    /// Per-column sums (the ABFT column-checksum primitive).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, sj) in s.iter_mut().enumerate() {
                *sj += self.data[i * self.cols + j];
            }
        }
        s
    }

    /// Largest absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &v| m.max(v.abs()))
    }

    /// Largest absolute elementwise difference (panics on shape
    /// mismatch).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Relative Frobenius-norm difference, for residual checks.
    pub fn rel_fro_diff(&self, other: &Matrix) -> f64 {
        let num: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let den: f64 = other.data.iter().map(|v| v * v).sum::<f64>().sqrt();
        if den == 0.0 { num } else { num / den }
    }
}

/// Max-abs difference between two vectors.
pub fn vec_max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).fold(0.0, |m, (x, y)| m.max((x - y).abs()))
}

/// allclose with both relative and absolute tolerance (numpy semantics).
pub fn allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| (x - y).abs() <= atol + rtol * y.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at() {
        let m = Matrix::identity(4);
        assert_eq!(m.at(2, 2), 1.0);
        assert_eq!(m.at(2, 3), 0.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(5);
        let m = Matrix::random(7, 3, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn row_col_sums() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row_sums(), vec![6., 15.]);
        assert_eq!(m.col_sums(), vec![5., 7., 9.]);
    }

    #[test]
    fn lower_triangular_is_lower() {
        let mut rng = Rng::new(6);
        let m = Matrix::random_lower_triangular(16, &mut rng);
        for i in 0..16 {
            for j in (i + 1)..16 {
                assert_eq!(m.at(i, j), 0.0);
            }
            assert!(m.at(i, i).abs() > 0.5);
        }
    }

    #[test]
    fn spd_is_symmetric() {
        let mut rng = Rng::new(8);
        let a = Matrix::random_spd(12, &mut rng);
        for i in 0..12 {
            for j in 0..12 {
                assert!((a.at(i, j) - a.at(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn allclose_tolerances() {
        assert!(allclose(&[1.0 + 1e-12], &[1.0], 1e-9, 0.0));
        assert!(!allclose(&[1.1], &[1.0], 1e-9, 1e-9));
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn overflowing_shapes_panic_instead_of_wrapping() {
        // usize::MAX * 2 wraps to a small length in release builds
        // without the checked multiply — the guard must fire first
        let _ = Matrix::zeros(usize::MAX, 2);
    }
}
