//! Grow-only scratch arena for the GEMM hot path.
//!
//! Every tuned/SIMD DGEMM call needs the same transient buffers: the
//! packed A/B panels of the GEBP loop nest and, on the fused-ABFT path,
//! the checksum scratch (`be`/`eta` per depth block, `cr*`/`cc*`
//! encoded/reference accumulators). Allocating them with `vec!` per
//! call is exactly the per-call overhead the paper's fused design
//! amortizes away — and it dominates when the workload is many *small*
//! GEMMs (the batched serving shape). [`PackArena`] replaces those
//! allocations with leases from one grow-only, thread-local slab: the
//! first call on a thread sizes the slab, every later call with the
//! same (or smaller) footprint reuses it allocation-free.
//!
//! A lease is always **zero-filled** before the borrower sees it, so a
//! kernel written against `vec![0.0; len]` buffers computes bit-identical
//! results through the arena — reuse can never leak state between calls
//! (the arena-determinism property test pins this).
//!
//! The sizing helpers [`packed_a_len`] / [`packed_b_len`] are the single
//! source of truth for packed-panel footprints; the scalar tuned path,
//! the AVX2 GEBP/fused kernels, and the unfused fused-ABFT driver all
//! size their panels through them instead of re-deriving the rounding
//! arithmetic per call site.

use std::cell::RefCell;

/// Length of a packed A panel: `mc` rows rounded up to whole `mr`
/// micro-panels, each `kc` deep. The one formula every packing call
/// site shares.
pub fn packed_a_len(mc: usize, kc: usize, mr: usize) -> usize {
    mc.div_ceil(mr) * mr * kc
}

/// Length of a packed B panel: `nc` columns rounded up to whole `nr`
/// micro-panels, each `kc` deep.
pub fn packed_b_len(nc: usize, kc: usize, nr: usize) -> usize {
    nc.div_ceil(nr) * nr * kc
}

/// A grow-only `f64` scratch slab that lends disjoint, zeroed slices.
///
/// The slab only ever grows (to the largest total footprint any lease
/// asked for), so steady-state leases are allocation-free. Not
/// thread-safe by design — each thread owns one via [`with`]'s
/// thread-local.
#[derive(Default)]
pub struct PackArena {
    slab: Vec<f64>,
    grows: u64,
    leases: u64,
}

impl PackArena {
    /// An empty arena; the first lease sizes the slab.
    pub fn new() -> PackArena {
        PackArena::default()
    }

    /// Current slab capacity in `f64` elements (the high-watermark of
    /// every lease footprint so far).
    pub fn capacity(&self) -> usize {
        self.slab.len()
    }

    /// How many times a lease had to grow the slab (a steady-state hot
    /// loop must stop incrementing this after warm-up).
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Total leases served.
    pub fn leases(&self) -> u64 {
        self.leases
    }

    /// Lease `N` disjoint zero-filled slices of the given sizes and run
    /// `f` on them. Equivalent to handing `f` freshly built
    /// `vec![0.0; size]` buffers, minus the per-call allocations: the
    /// slab grows to the total footprint once and is reused thereafter.
    pub fn with_slices<const N: usize, R>(
        &mut self, sizes: [usize; N],
        f: impl FnOnce([&mut [f64]; N]) -> R,
    ) -> R {
        let total: usize = sizes.iter().sum();
        if self.slab.len() < total {
            self.slab.resize(total, 0.0);
            self.grows += 1;
        }
        self.leases += 1;
        // zero the leased prefix: borrowers rely on vec![0.0; n]
        // semantics, and reuse must never leak a previous call's state
        for v in &mut self.slab[..total] {
            *v = 0.0;
        }
        let mut rest: &mut [f64] = &mut self.slab[..total];
        let parts = sizes.map(|s| {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(s);
            rest = tail;
            head
        });
        f(parts)
    }
}

thread_local! {
    static ARENA: RefCell<PackArena> = RefCell::new(PackArena::new());
}

/// Lease `N` zeroed scratch slices from the calling thread's arena.
///
/// This is the hot-path entry the GEMM kernels use: each worker/band
/// thread reuses its own slab across calls, so steady-state packing and
/// checksum scratch costs zero heap allocations. `f` must not re-enter
/// the arena (the kernels wired through it are leaves; a nested lease
/// would panic on the `RefCell` borrow rather than corrupt a live
/// lease).
pub fn with<const N: usize, R>(
    sizes: [usize; N], f: impl FnOnce([&mut [f64]; N]) -> R,
) -> R {
    ARENA.with(|a| a.borrow_mut().with_slices(sizes, f))
}

/// `(capacity, grows, leases)` of the calling thread's arena — what the
/// steady-state tests assert on (after warm-up, `grows` must not move).
pub fn thread_stats() -> (usize, u64, u64) {
    ARENA.with(|a| {
        let a = a.borrow();
        (a.capacity(), a.grows(), a.leases())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_slices_are_zeroed_disjoint_and_sized() {
        let mut arena = PackArena::new();
        arena.with_slices([4, 3, 5], |[a, b, c]| {
            assert_eq!((a.len(), b.len(), c.len()), (4, 3, 5));
            assert!(a.iter().chain(b.iter()).chain(c.iter())
                        .all(|&v| v == 0.0));
            a.fill(1.0);
            b.fill(2.0);
            // disjointness: writing a and b leaves c untouched
            assert!(c.iter().all(|&v| v == 0.0));
        });
        // dirt from the previous lease never leaks into the next one
        arena.with_slices([12], |[s]| {
            assert!(s.iter().all(|&v| v == 0.0));
        });
    }

    #[test]
    fn slab_grows_once_then_steady_state_is_allocation_free() {
        let mut arena = PackArena::new();
        arena.with_slices([64, 32], |_| ());
        assert_eq!(arena.grows(), 1);
        assert_eq!(arena.capacity(), 96);
        // smaller and equal footprints reuse the slab
        arena.with_slices([16], |_| ());
        arena.with_slices([48, 48], |_| ());
        assert_eq!(arena.grows(), 1, "steady state must not reallocate");
        // a larger footprint grows it exactly once more
        arena.with_slices([100, 100], |_| ());
        assert_eq!(arena.grows(), 2);
        assert_eq!(arena.capacity(), 200);
        assert_eq!(arena.leases(), 4);
    }

    #[test]
    fn zero_length_slices_are_fine() {
        let mut arena = PackArena::new();
        arena.with_slices([0, 8, 0], |[a, b, c]| {
            assert!(a.is_empty() && c.is_empty());
            assert_eq!(b.len(), 8);
        });
    }

    #[test]
    fn sizing_helpers_round_up_to_whole_micro_panels() {
        assert_eq!(packed_a_len(128, 128, 4), 128 * 128);
        assert_eq!(packed_a_len(70, 16, 4), 72 * 16);
        assert_eq!(packed_b_len(256, 128, 8), 256 * 128);
        assert_eq!(packed_b_len(9, 32, 8), 16 * 32);
        // degenerate blocks lease nothing
        assert_eq!(packed_a_len(0, 16, 8), 0);
    }

    #[test]
    fn thread_local_entry_reuses_one_slab_per_thread() {
        // run on a dedicated thread so other tests' leases don't skew
        // the counters
        std::thread::spawn(|| {
            with([32, 16], |[a, b]| {
                a.fill(3.0);
                b.fill(4.0);
            });
            let (cap, grows, _) = thread_stats();
            assert_eq!(cap, 48);
            assert_eq!(grows, 1);
            with([32, 16], |[a, _]| {
                assert!(a.iter().all(|&v| v == 0.0), "lease must be re-zeroed");
            });
            let (_, grows, leases) = thread_stats();
            assert_eq!(grows, 1, "same footprint must not grow the slab");
            assert_eq!(leases, 2);
        })
        .join()
        .unwrap();
    }
}
