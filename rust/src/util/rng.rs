//! Deterministic xoshiro256** RNG.
//!
//! The `rand` crate is not vendored in this offline image; the injection
//! substrate and the bench workload generators need *seeded, reproducible*
//! streams anyway (the paper injects at deterministic intervals), so a
//! small, well-known generator is the right tool.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.uniform().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// A vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }
}
