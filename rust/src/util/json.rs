//! Minimal JSON document builder (serde is not vendored in this
//! offline image; see DESIGN.md §9). The CI artifacts — the bench-smoke
//! ledger and the soak report — need a *stable, machine-readable*
//! schema across PRs, so this builder emits objects with keys in
//! insertion order (callers sort collections themselves), strings with
//! full escaping, and floats via Rust's shortest-roundtrip `Display`
//! (non-finite values degrade to `null` rather than emitting invalid
//! JSON).

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug)]
pub enum Json {
    /// An object; keys serialize in insertion order.
    Obj(Vec<(String, Json)>),
    /// An array.
    Arr(Vec<Json>),
    /// A string (escaped on serialization).
    Str(String),
    /// A float (`null` when non-finite).
    Num(f64),
    /// An unsigned integer (exact — not routed through f64).
    Int(u64),
    /// A boolean.
    Bool(bool),
    /// An explicit null.
    Null,
}

impl Json {
    /// An empty object to push fields onto.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a field to an object; panics when `self` is not one
    /// (builder misuse, not data-dependent).
    pub fn field(mut self, key: &str, value: Json) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value)),
            other => panic!("field() on a non-object Json: {other:?}"),
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Num(v) if v.is_finite() => {
                let _ = write!(out, "{v}");
            }
            Json::Num(_) => out.push_str("null"),
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Null => out.push_str("null"),
        }
    }
}

/// Write `s` as a quoted JSON string with RFC 8259 escaping.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_documents() {
        let doc = Json::obj()
            .field("schema", Json::Str("v1".into()))
            .field("count", Json::Int(3))
            .field("mean", Json::Num(0.25))
            .field("ok", Json::Bool(true))
            .field("rows", Json::Arr(vec![
                Json::obj().field("label", Json::Str("a".into())),
                Json::Null,
            ]));
        assert_eq!(doc.render(),
                   r#"{"schema":"v1","count":3,"mean":0.25,"ok":true,"rows":[{"label":"a"},null]}"#);
    }

    #[test]
    fn escapes_strings_and_degrades_nonfinite() {
        let doc = Json::obj()
            .field("s", Json::Str("a\"b\\c\nd\u{1}".into()))
            .field("nan", Json::Num(f64::NAN))
            .field("inf", Json::Num(f64::INFINITY));
        assert_eq!(doc.render(),
                   r#"{"s":"a\"b\\c\nd\u0001","nan":null,"inf":null}"#);
    }

    #[test]
    fn integers_are_exact() {
        // u64 values above 2^53 would lose precision through f64
        let big = (1u64 << 60) + 1;
        assert_eq!(Json::Int(big).render(), big.to_string());
    }
}
