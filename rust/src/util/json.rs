//! Minimal JSON document builder and parser (serde is not vendored in
//! this offline image; see DESIGN.md §9). The CI artifacts — the
//! bench-smoke ledger and the soak report — need a *stable,
//! machine-readable* schema across PRs, so this builder emits objects
//! with keys in insertion order (callers sort collections themselves),
//! strings with full escaping, and floats via Rust's
//! shortest-roundtrip `Display` (non-finite values degrade to `null`
//! rather than emitting invalid JSON). [`Json::parse`] reads the same
//! documents back for the `bench-diff` regression gate.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug)]
pub enum Json {
    /// An object; keys serialize in insertion order.
    Obj(Vec<(String, Json)>),
    /// An array.
    Arr(Vec<Json>),
    /// A string (escaped on serialization).
    Str(String),
    /// A float (`null` when non-finite).
    Num(f64),
    /// An unsigned integer (exact — not routed through f64).
    Int(u64),
    /// A boolean.
    Bool(bool),
    /// An explicit null.
    Null,
}

impl Json {
    /// An empty object to push fields onto.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a field to an object; panics when `self` is not one
    /// (builder misuse, not data-dependent).
    pub fn field(mut self, key: &str, value: Json) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value)),
            other => panic!("field() on a non-object Json: {other:?}"),
        }
        self
    }

    /// Serialize to a compact JSON string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Num(v) if v.is_finite() => {
                let _ = write!(out, "{v}");
            }
            Json::Num(_) => out.push_str("null"),
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Null => out.push_str("null"),
        }
    }

    /// Parse a JSON document (the counterpart of [`Json::render`],
    /// for reading back committed `BENCH_*.json` artifacts). Numbers
    /// parse as [`Json::Int`] when they are unsigned integers that fit
    /// `u64` and [`Json::Num`] otherwise, matching what the builder
    /// emits. Returns a message with a byte offset on malformed input,
    /// including trailing non-whitespace.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(value)
    }

    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// The numeric value of a `Num` or `Int` node.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// The string value of a `Str` node.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The items of an `Arr` node.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

// ------------------------------------------------------------- parsing

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len()
        && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r')
    {
        *pos += 1;
    }
}

fn expect_byte(bytes: &[u8], pos: &mut usize, want: u8) -> Result<(), String> {
    if bytes.get(*pos) == Some(&want) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", want as char, pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        Some(b'{') => parse_obj(bytes, pos),
        Some(b'[') => parse_arr(bytes, pos),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(_) => parse_number(bytes, pos),
        None => Err("unexpected end of input".into()),
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect_byte(bytes, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect_byte(bytes, pos, b':')?;
        fields.push((key, parse_value(bytes, pos)?));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    expect_byte(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos}")),
        }
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect_byte(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let code = parse_hex4(bytes, *pos + 1)?;
                        *pos += 4;
                        let ch = match code {
                            0xD800..=0xDBFF => {
                                // high surrogate: RFC 8259 §7 encodes
                                // astral chars as a \u pair, so a low
                                // half must follow immediately
                                if bytes.get(*pos + 1..*pos + 3)
                                    != Some(&b"\\u"[..])
                                {
                                    return Err(format!(
                                        "lone high surrogate \
                                         \\u{code:04x}"));
                                }
                                let low = parse_hex4(bytes, *pos + 3)?;
                                if !(0xDC00..=0xDFFF).contains(&low) {
                                    return Err(format!(
                                        "\\u{code:04x} not followed by a \
                                         low surrogate (got \
                                         \\u{low:04x})"));
                                }
                                *pos += 6;
                                let scalar = 0x10000
                                    + ((code - 0xD800) << 10)
                                    + (low - 0xDC00);
                                char::from_u32(scalar)
                                    .expect("paired surrogates decode")
                            }
                            0xDC00..=0xDFFF => {
                                return Err(format!(
                                    "lone low surrogate \\u{code:04x}"))
                            }
                            code => char::from_u32(code)
                                .expect("non-surrogate BMP scalar"),
                        };
                        out.push(ch);
                    }
                    _ => return Err(format!("bad escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => {
                // RFC 8259 §7: control characters must be escaped; raw
                // ones in the input are malformed, not data
                return Err(format!(
                    "raw control character 0x{c:02x} in string at byte \
                     {pos}"));
            }
            Some(_) => {
                // copy one UTF-8 scalar (multi-byte sequences intact)
                let start = *pos;
                *pos += 1;
                while *pos < bytes.len() && bytes[*pos] & 0xC0 == 0x80 {
                    *pos += 1;
                }
                let s = std::str::from_utf8(&bytes[start..*pos])
                    .map_err(|_| format!("invalid UTF-8 at byte {start}"))?;
                out.push_str(s);
            }
        }
    }
}

/// Four hex digits at `at` (the payload of a `\u` escape). Strict:
/// exactly `[0-9A-Fa-f]{4}` — `u32::from_str_radix` alone would let a
/// sign sneak in.
fn parse_hex4(bytes: &[u8], at: usize) -> Result<u32, String> {
    let hex = bytes
        .get(at..at + 4)
        .ok_or("truncated \\u escape")?;
    if !hex.iter().all(u8::is_ascii_hexdigit) {
        return Err(format!("bad \\u escape at byte {at}"));
    }
    u32::from_str_radix(std::str::from_utf8(hex).unwrap(), 16)
        .map_err(|_| format!("bad \\u escape at byte {at}"))
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json)
             -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("expected '{lit}' at byte {pos}"))
    }
}

/// Does `text` match RFC 8259's number grammar exactly? Rust's
/// `f64`/`u64` parsers are looser (leading `+`, leading zeros, `1.`,
/// `-.5`), so the token is validated here before delegating to them.
fn is_json_number(text: &str) -> bool {
    let b = text.as_bytes();
    let mut i = 0;
    if b.get(i) == Some(&b'-') {
        i += 1;
    }
    // int: `0` or a nonzero digit followed by digits (no leading zero)
    match b.get(i) {
        Some(b'0') => i += 1,
        Some(b'1'..=b'9') => {
            while matches!(b.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => return false,
    }
    // frac: `.` demands at least one digit
    if b.get(i) == Some(&b'.') {
        i += 1;
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return false;
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    // exp: `e`/`E`, optional sign, at least one digit
    if matches!(b.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return false;
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    i == b.len()
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos],
                    b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).unwrap();
    if !is_json_number(text) {
        return Err(format!("bad number '{text}' at byte {start}"));
    }
    if !text.contains(['.', 'e', 'E', '-']) {
        if let Ok(v) = text.parse::<u64>() {
            return Ok(Json::Int(v));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{text}' at byte {start}"))
}

/// Write `s` as a quoted JSON string with RFC 8259 escaping.
fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_documents() {
        let doc = Json::obj()
            .field("schema", Json::Str("v1".into()))
            .field("count", Json::Int(3))
            .field("mean", Json::Num(0.25))
            .field("ok", Json::Bool(true))
            .field("rows", Json::Arr(vec![
                Json::obj().field("label", Json::Str("a".into())),
                Json::Null,
            ]));
        assert_eq!(doc.render(),
                   r#"{"schema":"v1","count":3,"mean":0.25,"ok":true,"rows":[{"label":"a"},null]}"#);
    }

    #[test]
    fn escapes_strings_and_degrades_nonfinite() {
        let doc = Json::obj()
            .field("s", Json::Str("a\"b\\c\nd\u{1}".into()))
            .field("nan", Json::Num(f64::NAN))
            .field("inf", Json::Num(f64::INFINITY));
        assert_eq!(doc.render(),
                   r#"{"s":"a\"b\\c\nd\u0001","nan":null,"inf":null}"#);
    }

    #[test]
    fn integers_are_exact() {
        // u64 values above 2^53 would lose precision through f64
        let big = (1u64 << 60) + 1;
        assert_eq!(Json::Int(big).render(), big.to_string());
    }

    /// Everything the builder can emit parses back to an equivalent
    /// tree — the round-trip the bench-diff gate depends on.
    #[test]
    fn parse_round_trips_rendered_documents() {
        let doc = Json::obj()
            .field("schema", Json::Str("ftblas.bench-smoke.v1".into()))
            .field("quick", Json::Bool(true))
            .field("count", Json::Int(3))
            .field("rows", Json::Arr(vec![
                Json::obj()
                    .field("label", Json::Str("dgemm/simd".into()))
                    .field("gflops", Json::Num(12.375))
                    .field("note", Json::Str("a\"b\\c\nd".into())),
                Json::Null,
            ]));
        let text = doc.render();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.render(), text, "render∘parse must be identity");
        assert_eq!(back.get("schema").and_then(Json::as_str),
                   Some("ftblas.bench-smoke.v1"));
        let rows = back.get("rows").and_then(Json::as_arr).unwrap();
        assert_eq!(rows[0].get("gflops").and_then(Json::as_f64),
                   Some(12.375));
        assert_eq!(rows[0].get("note").and_then(Json::as_str),
                   Some("a\"b\\c\nd"));
        assert_eq!(back.get("count").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn parse_accepts_whitespace_and_negative_numbers() {
        let back = Json::parse(" { \"a\" : [ -1.5 , 2e3 , 7 ] }\n").unwrap();
        let a = back.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert!(matches!(a[2], Json::Int(7)));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "tru", "1.5x", "{} {}"] {
            assert!(Json::parse(bad).is_err(), "accepted malformed: {bad:?}");
        }
    }

    /// `\u` escapes decode exactly: BMP scalars directly, astral chars
    /// through surrogate pairs — gaps the gateway's envelope round-trip
    /// proptests surfaced.
    #[test]
    fn parse_decodes_unicode_escapes() {
        let cases = [
            ("\"\\u0041\"", "A"),
            ("\"\\u00e9\"", "\u{e9}"),
            ("\"\\u2603\"", "\u{2603}"),
            // U+1D11E (musical G clef), the canonical astral example
            ("\"\\ud834\\udd1e\"", "\u{1D11E}"),
            ("\"\\ud83d\\ude00\"", "\u{1F600}"),
            ("\"\\u0000\"", "\u{0}"),
            ("\"\\u001f\"", "\u{1F}"),
        ];
        for (text, want) in cases {
            let got = Json::parse(text).unwrap();
            assert_eq!(got.as_str(), Some(want), "decoding {text}");
        }
        // escaped control chars round-trip through the writer
        let doc = Json::Str("\u{1}\u{1F}".into());
        assert_eq!(Json::parse(&doc.render()).unwrap().as_str(),
                   Some("\u{1}\u{1F}"));
    }

    /// Lone or mispaired surrogate halves are malformed, not U+FFFD.
    #[test]
    fn parse_rejects_broken_surrogates() {
        for bad in [
            r#""\ud834""#,          // lone high, string ends
            r#""\ud834x""#,         // lone high, raw char follows
            "\"\\ud834\\u0041\"",   // high paired with a non-surrogate
            r#""\udd1e""#,          // lone low
            r#""\ud834\ud834""#,    // high paired with another high
            r#""\u12""#,            // truncated hex
            r#""\u+123""#,          // sign is not a hex digit
        ] {
            assert!(Json::parse(bad).is_err(),
                    "accepted broken escape: {bad}");
        }
    }

    /// Raw (unescaped) control characters inside strings are malformed
    /// per RFC 8259 §7 — only their `\u`/short-escape forms parse.
    #[test]
    fn parse_rejects_raw_control_characters() {
        for bad in ["\"a\u{1}b\"", "\"a\nb\"", "\"\u{0}\"", "\"a\tb\""] {
            assert!(Json::parse(bad).is_err(),
                    "accepted raw control char: {bad:?}");
        }
        // the escaped forms of the same strings are fine
        assert_eq!(Json::parse(r#""a\nb""#).unwrap().as_str(),
                   Some("a\nb"));
        assert_eq!(Json::parse("\"a\\u0001b\"").unwrap().as_str(),
                   Some("a\u{1}b"));
    }

    /// Number syntax is JSON's (RFC 8259), not Rust's: no leading `+`,
    /// bare `.`, leading zeros, trailing dot, `-.5`, or empty exponent
    /// (exponent signs stay legal).
    #[test]
    fn parse_rejects_nonjson_number_forms() {
        for bad in ["+1", "[+1.5]", "{\"a\":+2}", ".5", "[.25]",
                    "01", "[007]", "-01", "1.", "[2.e3]", "-.5", "[-.25]",
                    "-", "1e", "1e+", "[1E-]", "--1", "1.2.3"] {
            assert!(Json::parse(bad).is_err(), "accepted: {bad}");
        }
        assert_eq!(Json::parse("1e+3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2e-2").unwrap().as_f64(), Some(-0.02));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("0.25").unwrap().as_f64(), Some(0.25));
        assert_eq!(Json::parse("10.5E2").unwrap().as_f64(), Some(1050.0));
    }
}
