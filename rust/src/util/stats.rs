//! Timing statistics for the bench harness: the paper reports the average
//! of 20 repeated measurements; we also keep percentiles for the serving
//! metrics.

use std::time::{Duration, Instant};

/// Summary statistics over a set of measurements (seconds).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 99th percentile (nearest-rank).
    pub p99: f64,
}

impl Summary {
    /// Summarize a sample slice (all zeros when empty).
    pub fn from_samples(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((n as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: pct(0.5),
            p99: pct(0.99),
        }
    }
}

/// Time `f` for `reps` repetitions after `warmup` runs; returns per-rep
/// seconds. The paper repeats each measurement 20 times and averages.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// GFLOPS for an op count and a duration in seconds.
pub fn gflops(flops: f64, secs: f64) -> f64 {
    if secs <= 0.0 { 0.0 } else { flops / secs / 1e9 }
}

/// Pretty duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::from_samples(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::from_samples(&xs);
        assert!(s.p50 <= s.p99);
        assert!((s.p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn gflops_sane() {
        assert!((gflops(2e9, 1.0) - 2.0).abs() < 1e-12);
        assert_eq!(gflops(1.0, 0.0), 0.0);
    }

    #[test]
    fn time_reps_counts() {
        let mut calls = 0;
        let t = time_reps(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }
}
