//! `ftblas` — CLI for the FT-BLAS reproduction.
//!
//! ```text
//! ftblas artifacts                         list AOT artifacts
//! ftblas verify [--profile P]              cross-check artifacts vs native
//! ftblas run --routine R --n N [...]       execute one routine
//! ftblas serve --requests N [...]          drive the plan-aware server
//! ftblas gateway [--addr A] [...]          HTTP/1.1 front end over the
//!                                          cluster (docs/PROTOCOL.md)
//! ftblas soak [--quick] [...]              timed fault-injection campaign
//!                                          on an elastic tier (CI gate)
//! ftblas backends [--json]                 capability catalog: backends,
//!                                          health, kernel descriptors
//! ftblas bench --exp ID [--quick]          regenerate a paper table/figure
//! ftblas bench-diff BASE.json CAND.json    gate candidate bench rows
//!                                          against a committed baseline
//! ```

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use ftblas::bench::{self, BenchCtx};
use ftblas::blas::Impl;
use ftblas::config::Profile;
use ftblas::coordinator::autoscale::ScalingConfig;
use ftblas::coordinator::cluster::{Cluster, ClusterConfig, ClusterHandle,
                                   RetryPolicy};
use ftblas::coordinator::executor::PjrtExecutor;
use ftblas::coordinator::gateway::{self, Envelope, Gateway, GatewayConfig};
use ftblas::coordinator::http;
use ftblas::coordinator::pjrt_backend::PjrtBackend;
use ftblas::coordinator::plan::{CapRequirement, Planner, SelectionPolicy};
use ftblas::coordinator::registry;
use ftblas::coordinator::request::{Backend, BlasRequest, BlasResponse,
                                   BlasResult};
use ftblas::coordinator::router::{execute_plan, Router};
use ftblas::coordinator::trace::{self, Burst, TraceConfig, TraceShape};
use ftblas::ft::injector::{CampaignConfig, CampaignTarget, Fault,
                           InjectorConfig};
use ftblas::ft::policy::FtPolicy;
use ftblas::util::json::Json;
use ftblas::util::matrix::Matrix;
use ftblas::util::rng::Rng;

/// Minimal flag parser (clap is not vendored in this offline image).
struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(name) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { flags, positional }
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants a number")),
            None => Ok(default),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn usage() -> ! {
    eprintln!(
        "ftblas — FT-BLAS reproduction (Zhai et al., ICS '21)

USAGE:
  ftblas artifacts [--profile skylake_sim|cascade_sim]
  ftblas verify    [--profile P] [--quick]
  ftblas run --routine dgemm --n 256
             [--backend naive|blocked|tuned|simd|pjrt|gpu-sim]
             [--variant naive|blocked|tuned|simd] [--threads T]
             [--ft none|hybrid|abft-unfused|abft-weighted] [--inject]
             [--profile P]
  ftblas serve [--requests N] [--ft P] [--shards S] [--min-shards M]
             [--max-shards X] [--scale-interval MS] [--admission-depth D]
             [--workers W] [--max-batch B] [--thread-budget T] [--threads T]
             [--vec-len N] [--mat-dim N]
             [--backend naive|blocked|tuned|simd|pjrt|gpu-sim]
             [--require cap=value[,cap=value]] [--deny backend[,backend]]
             [--trace steady|burst|small-gemm] [--burst F]
             [--pool-workers N] [--no-pool]
             [--inject] [--profile P]
             (--shards: fixed-size cluster, routed by planned kernel;
              --min-shards/--max-shards: elastic bounds — a scaling
              controller grows/shrinks the tier every --scale-interval ms;
              --admission-depth: per-shard queue watermark — excess
              submissions shed as `Overloaded` and retried with backoff;
              --trace burst (or --burst F): bursty paced arrivals;
              --trace small-gemm: bursty all-small-DGEMM stream that
              exercises the batch-fused execution path — pair with
              --backend simd to fuse under a protecting --ft policy;
              --pool-workers: size of the cluster's persistent compute
              pool (default: the thread budget); --no-pool: scoped
              fork/join per kernel frame — the A/B baseline, bitwise
              identical results;
              --backend seeds the selection ladder's preference order;
              --require precision=f64 / scheme=S / threaded=B /
              batched=B / feature=F constrains every plan to kernels
              with that capability, --deny excludes whole backends —
              together they build the tier's SelectionPolicy)
  ftblas gateway [--addr HOST:PORT] [--workers N (HTTP handler threads)]
             [--ft P] [--backend naive|blocked|tuned|simd|pjrt|gpu-sim]
             [--require cap=value[,cap=value]] [--deny backend[,backend]]
             [--shards S] [--min-shards M]
             [--max-shards X] [--admission-depth D] [--shard-workers W]
             [--threads T] [--retry-attempts N] [--max-deadline-s S]
             [--max-dim N (envelope dim cap, default 4096 — operand
              memory is O(dim^2); oversized requests answer 413)]
             [--duration SECS] [--campaign] [--rate ERRORS_PER_MIN]
             [--stride K] [--target all|dmr|abft|fused] [--seed S]
             [--self-check] [--out PATH] [--profile P]
             (dependency-free HTTP/1.1 front end over the elastic
              cluster — the wire contract is docs/PROTOCOL.md. POST
              /v1/blas takes an ftblas.request.v1 envelope, or a v2
              envelope whose `routing` object overlays per-request
              backend pins / allow / deny / capability requirements on
              the flags' SelectionPolicy; GET
              /healthz /metrics /topology /campaign /backends serve
              live operational state. Typed outcomes map onto status
              codes:
              Overloaded -> 429 with Retry-After, planner no-candidate
              -> 400 with the diagnostic, deadline -> 504. --campaign
              arms a seeded injection campaign under wire load;
              --duration drains gracefully after SECS (default: serve
              until killed). --self-check binds an ephemeral port,
              round-trips one request against a direct in-process call,
              checks /healthz and the 400 mapping, and exits nonzero on
              any mismatch; --out writes the ftblas.gateway-check.v1
              report as JSON.)
  ftblas soak [--quick] [--duration SECS] [--rate ERRORS_PER_MIN]
             [--stride K] [--target all|dmr|abft|fused] [--ft P]
             [--seed S (campaign schedule)] [--trace-seed S (workload)]
             [--min-shards M] [--max-shards X] [--admission-depth D]
             [--workers W] [--threads T] [--mat-dim N] [--vec-len N]
             [--out PATH] [--pool-workers N] [--no-pool]
             [--trace steady|burst|small-gemm]
             [--backend naive|blocked|tuned|simd|pjrt|gpu-sim]
             [--require cap=value[,cap=value]] [--deny backend[,backend]]
             [--profile P]
             (timed, rate-controlled fault-injection campaign against an
              elastic burst trace; exits nonzero unless the tier grew,
              shards spawned mid-run were struck, no error escaped, and
              the injected/detected/corrected counts balance exactly —
              the CI reliability gate. Unless --no-pool, the gate also
              asserts the persistent compute pool woke parked workers
              and leaked no tasks. --backend gpu-sim soaks the
              simulated warp executors' fused-ABFT tiers. --out writes
              the soak report as JSON.)
  ftblas backends [--json]
             (capability catalog: every backend with its health probe
              and per-kernel descriptor records — scheme, precision,
              threading, dim caps, served policies, CPU features,
              selection counts. --json emits the same
              ftblas.backends.v1 document the gateway's GET /backends
              route serves.)
  ftblas bench --exp smoke|table1|fig5|fig6|fig7|fig8a|fig8b|fig9|fig10|fig11|all
             [--quick] [--profile P]
             (--exp smoke also takes --out PATH to write its rows as JSON)
  ftblas bench --exp ablations   (or ablation-kc|ablation-trsm-panel|
             ablation-threads|ablation-weighted)
  ftblas bench-diff BASELINE.json CANDIDATE.json [--tolerance 0.05]
             (compare two ftblas.bench-smoke.v1 row sets per label; exits
              nonzero when a candidate row's GFLOP/s regresses below the
              baseline by more than the tolerance — the committed perf
              trajectory's CI gate)"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let profile = Profile::by_name(&args.get("profile", "skylake_sim"))
        .ok_or_else(|| anyhow!("unknown profile"))?;

    match cmd.as_str() {
        "artifacts" => cmd_artifacts(&profile),
        "verify" => cmd_verify(&profile, args.has("quick")),
        "run" => cmd_run(&args, profile),
        "serve" => cmd_serve(&args, profile),
        "gateway" => cmd_gateway(&args, profile),
        "soak" => cmd_soak(&args, profile),
        "backends" => cmd_backends(&args),
        "bench" => {
            let exp = args.get("exp", "all");
            let mut ctx = BenchCtx::with_artifacts(profile, args.has("quick"));
            if args.has("out") {
                ctx.out = Some(args.get("out", "bench.json").into());
            }
            bench::run(&exp, &mut ctx)
        }
        "bench-diff" => cmd_bench_diff(&args),
        _ => usage(),
    }
}

/// Desugar the selection flags `serve`, `soak`, and `gateway` share:
/// `--backend` seeds the preference order, `--require
/// cap=value[,cap=value]` adds capability requirements every plan must
/// satisfy, and `--deny backend[,backend]` excludes whole backends.
/// The result is the [`SelectionPolicy`] the tier's router serves.
fn selection_args(args: &Args, cmd: &str)
                  -> Result<(Backend, SelectionPolicy)> {
    let name = args.get("backend", "tuned");
    let backend = Backend::by_name(&name).ok_or_else(|| {
        anyhow!("{cmd} --backend wants naive|blocked|tuned|simd|pjrt|\
                 gpu-sim, got `{name}`")
    })?;
    let mut sel = SelectionPolicy::for_backend(backend);
    if let Some(spec) = args.flags.get("deny") {
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let be = Backend::by_name(item).ok_or_else(|| {
                anyhow!("--deny: unknown backend `{item}` (want naive|\
                         blocked|tuned|simd|pjrt|gpu-sim)")
            })?;
            sel = sel.with_denied(be);
        }
    }
    if let Some(spec) = args.flags.get("require") {
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, value) = item.split_once('=').ok_or_else(|| {
                anyhow!("--require wants cap=value (e.g. precision=f64, \
                         scheme=abft-fused, threaded=true), got `{item}`")
            })?;
            sel.require.push(CapRequirement::parse(key, value)
                .map_err(|e| anyhow!("--require: {e}"))?);
        }
    }
    Ok((backend, sel))
}

/// `ftblas backends [--json]` — the capability catalog: every backend
/// with its health probe and per-kernel descriptor records, the same
/// `ftblas.backends.v1` document the gateway's `GET /backends` route
/// serves (one serializer, two transports).
fn cmd_backends(args: &Args) -> Result<()> {
    let doc = registry::backends_json(None);
    if args.has("json") {
        println!("{}", doc.render());
        return Ok(());
    }
    let empty: &[Json] = &[];
    let backends = doc.get("backends").and_then(Json::as_arr)
        .unwrap_or(empty);
    for be in backends {
        let kernels = be.get("kernels").and_then(Json::as_arr)
            .unwrap_or(empty);
        println!("{} — {} ({} kernels)",
                 be.get("backend").and_then(Json::as_str).unwrap_or("?"),
                 be.get("health").and_then(Json::as_str).unwrap_or("?"),
                 kernels.len());
        for k in kernels {
            let field = |n: &str| k.get(n)
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string();
            let policies = k.get("policies").and_then(Json::as_arr)
                .unwrap_or(empty)
                .iter()
                .filter_map(Json::as_str)
                .collect::<Vec<_>>()
                .join(",");
            // max_dim 0 = uncapped; render as "-" so the table reads as
            // the capability it is, not a zero-sized kernel
            let max_dim = match k.get("max_dim").and_then(Json::as_f64) {
                Some(d) if d > 0.0 => format!("{}", d as u64),
                _ => "-".to_string(),
            };
            println!("  {:<28} scheme={:<13} threaded={:<5} max_dim={:<6} \
                      policies={}",
                     field("name"), field("scheme"),
                     matches!(k.get("threaded"), Some(Json::Bool(true))),
                     max_dim, policies);
        }
    }
    Ok(())
}

/// `ftblas bench-diff BASELINE CANDIDATE` — the committed-perf gate.
/// Both files are `ftblas.bench-smoke.v1` documents; rows are matched
/// by label and a candidate row whose GFLOP/s falls more than the
/// tolerance below the baseline fails the run. Rows only ever produced
/// on one side (new kernels, zero-GFLOP floor rows) never gate but are
/// called out as explicit warnings — a renamed or lost row must not
/// masquerade as a clean pass — and when the two documents were produced under
/// different `cpu_features` the comparison is reported without gating
/// — rows from different machines are not commensurable.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let [baseline, candidate] = args.positional.as_slice() else {
        bail!("bench-diff wants exactly two row files: \
               ftblas bench-diff BASELINE.json CANDIDATE.json");
    };
    let tolerance = match args.flags.get("tolerance") {
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| anyhow!("--tolerance wants a number"))?,
        None => 0.05,
    };
    let load = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("{path}: {e}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow!("{path}: malformed JSON: {e}"))?;
        match doc.get("schema").and_then(Json::as_str) {
            Some("ftblas.bench-smoke.v1") => Ok(doc),
            other => bail!("{path}: not an ftblas.bench-smoke.v1 document \
                            (schema {other:?})"),
        }
    };
    let base = load(baseline)?;
    let cand = load(candidate)?;
    let feat = |d: &Json| {
        d.get("cpu_features")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string()
    };
    // zero-GFLOP rows (the L1 request-clone floor) carry no throughput
    // claim, so they never gate
    let rows = |d: &Json| -> Vec<(String, f64)> {
        d.get("rows")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|r| {
                let label = r.get("label")?.as_str()?;
                let g = r.get("gflops")?.as_f64()?;
                (g > 0.0).then(|| (label.to_string(), g))
            })
            .collect()
    };
    let (bf, cf) = (feat(&base), feat(&cand));
    let comparable = bf == cf;
    println!("bench-diff: {candidate} vs {baseline} (tolerance -{:.1}%)",
             tolerance * 100.0);
    if !comparable {
        println!("cpu_features differ (baseline `{bf}`, candidate `{cf}`): \
                  rows from different machines are not commensurable — \
                  reporting deltas without gating");
    }
    let base_rows = rows(&base);
    let cand_rows = rows(&cand);
    if base_rows.is_empty() {
        bail!("{baseline}: no gateable rows (all zero-GFLOP or missing)");
    }
    println!("{:<38} {:>10} {:>10} {:>8}  {}", "label", "base", "cand",
             "delta", "status");
    let mut regressions = Vec::new();
    let mut one_sided = Vec::new();
    for (label, bg) in &base_rows {
        let Some((_, cg)) = cand_rows.iter().find(|(l, _)| l == label) else {
            println!("{label:<38} {bg:>10.3} {:>10} {:>8}  WARNING: \
                      dropped from candidate (not gated)", "-", "-");
            one_sided.push(format!("`{label}` only in baseline"));
            continue;
        };
        let delta = (cg - bg) / bg * 100.0;
        let regressed = *cg < bg * (1.0 - tolerance);
        let status = match (regressed, comparable) {
            (false, _) => "ok",
            (true, true) => "REGRESSION",
            (true, false) => "slower (not gated)",
        };
        println!("{label:<38} {bg:>10.3} {cg:>10.3} {delta:>+7.1}%  \
                  {status}");
        if regressed && comparable {
            regressions.push(label.clone());
        }
    }
    for (label, cg) in &cand_rows {
        if !base_rows.iter().any(|(l, _)| l == label) {
            println!("{label:<38} {:>10} {cg:>10.3} {:>8}  WARNING: new \
                      row (not gated)", "-", "-");
            one_sided.push(format!("`{label}` only in candidate"));
        }
    }
    // one-sided labels carry no regression verdict either way; surface
    // them loudly so a silently-renamed or lost row cannot masquerade
    // as a clean pass
    for warn in &one_sided {
        eprintln!("bench-diff: warning: {warn} — row not gated; update \
                   the baseline if the rename/addition is intentional");
    }
    if !regressions.is_empty() {
        bail!("bench-diff: {} row(s) regressed beyond {:.1}%: {}",
              regressions.len(), tolerance * 100.0, regressions.join(", "));
    }
    println!("bench-diff: no regressions beyond {:.1}%", tolerance * 100.0);
    Ok(())
}

fn cmd_artifacts(profile: &Profile) -> Result<()> {
    let dir = profile.artifact_path();
    let manifest = ftblas::runtime::manifest::Manifest::load(&dir)?;
    println!("profile: {} ({} artifacts)", manifest.profile,
             manifest.specs.len());
    for s in &manifest.specs {
        println!("{:<32} {:<8} {:<10} in:{} out:{}", s.name, s.routine,
                 s.variant, s.inputs.len(), s.outputs.len());
    }
    Ok(())
}

/// Cross-check every artifact family against the native oracle.
fn cmd_verify(profile: &Profile, quick: bool) -> Result<()> {
    let dir = profile.artifact_path();
    let exec = PjrtExecutor::spawn(dir.clone())?;
    let backend = PjrtBackend::new(exec.handle.clone(), &dir)?;
    let router = Router::with_pjrt(profile.clone(), backend, Backend::Pjrt);
    let mut rng = Rng::new(42);
    let mut pass = 0;
    let mut total = 0;

    let n1 = 65536;
    let n2 = 256;
    let n3 = if quick { 128 } else { 256 };
    let a2 = Matrix::random(n2, n2, &mut rng);
    let l2 = Matrix::random_lower_triangular(n2, &mut rng);
    let a3 = Matrix::random(n3, n3, &mut rng);
    let b3 = Matrix::random(n3, n3, &mut rng);
    let c3 = Matrix::random(n3, n3, &mut rng);
    let l3 = Matrix::random_lower_triangular(n3, &mut rng);
    let reqs = vec![
        BlasRequest::Dscal { alpha: 1.5, x: rng.normal_vec(n1) },
        BlasRequest::Daxpy { alpha: -0.5, x: rng.normal_vec(n1),
                             y: rng.normal_vec(n1) },
        BlasRequest::Ddot { x: rng.normal_vec(n1), y: rng.normal_vec(n1) },
        BlasRequest::Dnrm2 { x: rng.normal_vec(n1) },
        BlasRequest::Dasum { x: rng.normal_vec(n1) },
        BlasRequest::Dgemv { alpha: 1.1, a: a2.clone(), x: rng.normal_vec(n2),
                             beta: 0.4, y: rng.normal_vec(n2) },
        BlasRequest::Dtrsv { a: l2.clone(), b: rng.normal_vec(n2) },
        BlasRequest::Dgemm { alpha: 1.0, a: a3.clone(), b: b3.clone(),
                             beta: 0.0, c: Matrix::zeros(n3, n3) },
        BlasRequest::Dsymm { alpha: 1.0, a: a3.clone(), b: b3.clone(),
                             beta: 0.0, c: c3.clone() },
        BlasRequest::Dtrmm { alpha: 1.0, a: l3.clone(), b: b3.clone() },
        BlasRequest::Dtrsm { a: l3.clone(), b: b3.clone() },
        BlasRequest::Dsyrk { alpha: 1.0, a: a3.clone(), beta: 0.0,
                             c: c3.clone() },
    ];

    for policy in [FtPolicy::None, FtPolicy::Hybrid] {
        for req in &reqs {
            let Some(plan) = router.plan(req, policy) else {
                continue;
            };
            if plan.kernel.backend != Backend::Pjrt {
                continue; // no artifact for this shape/policy
            }
            total += 1;
            let want = run_native_oracle(req, profile);
            let fault = (policy.protects()
                && !matches!(req, BlasRequest::Dasum { .. }
                             | BlasRequest::Dsyrk { .. }))
                .then_some(Fault { step: 1, i: 7, j: 11, delta: 1e4 });
            let got = router.execute_planned(&plan, req, fault)?;
            let injected = fault.is_some();
            let ok = results_close(&got.result, &want.result, 1e-6)
                && (!injected || got.ft.errors_detected >= 1);
            println!("{:<8} policy={:<8} inject={:<5} detected={} ... {}",
                     req.routine(), policy.name(), injected,
                     got.ft.errors_detected, if ok { "OK" } else { "FAIL" });
            if ok {
                pass += 1;
            }
        }
    }
    println!("verify: {pass}/{total} checks passed");
    if pass != total {
        bail!("artifact verification failed");
    }
    Ok(())
}

/// The native reference execution `verify` checks artifacts against:
/// plan onto the pinned naive ladder, unprotected, and run the plan —
/// the same planned path everything else takes, just fully pinned.
fn run_native_oracle(req: &BlasRequest, profile: &Profile) -> BlasResponse {
    let sel = SelectionPolicy::for_variant(Impl::Naive);
    let plan = Planner::new(profile)
        .plan(req, &sel, FtPolicy::None)
        .expect("the naive ladder serves every routine unprotected");
    execute_plan(req, &plan, profile, None)
}

fn results_close(a: &BlasResult, b: &BlasResult, tol: f64) -> bool {
    use ftblas::util::matrix::allclose;
    match (a, b) {
        (BlasResult::Scalar(x), BlasResult::Scalar(y)) => {
            (x - y).abs() <= tol * (1.0 + y.abs())
        }
        (BlasResult::Vector(x), BlasResult::Vector(y)) => allclose(x, y, tol, tol),
        (BlasResult::Matrix(x), BlasResult::Matrix(y)) => {
            allclose(&x.data, &y.data, tol, tol)
        }
        _ => false,
    }
}

/// Drive the sharded serving tier with a mixed trace and print the
/// merged per-kernel metrics ledger: admission-time plans, rendezvous
/// routing across shards, queue-depth shedding with client-side
/// retries, elastic scaling events, kernel-keyed batches, the
/// thread-budget ledgers, SLO burns, plan-cache hit rates.
fn cmd_serve(args: &Args, mut profile: Profile) -> Result<()> {
    let requests = args.get_usize("requests", 200)?.max(1);
    let policy = FtPolicy::by_name(&args.get("ft", "hybrid"))
        .ok_or_else(|| anyhow!("bad --ft"))?;
    profile.threads = args.get_usize("threads", profile.threads)?.max(1);
    profile.workers = args.get_usize("workers", profile.workers)?.max(1);
    profile.max_batch = args.get_usize("max-batch", profile.max_batch)?.max(1);
    if args.has("thread-budget") {
        profile.thread_budget =
            Some(args.get_usize("thread-budget", 0)?.max(1));
    }
    if args.has("admission-depth") {
        profile.admission_depth =
            Some(args.get_usize("admission-depth", 0)?.max(1));
    }
    if args.has("pool-workers") {
        profile = profile
            .with_pool_workers(args.get_usize("pool-workers", 0)?.max(1));
    }
    if args.has("no-pool") {
        profile = profile.without_pool();
    }
    // sizing: `--shards` is the fixed-size mode; `--min-shards` /
    // `--max-shards` widen the bounds and hand sizing to the
    // autoscaling controller (starting at the floor)
    if args.has("min-shards") || args.has("max-shards") {
        let min = args.get_usize("min-shards", 1)?.max(1);
        let max = args.get_usize("max-shards", profile.shards.max(min))?;
        if min >= max {
            // the elastic flags promise an autoscaler, which needs a
            // real range — a collapsed or inverted one would silently
            // run fixed-size (use --shards for that)
            bail!("elastic bounds [{min}, {max}] leave the autoscaler no \
                   room: need min < max (use --shards N for a fixed-size \
                   tier)");
        }
        profile = profile.with_shard_bounds(min, max);
        // start at an explicit --shards (clamped into the bounds), else
        // at the floor and let the controller earn the rest
        profile.shards = args
            .get_usize("shards", profile.min_shards)?
            .clamp(profile.min_shards, profile.max_shards);
    } else {
        profile = profile
            .with_shards(args.get_usize("shards", profile.shards)?.max(1));
    }
    // 10ms sampling: bursty queue spikes last a few ms, so the
    // controller needs a tight cadence to witness them live (shed and
    // burn counters integrate between samples regardless)
    let scale_interval = args.get_usize("scale-interval", 10)?.max(1);
    let mat_dim = args.get_usize("mat-dim", 128)?;
    // `--trace` names a workload shape; `small-gemm` also overrides the
    // mix/dims to the batch-fusion workload. `--burst F` layers the
    // on/off overlay at a custom factor on top of any shape.
    let shape = TraceShape::from_name(&args.get("trace", "steady"))
        .map_err(|e| anyhow!(e))?;
    let mut cfg = shape.apply(TraceConfig {
        requests,
        vec_len: args.get_usize("vec-len", 16384)?,
        mat_dim,
        // a second MT-eligible DGEMM shape shows kernel-keyed batching
        mat_dim_alt: Some((mat_dim / 2).max(profile.gemm.mr * 2)),
        seed: args.get_usize("seed", 0x5E12)? as u64,
        ..Default::default()
    });
    if args.has("burst") {
        let factor = match args.get("burst", "50").as_str() {
            "true" => 50.0,
            v => v.parse::<f64>().map_err(|_| anyhow!("--burst wants a number"))?,
        };
        cfg.burst =
            Some(Burst { factor: factor.max(1.0), ..Default::default() });
    }
    // `--backend` seeds the tier's selection ladder: `simd` is the
    // preference whose batched sibling exists (so the small-gemm shape
    // actually fuses), `gpu-sim` routes protected small DGEMMs onto the
    // simulated warp executors. `--require`/`--deny` tighten the policy
    // every admission-time plan resolves under.
    let (backend, selection) = selection_args(args, "serve")?;
    println!("serve: {} requests on {} (shards={}{}, workers/shard={}, \
              threads={}, max_batch={}, admission_depth={}, policy={}, \
              trace={}, backend={}, pool={})",
             requests, profile.name, profile.shards,
             if profile.elastic() {
                 format!(" elastic [{}..{}]", profile.min_shards,
                         profile.max_shards)
             } else {
                 String::new()
             },
             profile.workers, profile.threads, profile.max_batch,
             profile.admission_depth.map_or("unbounded".to_string(),
                                            |d| d.to_string()),
             policy.name(), shape.name(), backend.name(),
             if profile.no_pool {
                 "off (scoped frames)".to_string()
             } else {
                 format!("{} workers", profile.pool_worker_count())
             });
    let entries = trace::generate(&cfg);
    let injection = args.has("inject").then(|| InjectorConfig {
        count: (requests / 8).max(1),
        ..Default::default()
    });
    let autoscale = profile.elastic().then(|| {
        let mut scfg = ScalingConfig::from_profile(&profile)
            .with_interval(std::time::Duration::from_millis(
                scale_interval as u64));
        scfg.verbose = true;
        scfg
    });
    let cluster_cfg = ClusterConfig {
        injection,
        expected_requests: requests,
        autoscale,
        ..ClusterConfig::from_profile(&profile)
    };
    let elastic = cluster_cfg.autoscale.is_some();
    let min_shards = profile.min_shards;
    let router =
        Router::native_only(profile, backend).with_selection(selection);
    let cluster = Cluster::start(router, policy, cluster_cfg);
    let handle = cluster.handle();
    let retry = RetryPolicy::default();
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    let mut retries = 0u64;
    // with a burst overlay the trace's arrival times are the point:
    // pace submissions by them so the on-phases actually slam the
    // admission watermark while off-phases let the shards drain.
    // Without bursts, submissions stay un-paced (as fast as possible).
    let paced = cfg.burst.is_some();
    for e in &entries {
        if paced {
            let at = t0 + std::time::Duration::from_secs_f64(e.at_seconds);
            let wait = at.saturating_duration_since(std::time::Instant::now());
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        if paced {
            // bursty clients ride out transient sheds with jittered
            // exponential backoff instead of losing the request
            let (admitted, spent) = handle.submit_with_retry(
                e.request.clone(), &retry);
            retries += spent as u64;
            match admitted {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1, // retries exhausted
            }
        } else {
            match handle.submit(e.request.clone()) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1, // typed Overloaded, no pacing
            }
        }
    }
    for rx in rxs {
        rx.recv()??;
    }
    let wall = t0.elapsed().as_secs_f64();
    // elastic runs end with a cooldown: the trace is done, arrivals are
    // calm, and the controller should hand capacity back — wait for at
    // least one scale-down (bounded) so a single `serve` demonstrates a
    // full grow→shrink cycle.
    if elastic {
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(3);
        while std::time::Instant::now() < deadline {
            // done when the tier never grew (nothing to hand back) or
            // has drained back down to the floor; scale_events is a
            // cheap counter read, no ledger merge per poll
            let (ups, _) = handle.scale_events();
            if ups == 0 || handle.shard_count() <= min_shards {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }
    let shard_snaps = cluster.shard_metrics();
    let retired = cluster.retired_metrics();
    let snap = cluster.shutdown();
    // unpaced runs submit without retries, so their rejects are raw
    // first-attempt sheds — label them as such
    let shed_label =
        if paced { "shed after retries" } else { "shed at admission" };
    println!("completed {} of {} requests in {:.2}s -> {:.1} req/s \
              ({retries} retried, {rejected} {shed_label})\n",
             snap.completed, requests, wall, snap.completed as f64 / wall);
    for (i, s) in shard_snaps.iter().enumerate() {
        println!("shard {i}: {} completed, {} shed, e2e p99={:.2}ms, \
                  max queue depth {}",
                 s.completed, s.shed, s.overall_e2e().p99 * 1e3,
                 s.max_queue_depth);
    }
    for (i, s) in retired.iter().enumerate() {
        println!("retired shard #{i}: {} completed, {} shed \
                  (drained by scale-down; ledger merged below)",
                 s.completed, s.shed);
    }
    println!();
    ftblas::bench::harness::print_ledger(&snap);
    Ok(())
}

/// `ftblas gateway` — serve the cluster over HTTP/1.1
/// (docs/PROTOCOL.md). Fixed-size by default; `--min-shards` /
/// `--max-shards` hand sizing to the autoscaler exactly as `serve`
/// does; `--campaign` arms seeded injection under wire load. With
/// `--self-check` the gateway binds an ephemeral port, conforms one
/// wire round-trip against a direct in-process call, and exits
/// nonzero on any mismatch — the CI smoke step.
fn cmd_gateway(args: &Args, mut profile: Profile) -> Result<()> {
    let policy = FtPolicy::by_name(&args.get("ft", "hybrid"))
        .ok_or_else(|| anyhow!("bad --ft"))?;
    // one SelectionPolicy serves both the router and the gateway's
    // planner preflights — the preflight must see exactly the ladder
    // the cluster will resolve under, or the 400s would lie
    let (backend, selection) = selection_args(args, "gateway")?;
    profile.threads = args.get_usize("threads", profile.threads)?.max(1);
    profile.workers =
        args.get_usize("shard-workers", profile.workers)?.max(1);
    if args.has("admission-depth") {
        profile.admission_depth =
            Some(args.get_usize("admission-depth", 0)?.max(1));
    }
    if args.has("min-shards") || args.has("max-shards") {
        let min = args.get_usize("min-shards", 1)?.max(1);
        let max = args.get_usize("max-shards", profile.shards.max(min))?;
        if min >= max {
            bail!("elastic bounds [{min}, {max}] leave the autoscaler no \
                   room: need min < max (use --shards N for a fixed-size \
                   tier)");
        }
        profile = profile.with_shard_bounds(min, max);
        profile.shards = args
            .get_usize("shards", profile.min_shards)?
            .clamp(profile.min_shards, profile.max_shards);
    } else {
        profile = profile.with_shards(args.get_usize("shards", 2)?.max(1));
    }
    if args.has("campaign") {
        let target = CampaignTarget::by_name(&args.get("target", "all"))
            .ok_or_else(|| anyhow!("bad --target (want all|dmr|abft|\
                                    fused)"))?;
        if !policy.protects() {
            bail!("--campaign needs a protecting --ft policy: under \
                   `none` the strikes could never be detected");
        }
        if !policy.reaches(target) {
            bail!("campaign target `{}` is unreachable under policy `{}`",
                  target.name(), policy.name());
        }
        profile = profile.with_campaign(CampaignConfig {
            seed: args.get_usize("seed", 0xCA4A16)? as u64,
            rate_per_min: args.get_usize("rate", 600)?.max(1) as f64,
            stride: args.get_usize("stride", 2)?.max(1) as u64,
            target,
            ..Default::default()
        });
    }
    let scale_interval = args.get_usize("scale-interval", 10)?.max(1);
    let autoscale = profile.elastic().then(|| {
        ScalingConfig::from_profile(&profile).with_interval(
            std::time::Duration::from_millis(scale_interval as u64))
    });
    let cluster_cfg = ClusterConfig {
        autoscale,
        ..ClusterConfig::from_profile(&profile)
    };
    let router = Router::native_only(profile.clone(), backend)
        .with_selection(selection.clone());
    let cluster = Cluster::start(router, policy, cluster_cfg);
    let handle = cluster.handle();
    let gcfg = GatewayConfig {
        workers: args.get_usize("workers", 4)?.max(1),
        retry: RetryPolicy {
            attempts: args.get_usize("retry-attempts", 5)? as u32,
            ..RetryPolicy::default()
        },
        selection,
        max_deadline: std::time::Duration::from_secs(
            args.get_usize("max-deadline-s", 30)?.max(1) as u64),
        max_dim: args.get_usize("max-dim", 4096)?.max(1),
    };
    if args.has("self-check") {
        return gateway_self_check(args, cluster, handle, profile, policy,
                                  gcfg);
    }
    let addr = args.get("addr", "127.0.0.1:8775");
    let gw = Gateway::bind(&addr, handle, profile.clone(), policy, gcfg)?;
    println!("gateway: listening on {} (policy={}, backend={}, \
              shards={}{}, campaign={})",
             gw.local_addr(), policy.name(), backend.name(),
             profile.shards,
             if profile.elastic() {
                 format!(" elastic [{}..{}]", profile.min_shards,
                         profile.max_shards)
             } else {
                 String::new()
             },
             if profile.campaign.is_some() { "armed" } else { "off" });
    let duration = args.get_usize("duration", 0)?;
    if duration == 0 {
        // serve until the process is killed
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration as u64));
    let stats = gw.shutdown();
    println!("gateway drained: {} accepted == {} served \
              ({} 2xx / {} 4xx / {} 5xx)",
             stats.accepted, stats.served, stats.s2xx, stats.s4xx,
             stats.s5xx);
    let snap = cluster.shutdown();
    ftblas::bench::harness::print_ledger(&snap);
    Ok(())
}

/// The `--self-check` smoke: one wire round-trip conformed against a
/// direct in-process call, plus the `/healthz` and 400 mappings and
/// the drain invariant. Exits nonzero on any mismatch.
fn gateway_self_check(args: &Args, cluster: Cluster, handle: ClusterHandle,
                      profile: Profile, policy: FtPolicy,
                      cfg: GatewayConfig) -> Result<()> {
    let gw = Gateway::bind("127.0.0.1:0", handle.clone(), profile, policy,
                           cfg)?;
    let addr = gw.local_addr().to_string();
    println!("gateway self-check on {addr}");
    let parse = |body: &str| {
        Json::parse(body).unwrap_or(Json::Null)
    };
    let mut checks = Vec::new();

    // one wire round-trip must byte-agree with the in-process result
    let env = Envelope::new("dgemm", 48);
    let wire = http::fetch(&addr, "POST", "/v1/blas",
                           Some(&env.to_json().render()))
        .map_err(|e| anyhow!("self-check POST failed: {e}"))?;
    let wire_sum = parse(&wire.body).get("checksum").and_then(Json::as_f64);
    let direct = handle.call(env.build_request().expect("dgemm builds"))?;
    let direct_sum = gateway::result_checksum(&direct.result);
    checks.push(soak_check(
        "wire-roundtrip",
        wire.status == 200 && wire_sum == Some(direct_sum),
        format!("status {}, wire checksum {:?} vs direct {}",
                wire.status, wire_sum, direct_sum)));

    let health = http::fetch(&addr, "GET", "/healthz", None)
        .map_err(|e| anyhow!("self-check /healthz failed: {e}"))?;
    let hdoc = parse(&health.body);
    checks.push(soak_check(
        "healthz",
        health.status == 200
            && hdoc.get("schema").and_then(Json::as_str)
                == Some(gateway::HEALTH_SCHEMA)
            && hdoc.get("status").and_then(Json::as_str) == Some("ok"),
        format!("status {}, body schema {:?}", health.status,
                hdoc.get("schema").and_then(Json::as_str))));

    let bad = http::fetch(&addr, "POST", "/v1/blas", Some("{not json"))
        .map_err(|e| anyhow!("self-check malformed POST failed: {e}"))?;
    checks.push(soak_check("malformed-400", bad.status == 400,
                           format!("status {}", bad.status)));

    let stats = gw.shutdown();
    checks.push(soak_check(
        "drain-exact", stats.accepted == stats.served,
        format!("{} accepted / {} served", stats.accepted, stats.served)));
    let snap = cluster.shutdown();
    checks.push(soak_check(
        "ledger-clean",
        snap.completed >= 2 && snap.failed == 0
            && snap.errors_escaped == 0,
        format!("{} completed, {} failed, {} escaped", snap.completed,
                snap.failed, snap.errors_escaped)));

    println!("\ngateway self-check:");
    for c in &checks {
        println!("  [{}] {:<16} {}", if c.pass { "PASS" } else { "FAIL" },
                 c.name, c.detail);
    }
    if let Some(path) = args.flags.get("out") {
        let doc = Json::obj()
            .field("schema", Json::Str("ftblas.gateway-check.v1".into()))
            .field("addr", Json::Str(addr))
            .field("checks", Json::Arr(checks.iter().map(|c| {
                Json::obj()
                    .field("name", Json::Str(c.name.into()))
                    .field("pass", Json::Bool(c.pass))
                    .field("detail", Json::Str(c.detail.clone()))
            }).collect()))
            .field("passed", Json::Bool(checks.iter().all(|c| c.pass)))
            .field("ledger", snap.to_json());
        ftblas::bench::harness::write_json(std::path::Path::new(path), &doc)?;
        println!("gateway-check report written to {path}");
    }
    let failed: Vec<&str> = checks
        .iter()
        .filter(|c| !c.pass)
        .map(|c| c.name)
        .collect();
    if !failed.is_empty() {
        bail!("gateway self-check failed: {}", failed.join(", "));
    }
    println!("gateway self-check passed");
    Ok(())
}

/// One soak-gate check: a named pass/fail with its evidence.
struct SoakCheck {
    name: &'static str,
    pass: bool,
    detail: String,
}

fn soak_check(name: &'static str, pass: bool, detail: String) -> SoakCheck {
    SoakCheck { name, pass, detail }
}

/// `ftblas soak` — a timed, rate-controlled fault-injection campaign
/// against an elastic burst trace, gated for CI.
///
/// The run starts the tier at its elastic floor, paces a bursty trace
/// through admission (sheds ride out with bounded retries) so the
/// autoscaler grows the tier mid-campaign, and arms scheme-aware
/// campaign strikes on every shard — including the shards spawned
/// mid-run, which inherit their slice of the schedule through the
/// shared router. The process exits nonzero unless:
///
/// - at least one grow event happened and a shard spawned mid-run
///   recorded a nonzero injected-error count (the campaign really is
///   topology-proof, not just configured);
/// - zero errors escaped and the injected / detected / corrected
///   counts — ledger-side and campaign-side — balance exactly.
fn cmd_soak(args: &Args, mut profile: Profile) -> Result<()> {
    let quick = args.has("quick");
    let duration = args.get_usize("duration", if quick { 5 } else { 20 })?
        .max(1) as f64;
    let rate_per_min = args.get_usize("rate", 600)?.max(1) as f64;
    let stride = args.get_usize("stride", 2)?.max(1) as u64;
    let target = CampaignTarget::by_name(&args.get("target", "all"))
        .ok_or_else(|| anyhow!("bad --target (want all|dmr|abft|fused)"))?;
    let policy = FtPolicy::by_name(&args.get("ft", "hybrid"))
        .ok_or_else(|| anyhow!("bad --ft"))?;
    if !policy.protects() {
        bail!("soak needs a protecting --ft policy: under `none` the \
               campaign could never strike and the gate would pass \
               vacuously");
    }
    if !policy.reaches(target) {
        bail!("campaign target `{}` is unreachable under policy `{}`: no \
               registered kernel serving the policy runs a targeted \
               scheme, so the run would inject nothing",
              target.name(), policy.name());
    }
    // elastic floor→ceiling: the run must have room to grow, and it
    // starts at the floor so every slot >= min_shards is provably a
    // mid-run spawn
    let min = args.get_usize("min-shards", 1)?.max(1);
    let max = args.get_usize("max-shards", 3)?;
    if min >= max {
        bail!("soak drives an elastic tier: need --min-shards {min} < \
               --max-shards {max}");
    }
    profile = profile.with_shard_bounds(min, max);
    profile.shards = profile.min_shards;
    profile.workers = args.get_usize("workers", 1)?.max(1);
    // MT frames need a real thread grant to reach the compute pool: at
    // the skylake_sim default of 1 thread every frame would fall
    // through to serial and the pool gates below would fail vacuously
    profile.threads =
        args.get_usize("threads", profile.threads.max(2))?.max(1);
    if args.has("pool-workers") {
        profile = profile
            .with_pool_workers(args.get_usize("pool-workers", 0)?.max(1));
    }
    if args.has("no-pool") {
        profile = profile.without_pool();
    }
    let pooled = !profile.no_pool;
    // a shallow watermark + small batch window keep burst pressure
    // visible to the controller (sheds and queue spikes, not silence)
    profile = profile
        .with_admission_depth(args.get_usize("admission-depth", 4)?.max(1))
        .with_max_batch(4);
    let campaign_seed = args.get_usize("seed", 0xCA4A16)? as u64;
    let trace_seed = args.get_usize("trace-seed", 0x50AC)? as u64;
    let campaign = CampaignConfig {
        seed: campaign_seed,
        rate_per_min,
        stride,
        target,
        ..Default::default()
    };
    profile = profile.with_campaign(campaign);
    // `--trace small-gemm` soaks the batch-fused path instead of the
    // default mixed burst workload (pair with `--backend simd` so the
    // protected small-GEMM plans carry a batched sibling)
    let shape = TraceShape::from_name(&args.get("trace", "burst"))
        .map_err(|e| anyhow!(e))?;
    // `--backend gpu-sim` points the campaign at the simulated warp
    // executors' fused-ABFT tiers; `--require`/`--deny` narrow the
    // ladder further (vector routines keep their native fallback)
    let (backend, selection) = selection_args(args, "soak")?;
    let trace_cfg = shape
        .apply(TraceConfig {
            seed: trace_seed,
            rate: 300.0,
            vec_len: args.get_usize("vec-len", 2048)?,
            mat_dim: args.get_usize("mat-dim", 128)?,
            mat_dim_alt: None,
            burst: Some(Burst::default()),
            ..Default::default()
        })
        .sized_for(duration);
    println!("soak: ~{duration:.0}s campaign at {rate_per_min:.0} err/min \
              (stride {stride}, target {}, policy {}) over {} `{}` \
              requests on {} [{}..{} shards, {} worker(s)/shard, \
              admission depth {}, backend {}]",
             target.name(), policy.name(), trace_cfg.requests, shape.name(),
             profile.name, profile.min_shards, profile.max_shards,
             profile.workers, profile.admission_depth.unwrap_or(0),
             backend.name());
    let entries = trace::generate(&trace_cfg);
    let mut scfg = ScalingConfig::from_profile(&profile)
        .with_interval(std::time::Duration::from_millis(
            args.get_usize("scale-interval", 10)?.max(1) as u64));
    scfg.verbose = true;
    let cluster_cfg = ClusterConfig {
        expected_requests: entries.len(),
        autoscale: Some(scfg),
        ..ClusterConfig::from_profile(&profile)
    };
    let min_shards = profile.min_shards;
    let router =
        Router::native_only(profile, backend).with_selection(selection);
    let cluster = Cluster::start(router, policy, cluster_cfg);
    let handle = cluster.handle();
    let retry = RetryPolicy { attempts: 6, ..RetryPolicy::default() };
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut rejected = 0u64;
    let mut retries = 0u64;
    for e in &entries {
        let at = t0 + std::time::Duration::from_secs_f64(e.at_seconds);
        let wait = at.saturating_duration_since(std::time::Instant::now());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let (admitted, spent) =
            handle.submit_with_retry(e.request.clone(), &retry);
        retries += spent as u64;
        match admitted {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    for rx in rxs {
        // execution failures land in the ledger's `failed` counter,
        // which the gate checks; a dropped channel cannot happen while
        // the cluster is alive
        let _ = rx.recv().map_err(|_| anyhow!("cluster dropped a request"))?;
    }
    let campaign_wall = t0.elapsed().as_secs_f64();
    // cooldown: give the calm tier a chance to hand capacity back so
    // one soak demonstrates the full grow → strike → shrink → retire
    // cycle (bounded; shrink is reported, not gated)
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(3);
    while std::time::Instant::now() < deadline {
        let (ups, _) = handle.scale_events();
        if ups == 0 || handle.shard_count() <= min_shards {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let live = cluster.shard_metrics();
    let retired = cluster.retired_metrics();
    let (armed, suppressed) = cluster
        .campaign()
        .map(|c| (c.injected(), c.suppressed()))
        .expect("soak always runs a campaign");
    let snap = cluster.shutdown();
    println!("\ncampaign wall {:.2}s: {} submitted, {} completed, {} shed \
              after {} retries; {} strikes armed ({} suppressed by the \
              rate gate -> {:.1} err/min realized)",
             campaign_wall, entries.len(), snap.completed, rejected, retries,
             armed, suppressed, armed as f64 / (campaign_wall / 60.0));
    for (slot, s) in live.iter().enumerate() {
        let origin = if slot < min_shards { "start" } else { "mid-run" };
        println!("shard {slot} [{origin}]: {} completed, injected={} \
                  detected={} escaped={}",
                 s.completed, s.errors_injected, s.errors_detected,
                 s.errors_escaped);
    }
    for (i, s) in retired.iter().enumerate() {
        println!("retired shard #{i} [mid-run]: {} completed, injected={} \
                  detected={} escaped={} (drained by scale-down)",
                 s.completed, s.errors_injected, s.errors_detected,
                 s.errors_escaped);
    }
    println!();
    ftblas::bench::harness::print_ledger(&snap);

    // every shard at a slot >= the floor — live or already retired —
    // was spawned mid-run (the tier started at the floor and the floor
    // slots can never be drained)
    let midrun_injected: u64 = live
        .iter()
        .skip(min_shards)
        .chain(retired.iter())
        .map(|s| s.errors_injected)
        .sum();
    let mut checks = vec![
        soak_check("requests-complete", snap.failed == 0,
                   format!("{} failed of {} completed", snap.failed,
                           snap.completed)),
        soak_check("campaign-injected", snap.errors_injected > 0,
                   format!("{} errors injected", snap.errors_injected)),
        soak_check("zero-escapes", snap.errors_escaped == 0,
                   format!("{} errors escaped detection",
                           snap.errors_escaped)),
        soak_check("detect-drift",
                   snap.errors_detected == snap.errors_injected,
                   format!("detected {} vs injected {}",
                           snap.errors_detected, snap.errors_injected)),
        soak_check("correct-drift",
                   snap.errors_corrected == snap.errors_detected,
                   format!("corrected {} vs detected {}",
                           snap.errors_corrected, snap.errors_detected)),
        soak_check("ledger-vs-campaign", snap.errors_injected == armed,
                   format!("ledger {} vs campaign {}",
                           snap.errors_injected, armed)),
        soak_check("tier-grew", snap.scale_ups >= 1,
                   format!("{} grow events", snap.scale_ups)),
        soak_check("midrun-shard-struck", midrun_injected > 0,
                   format!("{midrun_injected} strikes on shards spawned \
                            mid-run")),
    ];
    if pooled {
        // the grow→shrink cycle above ran entirely on the persistent
        // pool: parked workers must have been woken by arriving band
        // tasks, and every submitted task must have executed (no leaks
        // across elastic scale events — the Drop/shutdown join
        // guarantee, observed from the ledger side)
        checks.push(soak_check(
            "pool-wakeups", snap.pool.park_wakeups > 0,
            format!("{} park wakeups across {} pooled tasks",
                    snap.pool.park_wakeups, snap.pool.tasks_executed)));
        checks.push(soak_check(
            "pool-drained",
            snap.pool.tasks_submitted > 0
                && snap.pool.tasks_executed == snap.pool.tasks_submitted,
            format!("{} submitted / {} executed",
                    snap.pool.tasks_submitted, snap.pool.tasks_executed)));
    }
    println!("\nsoak gate:");
    for c in &checks {
        println!("  [{}] {:<22} {}", if c.pass { "PASS" } else { "FAIL" },
                 c.name, c.detail);
    }
    if let Some(path) = args.flags.get("out") {
        let doc = Json::obj()
            .field("schema", Json::Str("ftblas.soak.v1".into()))
            .field("config", Json::obj()
                .field("duration_s", Json::Num(duration))
                .field("rate_errors_per_min", Json::Num(rate_per_min))
                .field("stride", Json::Int(stride))
                .field("target", Json::Str(target.name().into()))
                .field("policy", Json::Str(policy.name().into()))
                .field("seed", Json::Int(campaign_seed))
                .field("trace_seed", Json::Int(trace_seed))
                .field("min_shards", Json::Int(min_shards as u64))
                .field("max_shards", Json::Int(max as u64))
                .field("trace", Json::Str(shape.name().into()))
                .field("backend", Json::Str(backend.name().into()))
                .field("pooled", Json::Bool(pooled))
                .field("quick", Json::Bool(quick)))
            .field("campaign", Json::obj()
                .field("wall_s", Json::Num(campaign_wall))
                .field("armed", Json::Int(armed))
                .field("suppressed", Json::Int(suppressed)))
            .field("submitted", Json::Int(entries.len() as u64))
            .field("rejected", Json::Int(rejected))
            .field("retries", Json::Int(retries))
            .field("midrun_injected", Json::Int(midrun_injected))
            .field("checks", Json::Arr(checks.iter().map(|c| {
                Json::obj()
                    .field("name", Json::Str(c.name.into()))
                    .field("pass", Json::Bool(c.pass))
                    .field("detail", Json::Str(c.detail.clone()))
            }).collect()))
            .field("passed", Json::Bool(checks.iter().all(|c| c.pass)))
            .field("ledger", snap.to_json());
        ftblas::bench::harness::write_json(std::path::Path::new(path), &doc)?;
        println!("soak report written to {path}");
    }
    let failed: Vec<&str> = checks
        .iter()
        .filter(|c| !c.pass)
        .map(|c| c.name)
        .collect();
    if !failed.is_empty() {
        bail!("soak gate failed: {}", failed.join(", "));
    }
    println!("soak gate passed: {} errors injected, all detected and \
              corrected, none escaped, across {} grow / {} shrink events",
             snap.errors_injected, snap.scale_ups, snap.scale_downs);
    Ok(())
}

fn cmd_run(args: &Args, mut profile: Profile) -> Result<()> {
    let routine = args.get("routine", "dgemm");
    let n = args.get_usize("n", 256)?;
    let policy = FtPolicy::by_name(&args.get("ft", "none"))
        .ok_or_else(|| anyhow!("bad --ft"))?;
    // --variant parses through Impl::by_name (symmetric with
    // Backend::by_name / FtPolicy::by_name) and overrides --backend
    let backend = match args.flags.get("variant") {
        Some(v) => Backend::for_variant(
            Impl::by_name(v).ok_or_else(|| anyhow!("bad --variant"))?),
        None => Backend::by_name(&args.get("backend", "tuned"))
            .ok_or_else(|| anyhow!("bad --backend"))?,
    };
    profile.threads = args.get_usize("threads", profile.threads)?.max(1);
    let mut rng = Rng::new(args.get_usize("seed", 7)? as u64);

    let req = match routine.as_str() {
        "dscal" => BlasRequest::Dscal { alpha: 1.5, x: rng.normal_vec(n) },
        "daxpy" => BlasRequest::Daxpy { alpha: 0.5, x: rng.normal_vec(n),
                                        y: rng.normal_vec(n) },
        "ddot" => BlasRequest::Ddot { x: rng.normal_vec(n), y: rng.normal_vec(n) },
        "dnrm2" => BlasRequest::Dnrm2 { x: rng.normal_vec(n) },
        "dasum" => BlasRequest::Dasum { x: rng.normal_vec(n) },
        "dgemv" => BlasRequest::Dgemv {
            alpha: 1.0, a: Matrix::random(n, n, &mut rng),
            x: rng.normal_vec(n), beta: 0.0, y: rng.normal_vec(n),
        },
        "dtrsv" => BlasRequest::Dtrsv {
            a: Matrix::random_lower_triangular(n, &mut rng),
            b: rng.normal_vec(n),
        },
        "dgemm" => BlasRequest::Dgemm {
            alpha: 1.0, a: Matrix::random(n, n, &mut rng),
            b: Matrix::random(n, n, &mut rng), beta: 0.0,
            c: Matrix::zeros(n, n),
        },
        "dsymm" => BlasRequest::Dsymm {
            alpha: 1.0, a: Matrix::random_symmetric(n, &mut rng),
            b: Matrix::random(n, n, &mut rng), beta: 0.0,
            c: Matrix::zeros(n, n),
        },
        "dtrmm" => BlasRequest::Dtrmm {
            alpha: 1.0, a: Matrix::random_lower_triangular(n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
        },
        "dtrsm" => BlasRequest::Dtrsm {
            a: Matrix::random_lower_triangular(n, &mut rng),
            b: Matrix::random(n, n, &mut rng),
        },
        "dsyrk" => BlasRequest::Dsyrk {
            alpha: 1.0, a: Matrix::random(n, n, &mut rng), beta: 0.0,
            c: Matrix::zeros(n, n),
        },
        "drot" => BlasRequest::Drot {
            x: rng.normal_vec(n), y: rng.normal_vec(n), c: 0.6, s: 0.8,
        },
        "drotm" => BlasRequest::Drotm {
            x: rng.normal_vec(n), y: rng.normal_vec(n),
            param: [-1.0, 0.9, -0.2, 0.3, 1.1],
        },
        "idamax" => BlasRequest::Idamax { x: rng.normal_vec(n) },
        "dger" => BlasRequest::Dger {
            alpha: 1.0, x: rng.normal_vec(n), y: rng.normal_vec(n),
            a: Matrix::random(n, n, &mut rng),
        },
        "dsymv" => BlasRequest::Dsymv {
            alpha: 1.0, a: Matrix::random_symmetric(n, &mut rng),
            x: rng.normal_vec(n), beta: 0.0, y: rng.normal_vec(n),
        },
        "dtrmv" => BlasRequest::Dtrmv {
            a: Matrix::random_lower_triangular(n, &mut rng),
            x: rng.normal_vec(n),
        },
        other => bail!("unknown routine `{other}`"),
    };

    let fault = args.has("inject").then_some(Fault {
        step: 1, i: 3.min(n - 1), j: 5 % n, delta: 1e4,
    });

    let router = if backend == Backend::Pjrt {
        let dir = profile.artifact_path();
        let exec = PjrtExecutor::spawn(dir.clone())?;
        let pjrt = PjrtBackend::new(exec.handle.clone(), &dir)?;
        // leak the executor so the router can use it for the process life
        std::mem::forget(exec);
        Router::with_pjrt(profile, pjrt, Backend::Pjrt)
    } else {
        Router::native_only(profile, backend)
    };

    let plan = router.plan(&req, policy).ok_or_else(|| {
        anyhow!("no candidate kernel serves {routine} n={n} under \
                 backend={} policy={}", backend.name(), policy.name())
    })?;
    println!("plan: {}", plan.describe());
    let resp = router.execute_planned(&plan, &req, fault)?;
    println!("routine={} n={n} backend={} kernel={} policy={} took={:.3}ms",
             routine, resp.backend.name(), resp.kernel, policy.name(),
             resp.exec_seconds * 1e3);
    println!("ft: detected={} corrected={}", resp.ft.errors_detected,
             resp.ft.errors_corrected);
    match &resp.result {
        BlasResult::Scalar(v) => println!("result: {v}"),
        BlasResult::Vector(v) => {
            println!("result[0..4]: {:?}", &v[..v.len().min(4)])
        }
        BlasResult::Matrix(m) => {
            println!("result[0][0..4]: {:?}", &m.data[..m.cols.min(4)])
        }
    }
    Ok(())
}
