//! # FT-BLAS
//!
//! A reproduction of *"FT-BLAS: A High Performance BLAS Implementation With
//! Online Fault Tolerance"* (Zhai et al., ICS '21) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! - [`blas`] — the pure-Rust BLAS substrate: naive (LAPACK-reference
//!   stand-in), blocked (OpenBLAS stand-in) and tuned kernels for all three
//!   BLAS levels, the runtime-probed AVX2+FMA microkernel backend in
//!   [`blas::simd`] (8×4 GEBP DGEMM, wide-lane Level-1 loops, scalar
//!   fallback off-AVX2), plus the step-wise DSCAL optimization ladder
//!   of the paper's Fig. 7.
//! - [`ft`] — the fault-tolerance engine: DMR wrappers for Level-1/2,
//!   checksum-based online ABFT for Level-3, and the fault-injection
//!   substrate used by the error-injection experiments (Figs. 10/11) —
//!   both per-call plans and cluster-wide, rate-based
//!   [`ft::injector::InjectionCampaign`]s whose schedules survive
//!   elastic scaling (the `ftblas soak` CI gate drives them).
//! - [`runtime`] — the execution substrate: the persistent work-stealing
//!   compute pool in [`runtime::pool`] that every multithreaded and
//!   batched kernel frame drains into (replacing per-call fork/join),
//!   and the PJRT runtime that loads the AOT-compiled HLO-text artifacts
//!   produced by `python/compile/aot.py` and executes them on the CPU
//!   PJRT client. Python never runs on this path.
//! - [`coordinator`] — typed BLAS requests and the serving shell: every
//!   native kernel (serial, multithreaded, DMR, fused/unfused/weighted
//!   ABFT) registers a descriptor in the kernel *registry*; a *planner*
//!   resolves request × FT policy × profile into an execution plan
//!   (kernel, thread grant, protection scheme) once at admission, via a
//!   memoized plan cache; a *cluster* front-end routes each admitted
//!   request to a shard by rendezvous hashing on the planned kernel id
//!   (shedding typed `Overloaded` errors at a per-shard queue-depth
//!   watermark, which clients ride out with
//!   [`coordinator::cluster::ClusterHandle::submit_with_retry`]); each
//!   shard's batcher schedules by planned kernel id under a
//!   thread-budget ledger with anti-starvation aging, and workers
//!   execute pre-resolved plans. The shard set itself is **elastic**: a
//!   [`coordinator::autoscale::ScalingController`] grows and shrinks it
//!   between the profile's bounds on queue-depth / shed-rate / SLO-burn
//!   signals, migrating only the minimal kernel-id slice per scale
//!   event and draining victims without dropping a request. Completions
//!   land in per-shard, per-kernel metrics ledgers (latencies, SLO
//!   burns, FT counters, scale events) that merge exactly. Dispatch is
//!   data — a descriptor table — not nested match arms. The dep-free
//!   HTTP/1.1 [`coordinator::gateway`] serves this whole pipeline over
//!   the wire: `ftblas.request.v1` envelopes in, typed status mappings
//!   out (429 + `Retry-After` on sheds, 400 on plan failures, 504 past
//!   the deadline), plus `/healthz` `/metrics` `/topology` `/campaign`
//!   admin routes — see `docs/PROTOCOL.md`.
//! - [`bench`] — the harness that regenerates every table and figure of
//!   the paper's evaluation section.
//! - [`apps`] — downstream consumers (blocked Cholesky) exercising the
//!   public API end to end.
//!
//! `docs/ARCHITECTURE.md` is the narrative companion: the full
//! admission → route → schedule → execute pipeline, the elastic-scaling
//! state machine, and the mapping from each `ft/` scheme back to the
//! paper section it reproduces.

#![warn(missing_docs)]

pub mod apps;
pub mod bench;
pub mod blas;
pub mod config;
pub mod coordinator;
pub mod ft;
pub mod runtime;
pub mod util;

pub use config::Profile;
pub use coordinator::autoscale::{ScalingConfig, ScalingController};
pub use coordinator::cluster::{Cluster, ClusterHandle, RetryPolicy};
pub use coordinator::gateway::{Envelope, Gateway, GatewayConfig};
pub use coordinator::metrics::MetricsSnapshot;
pub use coordinator::plan::{ExecutionPlan, PlanCache, Planner};
pub use coordinator::registry::{KernelId, KernelRegistry};
pub use coordinator::request::{BlasRequest, BlasResponse};
pub use ft::policy::FtPolicy;
