//! Runtime tuning profiles — the simulation substitute for the paper's two
//! machines (Skylake Gold 5122 / Cascade Lake W-2255; DESIGN.md
//! substitution #4). A profile fixes the native kernel block parameters,
//! the artifact directory, and the coordinator's worker count.

use crate::blas::level3::GemmParams;

/// A machine tuning profile.
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub gemm: GemmParams,
    /// DTRSV panel size for the tuned kernel (paper: B = 4).
    pub trsv_panel: usize,
    /// DTRSM panel size for the tuned kernel.
    pub trsm_panel: usize,
    /// Coordinator worker threads.
    pub workers: usize,
    /// Kernel-level threads for the parallel Level-3 kernels
    /// (`blas::parallel`). 1 = serial; above 1 the planner selects the
    /// MT kernels for requests clearing the MR-aligned size threshold.
    pub threads: usize,
    /// Max requests the server drains per batch window.
    pub max_batch: usize,
    /// Total thread capacity the server's budget ledger schedules
    /// against. `None` defaults to `threads × workers` (every worker
    /// can hold a full MT grant); set it lower to force the scheduler
    /// to defer MT batches instead of oversubscribing. The server
    /// clamps it to at least `threads` (one full MT grant), so the
    /// in-flight watermark can never exceed the effective budget.
    pub thread_budget: Option<usize>,
    /// Artifact directory relative to the repo root.
    pub artifact_dir: &'static str,
}

impl Profile {
    /// Skylake-sim: the paper's primary testbed (Gold 5122).
    pub fn skylake_sim() -> Profile {
        Profile {
            name: "skylake_sim",
            gemm: GemmParams { mc: 128, nc: 256, kc: 128, mr: 4, nr: 8 },
            trsv_panel: 4,
            // swept in EXPERIMENTS.md §Perf: 64 balances the (vectorized)
            // diagonal solve against per-panel GEMM packing overhead
            trsm_panel: 64,
            workers: 4,
            threads: 1,
            max_batch: 16,
            thread_budget: None,
            artifact_dir: "artifacts",
        }
    }

    /// Cascade-sim: the paper's second testbed (W-2255) — different cache
    /// blocking and wider parallelism.
    pub fn cascade_sim() -> Profile {
        Profile {
            name: "cascade_sim",
            gemm: GemmParams { mc: 96, nc: 512, kc: 192, mr: 4, nr: 8 },
            trsv_panel: 4,
            trsm_panel: 64,
            workers: 8,
            threads: 4,
            // wider machine: a larger batch window amortizes dispatch
            // across the MT kernels' bigger problems
            max_batch: 32,
            thread_budget: None,
            artifact_dir: "artifacts/cascade_sim",
        }
    }

    /// Same profile with a different kernel-level thread count.
    pub fn with_threads(mut self, threads: usize) -> Profile {
        self.threads = threads.max(1);
        self
    }

    /// Same profile with a different batch window.
    pub fn with_max_batch(mut self, max_batch: usize) -> Profile {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Same profile with an explicit thread budget for the server's
    /// scheduling ledger.
    pub fn with_thread_budget(mut self, budget: usize) -> Profile {
        self.thread_budget = Some(budget.max(1));
        self
    }

    /// Resolve the artifact directory: the working directory first, then
    /// the crate root (so examples/benches work from any cwd).
    pub fn artifact_path(&self) -> std::path::PathBuf {
        let rel = std::path::PathBuf::from(self.artifact_dir);
        if rel.join("manifest.tsv").exists() {
            return rel;
        }
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(self.artifact_dir)
    }

    pub fn by_name(name: &str) -> Option<Profile> {
        match name {
            "skylake_sim" => Some(Profile::skylake_sim()),
            "cascade_sim" => Some(Profile::cascade_sim()),
            _ => None,
        }
    }
}

impl Default for Profile {
    fn default() -> Self {
        Profile::skylake_sim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_differ() {
        let a = Profile::skylake_sim();
        let b = Profile::cascade_sim();
        assert_ne!(a.gemm.nc, b.gemm.nc);
        assert_ne!(a.artifact_dir, b.artifact_dir);
        assert_ne!(a.max_batch, b.max_batch);
    }

    #[test]
    fn scheduling_knobs_clamp() {
        let p = Profile::skylake_sim().with_max_batch(0).with_thread_budget(0);
        assert_eq!(p.max_batch, 1);
        assert_eq!(p.thread_budget, Some(1));
        assert!(Profile::cascade_sim().thread_budget.is_none());
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(Profile::by_name("skylake_sim").unwrap().name, "skylake_sim");
        assert_eq!(Profile::by_name("cascade_sim").unwrap().name, "cascade_sim");
        assert!(Profile::by_name("epyc").is_none());
    }
}
