//! Runtime tuning profiles — the simulation substitute for the paper's two
//! machines (Skylake Gold 5122 / Cascade Lake W-2255; DESIGN.md
//! substitution #4). A profile fixes the native kernel block parameters,
//! the artifact directory, and the coordinator's worker count, plus the
//! serving tier's sizing knobs: shard count, admission watermark, and
//! the per-kernel latency SLO table.

use crate::blas::level3::GemmParams;
use crate::coordinator::request::Level;
use crate::ft::injector::CampaignConfig;

/// Per-kernel end-to-end latency targets (seconds). Defaults derive
/// from the BLAS level — memory-bound L1 calls should turn around far
/// faster than an L3 GEMM — and individual registry kernels can be
/// pinned tighter or looser by name. The serving ledger counts a
/// **burn** for every completion whose end-to-end latency exceeds its
/// target ([`crate::coordinator::metrics::KernelStats::slo_burns`]).
#[derive(Clone, Debug)]
pub struct SloTable {
    /// Level-1 default target (seconds, end-to-end).
    pub l1: f64,
    /// Level-2 default target.
    pub l2: f64,
    /// Level-3 default target.
    pub l3: f64,
    /// Per-kernel overrides by registry name (e.g. `"dgemm/abft-fused"`).
    pub per_kernel: Vec<(&'static str, f64)>,
}

impl SloTable {
    /// Build a table from the three level defaults, with no per-kernel
    /// overrides.
    pub fn by_level(l1: f64, l2: f64, l3: f64) -> SloTable {
        SloTable { l1, l2, l3, per_kernel: Vec::new() }
    }

    /// Pin one kernel's target, overriding its level default.
    pub fn with_kernel(mut self, kernel: &'static str, target: f64) -> SloTable {
        self.per_kernel.push((kernel, target));
        self
    }

    /// Target for a kernel: its override if pinned, else the level
    /// default. The latest pin wins, so re-pinning a kernel overrides
    /// an earlier `with_kernel`.
    pub fn target(&self, kernel: &str, level: Level) -> f64 {
        self.per_kernel
            .iter()
            .rev()
            .find(|(k, _)| *k == kernel)
            .map(|(_, t)| *t)
            .unwrap_or(match level {
                Level::L1 => self.l1,
                Level::L2 => self.l2,
                Level::L3 => self.l3,
            })
    }
}

impl Default for SloTable {
    fn default() -> SloTable {
        // serving-sim scale: L1 calls are sub-millisecond on both
        // profiles, L3 requests queue behind multi-millisecond kernels
        SloTable::by_level(2e-3, 10e-3, 50e-3)
    }
}

/// A machine tuning profile.
#[derive(Clone, Debug)]
pub struct Profile {
    /// Profile name, as accepted by [`Profile::by_name`] and `--profile`.
    pub name: &'static str,
    /// Cache-blocking parameters for the tuned GEMM family.
    pub gemm: GemmParams,
    /// DTRSV panel size for the tuned kernel (paper: B = 4).
    pub trsv_panel: usize,
    /// DTRSM panel size for the tuned kernel.
    pub trsm_panel: usize,
    /// Coordinator worker threads.
    pub workers: usize,
    /// Kernel-level threads for the parallel Level-3 kernels
    /// (`blas::parallel`). 1 = serial; above 1 the planner selects the
    /// MT kernels for requests clearing the MR-aligned size threshold.
    pub threads: usize,
    /// Max requests the server drains per batch window.
    pub max_batch: usize,
    /// Total thread capacity the server's budget ledger schedules
    /// against. `None` defaults to `threads × workers` (every worker
    /// can hold a full MT grant); set it lower to force the scheduler
    /// to defer MT batches instead of oversubscribing. The server
    /// clamps it to at least `threads` (one full MT grant), so the
    /// in-flight watermark can never exceed the effective budget.
    pub thread_budget: Option<usize>,
    /// Shards the serving cluster *starts* with (each shard is a full
    /// worker-pool + batcher + thread-budget engine). 1 = the single
    /// monolithic server. With `min_shards == max_shards` the tier is
    /// fixed-size; widen the bounds to let the
    /// [`crate::coordinator::autoscale::ScalingController`] grow and
    /// shrink the shard set between them.
    pub shards: usize,
    /// Elastic floor: the scaling controller never drains the tier
    /// below this many shards. Equal to `shards` by default (fixed
    /// size).
    pub min_shards: usize,
    /// Elastic ceiling: the scaling controller never grows the tier
    /// past this many shards. Equal to `shards` by default (fixed
    /// size).
    pub max_shards: usize,
    /// Anti-starvation aging limit for the shard scheduler: after this
    /// many drains bypass a budget-deferred group at the FIFO head, the
    /// shard reserves its thread budget for that group (no younger
    /// group drains) until the head fits. Keeps sustained serial
    /// traffic from starving an MT batch indefinitely under a tight
    /// budget.
    pub starvation_limit: usize,
    /// Per-shard queue-depth watermark: submissions arriving while a
    /// shard's queue holds this many pending requests are shed with a
    /// typed `Overloaded` error instead of growing the queue without
    /// bound. `None` = unbounded admission.
    pub admission_depth: Option<usize>,
    /// Worker threads for the cluster's persistent work-stealing
    /// compute pool ([`crate::runtime::pool`]). `None` sizes the pool
    /// from the effective thread budget (see
    /// [`Profile::pool_worker_count`]), so admission tickets and pool
    /// capacity stay one currency.
    pub pool_workers: Option<usize>,
    /// Disable the persistent compute pool: every MT and batched kernel
    /// frame falls back to a per-call scoped fork/join. This is the
    /// `--no-pool` A/B mode; results are bitwise identical either way.
    pub no_pool: bool,
    /// Per-kernel latency SLO targets for the serving ledger.
    pub slo: SloTable,
    /// Cluster-wide fault-injection campaign knobs. When set, a serving
    /// cluster built from this profile runs a rate-based, scheme-aware
    /// [`crate::ft::injector::InjectionCampaign`] shared by every shard
    /// (including shards the autoscaler spawns mid-run). `None` = no
    /// campaign; the per-call `--inject` plans are unaffected.
    pub campaign: Option<CampaignConfig>,
    /// Artifact directory relative to the repo root.
    pub artifact_dir: &'static str,
}

impl Profile {
    /// Skylake-sim: the paper's primary testbed (Gold 5122).
    pub fn skylake_sim() -> Profile {
        Profile {
            name: "skylake_sim",
            gemm: GemmParams { mc: 128, nc: 256, kc: 128, mr: 4, nr: 8 },
            trsv_panel: 4,
            // swept in EXPERIMENTS.md §Perf: 64 balances the (vectorized)
            // diagonal solve against per-panel GEMM packing overhead
            trsm_panel: 64,
            workers: 4,
            threads: 1,
            max_batch: 16,
            thread_budget: None,
            shards: 1,
            min_shards: 1,
            max_shards: 1,
            starvation_limit: 4,
            admission_depth: None,
            pool_workers: None,
            no_pool: false,
            slo: SloTable::default(),
            campaign: None,
            artifact_dir: "artifacts",
        }
    }

    /// Cascade-sim: the paper's second testbed (W-2255) — different cache
    /// blocking and wider parallelism.
    pub fn cascade_sim() -> Profile {
        Profile {
            name: "cascade_sim",
            gemm: GemmParams { mc: 96, nc: 512, kc: 192, mr: 4, nr: 8 },
            trsv_panel: 4,
            trsm_panel: 64,
            workers: 8,
            threads: 4,
            // wider machine: a larger batch window amortizes dispatch
            // across the MT kernels' bigger problems
            max_batch: 32,
            thread_budget: None,
            // the wider machine serves as a two-shard cluster by default
            shards: 2,
            min_shards: 2,
            max_shards: 2,
            starvation_limit: 4,
            admission_depth: None,
            pool_workers: None,
            no_pool: false,
            slo: SloTable::default(),
            campaign: None,
            artifact_dir: "artifacts/cascade_sim",
        }
    }

    /// Same profile with a different kernel-level thread count.
    pub fn with_threads(mut self, threads: usize) -> Profile {
        self.threads = threads.max(1);
        self
    }

    /// Same profile with a different batch window.
    pub fn with_max_batch(mut self, max_batch: usize) -> Profile {
        self.max_batch = max_batch.max(1);
        self
    }

    /// Same profile with an explicit thread budget for the server's
    /// scheduling ledger.
    pub fn with_thread_budget(mut self, budget: usize) -> Profile {
        self.thread_budget = Some(budget.max(1));
        self
    }

    /// Same profile with a different serving-cluster shard count
    /// (fixed-size: the elastic bounds collapse onto it).
    pub fn with_shards(mut self, shards: usize) -> Profile {
        self.shards = shards.max(1);
        self.min_shards = self.shards;
        self.max_shards = self.shards;
        self
    }

    /// Same profile with elastic shard bounds: the cluster starts at
    /// the current `shards` clamped into `[min, max]`, and the scaling
    /// controller may grow/shrink within the bounds.
    pub fn with_shard_bounds(mut self, min: usize, max: usize) -> Profile {
        self.min_shards = min.max(1);
        self.max_shards = max.max(self.min_shards);
        self.shards = self.shards.clamp(self.min_shards, self.max_shards);
        self
    }

    /// Same profile with a different anti-starvation aging limit for
    /// the shard scheduler (clamped to at least 1 bypass).
    pub fn with_starvation_limit(mut self, limit: usize) -> Profile {
        self.starvation_limit = limit.max(1);
        self
    }

    /// Whether the serving tier may change size at runtime.
    pub fn elastic(&self) -> bool {
        self.min_shards < self.max_shards
    }

    /// Same profile with an explicit compute-pool worker count
    /// (clamped to at least 1).
    pub fn with_pool_workers(mut self, workers: usize) -> Profile {
        self.pool_workers = Some(workers.max(1));
        self
    }

    /// Same profile with the persistent compute pool disabled: kernel
    /// frames use per-call scoped fork/join (the `--no-pool` A/B mode).
    pub fn without_pool(mut self) -> Profile {
        self.no_pool = true;
        self
    }

    /// Resolved compute-pool size: the explicit [`Profile::pool_workers`]
    /// override when set, else the effective thread budget — the same
    /// formula the server's scheduling ledger uses (`thread_budget`,
    /// defaulting to `threads × workers`, clamped to at least one full
    /// MT grant) — so a grant admitted by the budget always fits the
    /// pool.
    pub fn pool_worker_count(&self) -> usize {
        self.pool_workers.unwrap_or_else(|| {
            self.thread_budget
                .unwrap_or(self.threads.max(1) * self.workers.max(1))
                .max(self.threads.max(1))
        })
    }

    /// Same profile with a per-shard queue-depth admission watermark.
    pub fn with_admission_depth(mut self, depth: usize) -> Profile {
        self.admission_depth = Some(depth.max(1));
        self
    }

    /// Same profile with a different SLO table.
    pub fn with_slo(mut self, slo: SloTable) -> Profile {
        self.slo = slo;
        self
    }

    /// Same profile with cluster-wide injection-campaign knobs (the
    /// stride is normalized to at least 1, matching how the schedule
    /// reads it, so configs compare predictably).
    pub fn with_campaign(mut self, mut campaign: CampaignConfig) -> Profile {
        campaign.stride = campaign.stride.max(1);
        self.campaign = Some(campaign);
        self
    }

    /// Resolve the artifact directory: the working directory first, then
    /// the crate root (so examples/benches work from any cwd).
    pub fn artifact_path(&self) -> std::path::PathBuf {
        let rel = std::path::PathBuf::from(self.artifact_dir);
        if rel.join("manifest.tsv").exists() {
            return rel;
        }
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(self.artifact_dir)
    }

    /// Look a profile up by its CLI name.
    pub fn by_name(name: &str) -> Option<Profile> {
        match name {
            "skylake_sim" => Some(Profile::skylake_sim()),
            "cascade_sim" => Some(Profile::cascade_sim()),
            _ => None,
        }
    }
}

impl Default for Profile {
    fn default() -> Self {
        Profile::skylake_sim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_differ() {
        let a = Profile::skylake_sim();
        let b = Profile::cascade_sim();
        assert_ne!(a.gemm.nc, b.gemm.nc);
        assert_ne!(a.artifact_dir, b.artifact_dir);
        assert_ne!(a.max_batch, b.max_batch);
    }

    #[test]
    fn scheduling_knobs_clamp() {
        let p = Profile::skylake_sim().with_max_batch(0).with_thread_budget(0);
        assert_eq!(p.max_batch, 1);
        assert_eq!(p.thread_budget, Some(1));
        assert!(Profile::cascade_sim().thread_budget.is_none());
        let p = Profile::skylake_sim().with_shards(0).with_admission_depth(0);
        assert_eq!(p.shards, 1);
        assert_eq!(p.admission_depth, Some(1));
        assert!(Profile::skylake_sim().admission_depth.is_none());
        assert_eq!(Profile::cascade_sim().shards, 2);
    }

    #[test]
    fn shard_bounds_default_to_fixed_size() {
        for p in [Profile::skylake_sim(), Profile::cascade_sim()] {
            assert_eq!(p.min_shards, p.shards);
            assert_eq!(p.max_shards, p.shards);
            assert!(!p.elastic());
        }
        // with_shards keeps the tier fixed at the new size
        let p = Profile::cascade_sim().with_shards(3);
        assert_eq!((p.min_shards, p.max_shards), (3, 3));
        assert!(!p.elastic());
    }

    #[test]
    fn shard_bounds_clamp_and_enable_elasticity() {
        let p = Profile::skylake_sim().with_shard_bounds(1, 4);
        assert!(p.elastic());
        assert_eq!(p.shards, 1, "start size clamps into the bounds");
        let p = Profile::cascade_sim().with_shard_bounds(0, 0);
        assert_eq!((p.min_shards, p.max_shards), (1, 1));
        assert_eq!(p.shards, 1);
        // inverted bounds collapse onto the floor
        let p = Profile::skylake_sim().with_shard_bounds(3, 2);
        assert_eq!((p.min_shards, p.max_shards), (3, 3));
        assert_eq!(p.shards, 3);
    }

    #[test]
    fn starvation_limit_clamps() {
        assert_eq!(Profile::skylake_sim().starvation_limit, 4);
        assert_eq!(Profile::skylake_sim().with_starvation_limit(0)
                       .starvation_limit, 1);
        assert_eq!(Profile::skylake_sim().with_starvation_limit(9)
                       .starvation_limit, 9);
    }

    #[test]
    fn slo_targets_derive_from_level_with_overrides() {
        let slo = SloTable::default();
        assert!(slo.target("ddot/dmr", Level::L1)
                < slo.target("dgemv/dmr", Level::L2));
        assert!(slo.target("dgemv/dmr", Level::L2)
                < slo.target("dgemm/abft-fused", Level::L3));
        let slo = SloTable::by_level(1e-3, 2e-3, 3e-3)
            .with_kernel("dgemm/abft-fused", 9e-3);
        assert_eq!(slo.target("dgemm/abft-fused", Level::L3), 9e-3);
        assert_eq!(slo.target("dgemm/tuned", Level::L3), 3e-3);
        // re-pinning the same kernel: the latest override wins
        let slo = slo.with_kernel("dgemm/abft-fused", 4e-3);
        assert_eq!(slo.target("dgemm/abft-fused", Level::L3), 4e-3);
    }

    #[test]
    fn campaign_knobs_normalize_and_default_off() {
        assert!(Profile::skylake_sim().campaign.is_none());
        assert!(Profile::cascade_sim().campaign.is_none());
        let p = Profile::skylake_sim().with_campaign(CampaignConfig {
            stride: 0,
            ..Default::default()
        });
        assert_eq!(p.campaign.as_ref().unwrap().stride, 1,
                   "stride normalizes to the schedule's floor");
    }

    #[test]
    fn pool_knobs_default_and_resolve() {
        let p = Profile::skylake_sim();
        assert!(p.pool_workers.is_none());
        assert!(!p.no_pool);
        // 1 kernel thread x 4 workers
        assert_eq!(p.pool_worker_count(), 4);
        // 4 threads x 8 workers on the wider machine
        assert_eq!(Profile::cascade_sim().pool_worker_count(), 32);
        // explicit override wins and clamps
        assert_eq!(Profile::skylake_sim().with_pool_workers(0)
                       .pool_worker_count(), 1);
        assert_eq!(Profile::skylake_sim().with_pool_workers(6)
                       .pool_worker_count(), 6);
        // an explicit budget resizes the pool with it (one currency),
        // clamped to a full MT grant
        assert_eq!(Profile::skylake_sim().with_thread_budget(2)
                       .pool_worker_count(), 2);
        assert_eq!(Profile::cascade_sim().with_thread_budget(1)
                       .pool_worker_count(), 4);
        assert!(Profile::skylake_sim().without_pool().no_pool);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(Profile::by_name("skylake_sim").unwrap().name, "skylake_sim");
        assert_eq!(Profile::by_name("cascade_sim").unwrap().name, "cascade_sim");
        assert!(Profile::by_name("epyc").is_none());
    }
}
