//! The PJRT execution engine: compile-on-first-use cache over the
//! artifact manifest, with typed f64 helpers.
//!
//! Pattern adapted from /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per artifact,
//! cached for the life of the engine (the request path never recompiles).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// A borrowed argument for an artifact call. All artifacts are f64 and
/// rank <= 2 (BLAS), which keeps this simple.
#[derive(Clone, Copy, Debug)]
pub enum ArgView<'a> {
    /// A scalar operand.
    Scalar(f64),
    /// A rank-1 operand.
    Vec1(&'a [f64]),
    /// Row-major (rows, cols).
    Mat(&'a [f64], usize, usize),
}

impl ArgView<'_> {
    fn elements(&self) -> usize {
        match self {
            ArgView::Scalar(_) => 1,
            ArgView::Vec1(d) => d.len(),
            ArgView::Mat(d, _, _) => d.len(),
        }
    }
}

/// The engine. NOT `Send` (PjRtClient is Rc-backed): own it on one
/// thread; the coordinator gives it a dedicated executor thread.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// compile + execute counters for metrics
    pub compiles: u64,
    /// Artifact executions performed.
    pub executions: u64,
}

impl Engine {
    /// Load the manifest from `dir` and connect the CPU PJRT client.
    pub fn new(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: HashMap::new(),
            compiles: 0,
            executions: 0,
        })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))
    }

    /// Compile (or fetch from cache) the executable for `name`.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let file = self.spec(name)?.file.clone();
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str().context("non-utf8 path")?)
            .map_err(|e| anyhow!("parsing {}: {e:?}", file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        self.compiles += 1;
        Ok(())
    }

    /// Execute artifact `name` with `args`; returns one Vec<f64> per
    /// output (row-major), in manifest output order.
    pub fn call(&mut self, name: &str, args: &[ArgView]) -> Result<Vec<Vec<f64>>> {
        // validate against the manifest before touching PJRT
        {
            let spec = self.spec(name)?;
            if spec.inputs.len() != args.len() {
                return Err(anyhow!(
                    "{name}: expected {} args, got {}",
                    spec.inputs.len(),
                    args.len()
                ));
            }
            for (i, (shape, arg)) in spec.inputs.iter().zip(args).enumerate() {
                if shape.elements() != arg.elements() {
                    return Err(anyhow!(
                        "{name} arg {i}: expected {} elements, got {}",
                        shape.elements(),
                        arg.elements()
                    ));
                }
            }
        }
        self.ensure_compiled(name)?;
        let literals: Vec<xla::Literal> =
            args.iter().map(to_literal).collect::<Result<_>>()?;
        let exe = self.cache.get(name).expect("just compiled");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        self.executions += 1;
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {name}: {e:?}"))?;
        let spec = self.spec(name)?;
        if outs.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: manifest promises {} outputs, got {}",
                spec.outputs.len(),
                outs.len()
            ));
        }
        outs.into_iter()
            .map(|l| {
                l.to_vec::<f64>()
                    .map_err(|e| anyhow!("output of {name}: {e:?}"))
            })
            .collect()
    }
}

fn to_literal(arg: &ArgView) -> Result<xla::Literal> {
    match arg {
        ArgView::Scalar(v) => Ok(xla::Literal::scalar(*v)),
        ArgView::Vec1(data) => Ok(xla::Literal::vec1(data)),
        ArgView::Mat(data, r, c) => xla::Literal::vec1(data)
            .reshape(&[*r as i64, *c as i64])
            .map_err(|e| anyhow!("reshape: {e:?}")),
    }
}
