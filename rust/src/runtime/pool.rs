//! Persistent work-stealing compute pool — the serving tier's threading
//! substrate.
//!
//! Before this module existed, every MT kernel frame in
//! [`crate::blas::parallel`] and every drained batch in
//! [`crate::blas::batched`] paid a full `std::thread::scope` fork/join:
//! a shard executing a steady stream of MT row-band GEMMs spawned and
//! joined OS threads thousands of times per second. The pool replaces
//! that with **one** set of long-lived workers per
//! [`crate::coordinator::cluster::Cluster`], shared by every shard via
//! the existing `Arc<Router>`:
//!
//! - each worker owns a LIFO deque (its newest band first, for warm
//!   caches) and steals FIFO from its siblings when it runs dry;
//! - a global injector queue takes the overflow when a frame submits
//!   more tasks than there are workers;
//! - idle workers park on a condvar and are woken exactly when work
//!   arrives — a quiet pool burns no CPU.
//!
//! Kernel frames never talk to the pool directly. The router installs
//! the cluster's pool into a **thread-local slot** around kernel
//! execution ([`enter`]), and the frames hand their per-band closures to
//! [`run_tasks`]: with a pool installed the bands become pool tasks
//! gated on a completion latch; without one (unit tests, `--no-pool`
//! A/B mode, plain [`crate::coordinator::server::Server`]s built
//! outside a cluster) the exact same closures run under a scoped
//! fork/join. Either way the MR-aligned band decomposition, the
//! band-local strike re-homing, and the per-item `FtReport` merges are
//! untouched — pooled execution is bitwise identical to the scoped
//! frames (the `proptest_pool` suite pins this).
//!
//! The submitting thread is not idle while its frame drains: it helps
//! execute queued tasks until its latch opens, so a grant of `t`
//! threads really applies `t` threads (the submitter plus `t - 1`
//! workers' worth of capacity) just like the scoped frames did.
//!
//! **Grants are admission tickets.** The server's thread-budget ledger
//! (debit on drain, deferral when the head group doesn't fit,
//! anti-starvation reservation) is unchanged, but its meaning shifts:
//! a grant of `t` threads is now a ticket admitting at most `t`
//! concurrent band tasks into the pool, and the budget bounds total
//! pool *occupancy* across a shard's in-flight batches instead of a
//! spawned-thread count. Sizing the pool from the same
//! `Profile::thread_budget` keeps tickets and capacity in one currency.
//!
//! Shutdown is a join guarantee: [`ComputePool::shutdown`] (also run by
//! `Drop`) flags the workers, wakes every parked one, and joins them
//! all — queued work is drained first, so `tasks_executed ==
//! tasks_submitted` holds at rest (the soak gate asserts exactly this
//! after an elastic grow→shrink).

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::util::arena;
use crate::util::stats::Summary;

/// A borrowed per-band closure, as the kernel frames build them. The
/// lifetime lets frames capture band slices of the caller's matrices;
/// [`ComputePool::run`] blocks until every task has finished, so the
/// borrows outlive the tasks.
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// An owned task as the workers see it (lifetime erased by `run`).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Completion latch for one submitted frame: counts tasks down and
/// carries the first panic payload across threads.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

impl Latch {
    fn new(remaining: usize) -> Latch {
        Latch {
            state: Mutex::new(LatchState { remaining, panic: None }),
            cv: Condvar::new(),
        }
    }

    /// One task finished (with its panic payload, if it panicked).
    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn done(&self) -> bool {
        self.state.lock().unwrap().remaining == 0
    }

    /// Block until every task completed; returns the first panic.
    fn wait(&self) -> Option<Box<dyn std::any::Any + Send>> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.take()
    }
}

/// One queued unit of work: a band closure plus its accounting tags.
struct PoolTask {
    run: Task,
    /// Kernel-frame label for the queue-to-start latency ledger.
    label: &'static str,
    queued_at: Instant,
    latch: Arc<Latch>,
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    /// Global injector queue: overflow beyond one task per worker.
    injector: Mutex<VecDeque<PoolTask>>,
    /// Per-worker deques: the owner pops LIFO (newest band, warm
    /// caches), thieves pop FIFO (oldest band, least contention).
    locals: Vec<Mutex<VecDeque<PoolTask>>>,
    /// Park/wake gate. Submitters notify while holding it and sleepy
    /// workers re-scan the queues under it, so no wakeup is lost.
    gate: Mutex<()>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Round-robin start for distributing a frame's tasks.
    cursor: AtomicUsize,
    tasks_submitted: AtomicU64,
    tasks_executed: AtomicU64,
    steals: AtomicU64,
    park_wakeups: AtomicU64,
    /// Queue-to-start wait samples per kernel-frame label (seconds).
    queue_waits: Mutex<HashMap<&'static str, Vec<f64>>>,
    /// Latest (capacity, grows, leases) of each worker's thread-local
    /// packing arena, refreshed after every executed task.
    arena: Mutex<Vec<(usize, u64, u64)>>,
}

impl PoolShared {
    /// Any task queued anywhere?
    fn has_work(&self) -> bool {
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.locals.iter().any(|q| !q.lock().unwrap().is_empty())
    }

    /// Pop the next task honoring the stealing order: own deque LIFO,
    /// then the injector, then siblings FIFO. `wid` is `None` for a
    /// helping submitter thread (no deque of its own).
    fn next_task(&self, wid: Option<usize>) -> Option<PoolTask> {
        if let Some(w) = wid {
            if let Some(t) = self.locals[w].lock().unwrap().pop_back() {
                return Some(t);
            }
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            return Some(t);
        }
        let n = self.locals.len();
        let start = wid.unwrap_or(0);
        for off in 0..n {
            let j = (start + 1 + off) % n;
            if Some(j) == wid {
                continue;
            }
            if let Some(t) = self.locals[j].lock().unwrap().pop_front() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    /// Run one queued task if any exists. Returns whether one ran.
    fn try_run_one(&self, wid: Option<usize>) -> bool {
        let Some(task) = self.next_task(wid) else { return false };
        let waited = task.queued_at.elapsed().as_secs_f64();
        self.queue_waits
            .lock()
            .unwrap()
            .entry(task.label)
            .or_default()
            .push(waited);
        // a panicking band must still open the latch, or the submitter
        // (and its borrowed matrices) would block forever; the payload
        // is re-thrown on the submitting thread instead
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(task.run));
        self.tasks_executed.fetch_add(1, Ordering::Relaxed);
        if let Some(w) = wid {
            self.arena.lock().unwrap()[w] = arena::thread_stats();
        }
        task.latch.complete(result.err());
        true
    }

    /// Worker body: drain, steal, park.
    fn worker_loop(self: &Arc<PoolShared>, wid: usize) {
        loop {
            if self.try_run_one(Some(wid)) {
                continue;
            }
            let guard = self.gate.lock().unwrap();
            // re-scan under the gate: a submitter that enqueued between
            // our last scan and this lock cannot notify until we wait
            if self.has_work() {
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            drop(self.cv.wait(guard).unwrap());
            self.park_wakeups.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Counter snapshot of one pool, merged exactly across shards by the
/// metrics layer and emitted under the ledger's `pool` object.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Worker threads owned by the pool (merge keeps the max: shards
    /// share one pool, so the counts are the same object observed
    /// twice, not disjoint pools).
    pub workers: u64,
    /// Tasks handed to the pool since startup.
    pub tasks_submitted: u64,
    /// Tasks that finished executing. Equals `tasks_submitted` whenever
    /// the pool is at rest — the soak gate's no-leak invariant.
    pub tasks_executed: u64,
    /// Tasks a worker (or helping submitter) took from a sibling's
    /// deque rather than its own.
    pub steals: u64,
    /// Times a parked worker was woken by arriving work (or shutdown).
    pub park_wakeups: u64,
    /// Total `f64` capacity of the workers' thread-local packing
    /// arenas ([`crate::util::arena::thread_stats`]).
    pub arena_capacity: u64,
    /// Total arena slab reallocations across workers — flat in steady
    /// state, when the hot path allocates nothing.
    pub arena_grows: u64,
    /// Total arena leases served across workers.
    pub arena_leases: u64,
    /// Queue-to-start wait samples (seconds) per kernel-frame label.
    pub queue_waits: HashMap<&'static str, Vec<f64>>,
}

impl PoolStats {
    /// Fold another snapshot into this one: counters sum, worker count
    /// keeps the max, wait samples concatenate (so merged summaries are
    /// exact, not averages of averages).
    pub fn absorb(&mut self, other: &PoolStats) {
        self.workers = self.workers.max(other.workers);
        self.tasks_submitted += other.tasks_submitted;
        self.tasks_executed += other.tasks_executed;
        self.steals += other.steals;
        self.park_wakeups += other.park_wakeups;
        self.arena_capacity += other.arena_capacity;
        self.arena_grows += other.arena_grows;
        self.arena_leases += other.arena_leases;
        for (label, samples) in &other.queue_waits {
            self.queue_waits
                .entry(label)
                .or_default()
                .extend_from_slice(samples);
        }
    }

    /// Per-kernel queue-to-start summaries, sorted by label for stable
    /// ledger output.
    pub fn queue_summaries(&self) -> Vec<(&'static str, Summary)> {
        let mut rows: Vec<(&'static str, Summary)> = self
            .queue_waits
            .iter()
            .map(|(label, s)| (*label, Summary::from_samples(s)))
            .collect();
        rows.sort_by_key(|(label, _)| *label);
        rows
    }
}

/// The persistent work-stealing pool. One per cluster, shared by every
/// shard through `Arc<Router>`; sized once from
/// [`crate::config::Profile::pool_worker_count`].
///
/// ```
/// use ftblas::runtime::pool::ComputePool;
/// let pool = ComputePool::new(2);
/// let mut out = vec![0u64; 4];
/// let tasks = out
///     .chunks_mut(1)
///     .enumerate()
///     .map(|(i, c)| {
///         Box::new(move || c[0] = i as u64 + 1)
///             as Box<dyn FnOnce() + Send + '_>
///     })
///     .collect();
/// pool.run("doc", tasks); // blocks until every task completed
/// assert_eq!(out, vec![1, 2, 3, 4]);
/// assert_eq!(pool.stats().tasks_executed, 4);
/// ```
pub struct ComputePool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ComputePool {
    /// Spawn a pool of `workers` threads (clamped to at least 1). The
    /// workers park immediately and cost nothing until work arrives.
    pub fn new(workers: usize) -> ComputePool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            injector: Mutex::new(VecDeque::new()),
            locals: (0..workers)
                .map(|_| Mutex::new(VecDeque::new()))
                .collect(),
            gate: Mutex::new(()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            cursor: AtomicUsize::new(0),
            tasks_submitted: AtomicU64::new(0),
            tasks_executed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            park_wakeups: AtomicU64::new(0),
            queue_waits: Mutex::new(HashMap::new()),
            arena: Mutex::new(vec![(0, 0, 0); workers]),
        });
        let handles = (0..workers)
            .map(|wid| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ftblas-pool-{wid}"))
                    .spawn(move || shared.worker_loop(wid))
                    .expect("spawn pool worker")
            })
            .collect();
        ComputePool { shared, handles: Mutex::new(handles) }
    }

    /// Worker threads owned by this pool.
    pub fn worker_count(&self) -> usize {
        self.shared.locals.len()
    }

    /// Execute a frame of borrowed band tasks on the pool and block
    /// until all of them finished (the completion latch). The first
    /// `worker_count()` tasks are dealt round-robin into the worker
    /// deques, the overflow goes to the global injector, and the
    /// submitting thread helps drain until its latch opens. If a band
    /// panicked, the panic resurfaces here, on the submitting thread.
    pub fn run<'scope>(&self, label: &'static str,
                       tasks: Vec<ScopedTask<'scope>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(n));
        let workers = self.shared.locals.len();
        let start = self.shared.cursor.fetch_add(1, Ordering::Relaxed);
        for (i, task) in tasks.into_iter().enumerate() {
            // SAFETY: `run` does not return until the latch has counted
            // every task down, so the 'scope borrows inside the closure
            // strictly outlive its execution. Erasing the lifetime is
            // what lets long-lived workers run borrowed band closures —
            // the same contract `std::thread::scope` enforces
            // structurally.
            let run: Task = unsafe {
                std::mem::transmute::<ScopedTask<'scope>, Task>(task)
            };
            let pooled = PoolTask {
                run,
                label,
                queued_at: Instant::now(),
                latch: latch.clone(),
            };
            if i < workers {
                let w = (start + i) % workers;
                self.shared.locals[w].lock().unwrap().push_back(pooled);
            } else {
                self.shared.injector.lock().unwrap().push_back(pooled);
            }
        }
        self.shared.tasks_submitted.fetch_add(n as u64, Ordering::Relaxed);
        {
            let _gate = self.shared.gate.lock().unwrap();
            self.shared.cv.notify_all();
        }
        // help: the grant admitted this thread too — drain until the
        // latch opens or the queues run dry (then the in-flight bands
        // belong to workers and the latch wait is all that's left)
        while !latch.done() {
            if !self.shared.try_run_one(None) {
                break;
            }
        }
        if let Some(payload) = latch.wait() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Counter snapshot for the serving ledger.
    pub fn stats(&self) -> PoolStats {
        let s = &self.shared;
        let mut stats = PoolStats {
            workers: s.locals.len() as u64,
            tasks_submitted: s.tasks_submitted.load(Ordering::Relaxed),
            tasks_executed: s.tasks_executed.load(Ordering::Relaxed),
            steals: s.steals.load(Ordering::Relaxed),
            park_wakeups: s.park_wakeups.load(Ordering::Relaxed),
            ..PoolStats::default()
        };
        for &(capacity, grows, leases) in s.arena.lock().unwrap().iter() {
            stats.arena_capacity += capacity as u64;
            stats.arena_grows += grows;
            stats.arena_leases += leases;
        }
        for (label, samples) in s.queue_waits.lock().unwrap().iter() {
            stats.queue_waits.insert(label, samples.clone());
        }
        stats
    }

    /// Flag shutdown, wake every parked worker, and join them all.
    /// Queued tasks are drained before the workers exit, so the no-leak
    /// invariant (`tasks_executed == tasks_submitted`) holds afterward.
    /// Idempotent; also run by `Drop`.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _gate = self.shared.gate.lock().unwrap();
            self.shared.cv.notify_all();
        }
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

thread_local! {
    /// The pool the current thread's kernel frames should submit to.
    static CURRENT: RefCell<Option<Arc<ComputePool>>> =
        RefCell::new(None);
}

/// Guard returned by [`enter`]; restores the previous thread-local pool
/// (usually `None`) when dropped.
pub struct PoolGuard {
    prev: Option<Arc<ComputePool>>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        CURRENT.with(|slot| *slot.borrow_mut() = self.prev.take());
    }
}

/// Install `pool` as the current thread's compute pool for the lifetime
/// of the returned guard. The router wraps kernel execution in this, so
/// the `blas` frames stay ignorant of the coordinator: they only ever
/// ask [`run_tasks`].
pub fn enter(pool: Arc<ComputePool>) -> PoolGuard {
    CURRENT.with(|slot| PoolGuard {
        prev: slot.borrow_mut().replace(pool),
    })
}

/// The pool installed on this thread, if any.
pub fn current() -> Option<Arc<ComputePool>> {
    CURRENT.with(|slot| slot.borrow().clone())
}

/// Execute one frame of band tasks and block until all complete: on the
/// installed pool when one is present, else under a scoped fork/join
/// (the `--no-pool` A/B mode and the default for code running outside a
/// serving cluster). A single task runs inline either way — no frame at
/// all, exactly like the serial fall-throughs.
pub fn run_tasks<'scope>(label: &'static str,
                         mut tasks: Vec<ScopedTask<'scope>>) {
    if tasks.len() <= 1 {
        if let Some(task) = tasks.pop() {
            task();
        }
        return;
    }
    match current() {
        Some(pool) => pool.run(label, tasks),
        None => std::thread::scope(|s| {
            for task in tasks {
                s.spawn(task);
            }
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    #[test]
    fn runs_borrowed_tasks_to_completion() {
        let pool = ComputePool::new(3);
        let mut out = vec![0u64; 17];
        let tasks: Vec<ScopedTask<'_>> = out
            .chunks_mut(1)
            .enumerate()
            .map(|(i, c)| {
                Box::new(move || c[0] = (i * i) as u64) as ScopedTask<'_>
            })
            .collect();
        pool.run("test-frame", tasks);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i * i) as u64);
        }
        let stats = pool.stats();
        assert_eq!(stats.tasks_submitted, 17);
        assert_eq!(stats.tasks_executed, 17);
        assert_eq!(stats.workers, 3);
        let waits = &stats.queue_waits["test-frame"];
        assert_eq!(waits.len(), 17, "every task leaves a wait sample");
    }

    #[test]
    fn many_frames_reuse_the_same_workers() {
        let pool = ComputePool::new(2);
        let hits = TestCounter::new(0);
        for _ in 0..50 {
            let tasks: Vec<ScopedTask<'_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run("reuse", tasks);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 200);
        let stats = pool.stats();
        assert_eq!(stats.tasks_executed, 200);
        assert_eq!(stats.workers, 2, "no per-frame spawns");
    }

    #[test]
    fn workers_park_and_wake() {
        let pool = ComputePool::new(2);
        // give the freshly spawned workers a moment to park
        std::thread::sleep(std::time::Duration::from_millis(30));
        let ran = TestCounter::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    ran.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run("wake", tasks);
        assert_eq!(ran.load(Ordering::Relaxed), 8);
        assert!(pool.stats().park_wakeups > 0,
                "parked workers never woke for arriving work");
    }

    #[test]
    fn band_panic_resurfaces_on_the_submitter() {
        let pool = ComputePool::new(2);
        let caught = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let tasks: Vec<ScopedTask<'_>> = (0..3)
                    .map(|i| {
                        Box::new(move || {
                            if i == 1 {
                                panic!("band strike");
                            }
                        }) as ScopedTask<'_>
                    })
                    .collect();
                pool.run("panic", tasks);
            }));
        assert!(caught.is_err(), "band panic was swallowed");
        // the pool survives the panic and keeps serving
        let mut x = [0u64; 2];
        let tasks: Vec<ScopedTask<'_>> = x
            .chunks_mut(1)
            .map(|c| Box::new(move || c[0] = 9) as ScopedTask<'_>)
            .collect();
        pool.run("after-panic", tasks);
        assert_eq!(x, [9, 9]);
        let stats = pool.stats();
        assert_eq!(stats.tasks_executed, stats.tasks_submitted,
                   "leaked tasks after a band panic");
    }

    #[test]
    fn shutdown_joins_workers_and_is_idempotent() {
        let pool = ComputePool::new(4);
        let tasks: Vec<ScopedTask<'_>> =
            (0..16).map(|_| Box::new(|| {}) as ScopedTask<'_>).collect();
        pool.run("pre-shutdown", tasks);
        pool.shutdown();
        pool.shutdown(); // second call must be a no-op
        let stats = pool.stats();
        assert_eq!(stats.tasks_executed, stats.tasks_submitted,
                   "shutdown leaked queued tasks");
    }

    #[test]
    fn run_tasks_falls_back_to_scoped_without_a_pool() {
        assert!(current().is_none());
        let mut out = vec![0u64; 4];
        let tasks: Vec<ScopedTask<'_>> = out
            .chunks_mut(1)
            .enumerate()
            .map(|(i, c)| Box::new(move || c[0] = i as u64 + 1)
                 as ScopedTask<'_>)
            .collect();
        run_tasks("scoped", tasks);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn enter_installs_and_guard_restores() {
        let pool = Arc::new(ComputePool::new(2));
        assert!(current().is_none());
        {
            let _guard = enter(pool.clone());
            assert!(current().is_some());
            let mut out = vec![0u64; 3];
            let tasks: Vec<ScopedTask<'_>> = out
                .chunks_mut(1)
                .map(|c| Box::new(move || c[0] = 7) as ScopedTask<'_>)
                .collect();
            run_tasks("installed", tasks);
            assert_eq!(out, vec![7, 7, 7]);
        }
        assert!(current().is_none(), "guard did not restore the slot");
        assert!(pool.stats().tasks_executed >= 3,
                "run_tasks bypassed the installed pool");
    }

    #[test]
    fn stats_absorb_merges_exactly() {
        let mut a = PoolStats {
            workers: 4,
            tasks_submitted: 10,
            tasks_executed: 10,
            steals: 2,
            park_wakeups: 5,
            arena_capacity: 100,
            arena_grows: 1,
            arena_leases: 20,
            ..PoolStats::default()
        };
        a.queue_waits.insert("dgemm/mt", vec![1e-6, 2e-6]);
        let mut b = PoolStats {
            workers: 2,
            tasks_submitted: 3,
            tasks_executed: 3,
            ..PoolStats::default()
        };
        b.queue_waits.insert("dgemm/mt", vec![3e-6]);
        b.queue_waits.insert("batched", vec![4e-6]);
        a.absorb(&b);
        assert_eq!(a.workers, 4, "worker count merges by max");
        assert_eq!(a.tasks_submitted, 13);
        assert_eq!(a.tasks_executed, 13);
        assert_eq!(a.queue_waits["dgemm/mt"].len(), 3);
        assert_eq!(a.queue_waits["batched"].len(), 1);
        let rows = a.queue_summaries();
        assert_eq!(rows[0].0, "batched", "summaries sorted by label");
        assert_eq!(rows[1].1.n, 3);
    }
}
