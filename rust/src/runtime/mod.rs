//! Execution runtimes: the persistent compute pool and the PJRT bridge.
//!
//! [`pool`] is the serving tier's threading substrate — a persistent
//! work-stealing worker set that replaces per-call `std::thread::scope`
//! fork/join on every MT and batched kernel path.
//!
//! The rest of the module is the PJRT runtime (Layer 3's bridge to the
//! AOT artifacts):
//! `python/compile/aot.py` lowers every routine x variant x shape to HLO
//! *text* plus a manifest; this module loads the manifest
//! ([`manifest`]), compiles artifacts on the CPU PJRT client on first
//! use, caches the executables, and provides typed f64 execute calls
//! ([`engine`]). HLO text — not serialized protos — is the interchange
//! format because xla_extension 0.5.1 rejects jax ≥ 0.5's 64-bit
//! instruction ids; the text parser reassigns them.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`), so the
//! engine is owned by a single thread; the coordinator gives it a
//! dedicated executor thread and talks to it over channels.

pub mod engine;
pub mod manifest;
pub mod pool;

pub use engine::{ArgView, Engine};
pub use manifest::{Manifest, ArtifactSpec, Shape};
