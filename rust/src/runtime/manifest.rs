//! Manifest parser for `artifacts/<profile>/manifest.tsv`.
//!
//! serde is not vendored in this offline image (DESIGN.md §9), so the
//! manifest is a line-oriented TSV with a tiny grammar:
//!
//! ```text
//! # ftblas manifest v1 profile=skylake_sim
//! name \t file \t routine \t variant \t inputs \t outputs \t meta
//! ```
//!
//! where `inputs`/`outputs` are space-separated `f64:SHAPE` with SHAPE
//! either `scalar` or `D1xD2x...`, and `meta` is space-separated `k=v`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A tensor shape in the manifest (f64 only; the paper is all double).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// The rank-0 shape.
    pub fn scalar() -> Shape {
        Shape(vec![])
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.0.iter().product()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Parse the manifest's `f64:...` shape syntax.
    pub fn parse(s: &str) -> Result<Shape> {
        let body = s
            .strip_prefix("f64:")
            .with_context(|| format!("shape `{s}` missing f64: prefix"))?;
        if body == "scalar" {
            return Ok(Shape::scalar());
        }
        let dims = body
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("bad shape `{s}`"))?;
        Ok(Shape(dims))
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Unique artifact name (manifest key).
    pub name: String,
    /// HLO-text file, relative to the manifest directory.
    pub file: PathBuf,
    /// BLAS routine the artifact implements.
    pub routine: String,
    /// Artifact variant (`ori`, `dmr`, `abft`, ...).
    pub variant: String,
    /// Input shapes, in call order.
    pub inputs: Vec<Shape>,
    /// Output shapes.
    pub outputs: Vec<Shape>,
    /// Free-form key=value metadata from the manifest row.
    pub meta: HashMap<String, String>,
}

impl ArtifactSpec {
    /// Numeric metadata accessor (`n`, `kc`, `panel`, ...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// The parsed manifest: ordered specs + indices.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Profile the artifacts were compiled for.
    pub profile: String,
    /// All artifact entries, in manifest order.
    pub specs: Vec<ArtifactSpec>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    /// Parse manifest text (TSV rows + `# profile=` header).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('#') {
                if let Some(p) = line.split("profile=").nth(1) {
                    m.profile = p.trim().to_string();
                }
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 7 {
                bail!("manifest line {}: expected 7 fields, got {}",
                      lineno + 1, fields.len());
            }
            let inputs = fields[4]
                .split(' ')
                .filter(|s| !s.is_empty())
                .map(Shape::parse)
                .collect::<Result<Vec<_>>>()?;
            let outputs = fields[5]
                .split(' ')
                .filter(|s| !s.is_empty())
                .map(Shape::parse)
                .collect::<Result<Vec<_>>>()?;
            let meta = fields[6]
                .split(' ')
                .filter(|s| !s.is_empty())
                .filter_map(|kv| {
                    kv.split_once('=')
                        .map(|(k, v)| (k.to_string(), v.to_string()))
                })
                .collect();
            let spec = ArtifactSpec {
                name: fields[0].to_string(),
                file: dir.join(fields[1]),
                routine: fields[2].to_string(),
                variant: fields[3].to_string(),
                inputs,
                outputs,
                meta,
            };
            m.by_name.insert(spec.name.clone(), m.specs.len());
            m.specs.push(spec);
        }
        Ok(m)
    }

    /// Load and parse `manifest.tsv` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Look an artifact up by its manifest name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.by_name.get(name).map(|&i| &self.specs[i])
    }

    /// All specs for a routine/variant pair.
    pub fn find(&self, routine: &str, variant: &str) -> Vec<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.routine == routine && s.variant == variant)
            .collect()
    }

    /// The spec for routine/variant whose `n` metadata matches.
    pub fn find_n(&self, routine: &str, variant: &str, n: usize)
                  -> Option<&ArtifactSpec> {
        self.specs.iter().find(|s| {
            s.routine == routine && s.variant == variant
                && s.meta_usize("n") == Some(n)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# ftblas manifest v1 profile=skylake_sim
dscal_ori_n65536\tdscal_ori_n65536.hlo.txt\tdscal\tori\tf64:scalar f64:65536\tf64:65536\tblock=1024 n=65536
dgemm_abft_n128\tdgemm_abft_n128.hlo.txt\tdgemm\tabft\tf64:128x128 f64:128x128 f64:4\tf64:128x128 f64:128 f64:128 f64:128 f64:128\tbk=64 bm=64 bn=64 n=128
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.profile, "skylake_sim");
        assert_eq!(m.specs.len(), 2);
        let s = m.get("dscal_ori_n65536").unwrap();
        assert_eq!(s.routine, "dscal");
        assert_eq!(s.inputs[0], Shape::scalar());
        assert_eq!(s.inputs[1], Shape(vec![65536]));
        assert_eq!(s.meta_usize("block"), Some(1024));
        assert_eq!(s.file, Path::new("/tmp/a/dscal_ori_n65536.hlo.txt"));
    }

    #[test]
    fn find_n_matches() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.find_n("dgemm", "abft", 128).is_some());
        assert!(m.find_n("dgemm", "abft", 256).is_none());
        assert!(m.find_n("dgemm", "ori", 128).is_none());
        assert_eq!(m.find("dgemm", "abft").len(), 1);
    }

    #[test]
    fn shape_parse_errors() {
        assert!(Shape::parse("f32:4").is_err());
        assert!(Shape::parse("f64:4xq").is_err());
        assert_eq!(Shape::parse("f64:2x3").unwrap().elements(), 6);
    }

    #[test]
    fn bad_line_rejected() {
        assert!(Manifest::parse("a\tb\tc", Path::new(".")).is_err());
    }
}
